"""Scenario base layer: availability processes with host AND jit surfaces.

The paper's theory makes *no* distributional assumption on A(t); the
processes in `core.participation` cover only i.i.d. Bernoulli, deterministic
blackouts, and trace replay — all NumPy-on-host, which forces the vmapped
fleet executor to precompute (T, N) trace matrices before it can sweep
availability. This module defines the contract that removes both limits:

* `AvailabilityProcess` — one availability law with TWO sampling surfaces
  that draw *identical* masks at a fixed seed:

    - jit-native: `sample_fn()` returns a pure function
      ``(key, t, state) -> (mask, state)`` safe under `jax.jit`/`jax.vmap`
      *and* `jax.lax.scan`, so `run_fl` and the fleet executor sample
      availability *inside* the jitted round (no host trace
      materialisation). `state` is a pytree of arrays (empty dict for
      memoryless processes) so per-trial parameters and chain state batch
      along the fleet's trial axis — and so the whole-run scan engine can
      thread it through the scan carry, advancing the chain across a chunk
      of rounds without leaving the compiled program.
    - host: `host_sampler()` returns a stateful object satisfying the
      legacy participation protocol (``.sample(t) -> (N,) bool``, ``.n``),
      consumable by `run_fl`, `sim.engine.FedSimEngine`, and every existing
      call site. The dynamics are re-implemented in NumPy; only the uniform
      draws come from the same counter-based `jax.random` stream, which is
      what makes the two surfaces bit-identical (property-tested in
      tests/test_scenarios.py).

* `TauBound` — which theory regime the process falls in: whether the
  paper's Assumption 4 (τ(t,i) <= t0 + t/b) holds deterministically, with
  the witnessing t0, plus the stationary E[τ] where a closed form exists.

* `Scenario` — a named (process, latency-model) pair: the full environment
  of one experiment cell. `sim_inputs()` adapts it to `FedSimEngine`.

Conventions shared by every process (matching `core.participation`):
round 0 is always all-active (paper Remark 5.2 / Definition 5.2(1)), and
per-round randomness is derived as `jax.random.fold_in(key, t)` so masks
depend only on (seed, t), never on how many times a surface was queried.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np


@dataclass(frozen=True)
class TauBound:
    """Where a process sits relative to the paper's Assumption 4.

    Attributes:
      deterministic: True when ``τ(t,i) <= t0 + t/b`` holds for EVERY sample
        path (with the `t0` below and any b); False for processes with
        unbounded (e.g. geometric) off-time tails, where the bound holds
        only in probability.
      t0: the witnessing offset — the longest possible inactivity stretch —
        or ``np.inf`` when no almost-sure bound exists.
      expected_tau: stationary E[τ] averaged over devices (Definition 5.1's
        τ̄ in the long-run limit); ``np.nan`` when no closed form exists.
      note: one-line justification, for benchmark tables and error messages.
    """

    deterministic: bool
    t0: float
    expected_tau: float
    note: str = ""

    def holds(self, t0: float, b: float = np.inf) -> bool:
        """True iff Assumption 4 with offset `t0` (and any slope b >= 1)
        holds on every sample path of this process."""
        del b  # any b suffices once the stretch is bounded by t0
        return self.deterministic and self.t0 <= t0


class HostSampler:
    """Host (NumPy) surface of an `AvailabilityProcess`.

    Satisfies the legacy participation protocol: ``sample(t) -> (N,) bool``
    plus the ``n`` attribute, so it plugs into `run_fl(participation=...)`,
    `FedSimEngine`, and `fleet.Trial(participation=...)` unchanged.

    Stateful processes (Markov chains) must be queried with strictly
    consecutive rounds t = 0, 1, 2, ... — the chain state at t depends on
    every earlier transition. Memoryless processes accept any t.
    """

    def __init__(self, process: "AvailabilityProcess"):
        self.process = process
        self.n = process.n
        self._state = process.init_state_host()
        self._t_next = 0

    def sample(self, t: int) -> np.ndarray:
        """Availability mask for round t as a (N,) bool array."""
        if not self.process.stateless:
            if t != self._t_next:
                raise ValueError(
                    f"{type(self.process).__name__} is stateful: host "
                    f"sampling must visit rounds in order (expected "
                    f"t={self._t_next}, got t={t})")
            self._t_next += 1
        mask, self._state = self.process.host_step(t, self._state)
        return np.asarray(mask, bool)

    def sample_block(self, t0: int, length: int) -> np.ndarray:
        """(length, n) bool masks for rounds [t0, t0 + length).

        The scan engine's chunk draw (docs/architecture.md §9): cohort
        algorithms need masks on the host to assemble compact batches, and
        drawing one chunk at a time keeps host-side mask storage bounded by
        the chunk length rather than T. Identical draws to `sample` called
        round by round (it IS `sample` called round by round).
        """
        return np.stack([self.sample(t0 + j) for j in range(length)])


class AvailabilityProcess:
    """Base class: one availability law, two equivalent sampling surfaces.

    Subclasses set `n` (device count), `seed`, `stateless`, and implement:

      * `init_state()`      — jit-side state pytree (jnp leaves) holding
                              BOTH chain state and numeric parameters:
                              nothing trial-specific may hide in the sample
                              function's closure, so the fleet executor can
                              stack states of same-type processes with
                              different parameters along the trial axis.
      * `sample_fn()`       — pure ``(key, t, state) -> (mask, state)``;
                              `mask` is (n,) bool, `t` a traced int32
                              scalar. MUST force all-active at t == 0.
      * `host_step(t, st)`  — the same transition in NumPy, consuming
                              uniforms from `uniforms(t, ...)`.
      * `stationary_rate()` — (n,) long-run activity rate per device.
      * `tau_bound()`       — `TauBound` classifying the theory regime.
    """

    n: int
    seed: int
    stateless: bool = True
    #: Definition 5.2(1) convention: sample surfaces force every device
    #: active at t == 0. `ElasticProcess` opts out (clients that have not
    #: JOINED by round 0 cannot be active; runners use TauStats
    #: strict=False to count their τ from the virtual round −1).
    round0_all_active: bool = True

    @property
    def key(self) -> jax.Array:
        """Base PRNG key; both surfaces derive round keys by fold_in(key, t)."""
        return jax.random.PRNGKey(self.seed)

    def uniforms(self, t: int, shape: tuple) -> np.ndarray:
        """Host-side U(0,1) draws for round t — the SAME values the jit
        surface draws from fold_in(key, t), materialised to NumPy."""
        return np.asarray(jax.random.uniform(
            jax.random.fold_in(self.key, t), shape), np.float64)

    # -- jit surface ------------------------------------------------------ #
    def init_state(self) -> dict:
        """Initial jit-side state pytree ({} for memoryless processes)."""
        return {}

    def sample_fn(self) -> Callable:
        """Pure ``(key, t, state) -> ((n,) bool mask, state)``, jit/vmap-safe."""
        raise NotImplementedError

    # -- host surface ----------------------------------------------------- #
    def init_state_host(self) -> dict:
        """NumPy mirror of `init_state` (parameters + chain state)."""
        return jax.tree.map(np.asarray, self.init_state())

    def host_step(self, t: int, state: dict) -> tuple[np.ndarray, dict]:
        """NumPy mirror of one `sample_fn` application at round t."""
        raise NotImplementedError

    def host_sampler(self) -> HostSampler:
        """Fresh host-surface sampler (legacy participation protocol)."""
        return HostSampler(self)

    # -- theory ----------------------------------------------------------- #
    def stationary_rate(self) -> np.ndarray:
        """(n,) long-run fraction of rounds each device is active."""
        raise NotImplementedError

    def tau_bound(self) -> TauBound:
        """Assumption-4 classification of this process (see `TauBound`)."""
        raise NotImplementedError


@dataclass
class Scenario:
    """One experiment environment: availability process + latency model.

    Attributes:
      process: the `AvailabilityProcess` (who is active each round).
      latency: optional per-client RTT model from `repro.sim.latency`
        (``sample(t) -> (N,) seconds``); None for round-synchronous runs.
      name: registry name + parameter tag, for labels and artifacts.
    """

    process: AvailabilityProcess
    latency: Any = None
    name: str = ""

    @property
    def n(self) -> int:
        """Device count of the underlying process."""
        return self.process.n

    def sim_inputs(self) -> tuple[HostSampler, Any]:
        """(participation, latency) pair for `FedSimEngine`."""
        if self.latency is None:
            raise ValueError(
                f"scenario {self.name!r} has no latency model; pass one at "
                "construction to drive the runtime simulator")
        return self.process.host_sampler(), self.latency


def as_process(scenario_or_process) -> AvailabilityProcess:
    """Accept either a `Scenario` or a bare process; return the process."""
    if isinstance(scenario_or_process, Scenario):
        return scenario_or_process.process
    return scenario_or_process
