"""Trace-replay availability: replay recorded device traces from disk,
streamed in windows so a (T, N) mask matrix is never materialised.

The paper's theory regime is *arbitrary* device unavailability — no
distributional assumption on A(t) at all (Assumption 4 is the only
structure, and even it may fail). Every other process in this package is a
synthetic model; this one replays what real fleets actually did. The legacy
`core.participation.TraceParticipation` already replays a matrix, but it
holds the full (T, N) trace in RAM — at fleet scale (N=10⁶ clients, T=10⁵
rounds) that is ~100 GB of masks for data the run only ever touches one
scan chunk at a time. This module fixes the ingestion path end to end:

  * **Trace file format v1** (`write_trace` / `open_trace`): a ``.npy``
    payload of bit-packed masks (uint8, shape (T, ⌈N/8⌉), `np.packbits`
    along the client axis) plus a ``.json`` sidecar recording
    ``{"format": "repro-trace-v1", "n_clients": N, "n_rounds": T}``.
    The payload is read through a memmap, so opening a trace costs O(1)
    and reading rounds [t0, t0+L) costs O(L·N/8) bytes — `write_trace`
    accepts an *iterator of row blocks* for the same reason, so converting
    a public availability trace never materialises (T, N) either.
  * **`TraceReplay`** — an `AvailabilityProcess` whose jit surface carries
    the current `window` rounds of masks in the scan carry (a small ring
    buffer, (W, N) bool) and whose host surface pages the same windows
    on demand. The scan engine refreshes the carried window at chunk
    boundaries through the `pre_chunk` pipelining hook (`load_window`),
    exactly like the paged bank's residency step; the per-round dispatch
    loop refreshes it between rounds. Masks are pure file contents, so
    every engine and every `scan_chunk` draws bit-identical masks.

Replay semantics match `TraceParticipation`: rounds past the end of the
trace repeat the last recorded row, and round 0 is forced all-active
(Definition 5.2(1)) regardless of what the file's first row says. τ/rate
statistics (`stationary_rate`, `tau_bound`) are *post-hoc empirical* —
computed from the recorded masks in one streamed pass — because a recorded
trace admits no a-priori bound: this is the arbitrary regime
(docs/scenarios.md taxonomy, docs/operations.md for the file format).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Callable, Iterable

import jax.numpy as jnp
import numpy as np

from repro.scenarios.base import AvailabilityProcess, TauBound
from repro.scenarios.registry import register

FORMAT = "repro-trace-v1"


def _sidecar(path: str) -> str:
    """Sidecar json path for a trace payload path."""
    return (path[:-4] if path.endswith(".npy") else path) + ".json"


def _atomic_bytes(path: str, data: bytes) -> None:
    """Write `data` to `path` via a same-directory temp file + rename."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def write_trace(path: str, masks, *, n_clients: int | None = None,
                n_rounds: int | None = None) -> str:
    """Write availability masks as a v1 trace file; returns the payload path.

    Args:
      path: payload destination; ``.npy`` is appended if missing, and the
        ``.json`` sidecar lands next to it. Both are written to temp files
        in the same directory and atomically renamed (payload first), so a
        crash mid-write never leaves a torn trace.
      masks: either a (T, N) bool array, or an *iterator of (L, N) bool
        blocks* — the streaming form converts arbitrarily long recordings
        without ever materialising (T, N) (see docs/operations.md for the
        conversion recipe).
      n_clients: required for the iterator form (the header is written
        before the first block); inferred from an array.
      n_rounds: required for the iterator form; the writer raises if the
        blocks do not sum to exactly this many rounds.

    Returns:
      The payload path (with the ``.npy`` suffix).
    """
    if not path.endswith(".npy"):
        path += ".npy"
    if hasattr(masks, "shape"):
        a = np.asarray(masks, bool)
        if a.ndim != 2:
            raise ValueError(f"masks must be (T, N), got shape {a.shape}")
        n_rounds, n_clients = a.shape
        blocks: Iterable = (a,)
    else:
        if n_clients is None or n_rounds is None:
            raise ValueError("write_trace(masks=<iterator>) needs explicit "
                             "n_clients= and n_rounds= (the npy header is "
                             "written before the first block)")
        blocks = masks
    n_bytes = -(-n_clients // 8)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=parent, suffix=".tmp")
    rows = 0
    try:
        with os.fdopen(fd, "wb") as f:
            np.lib.format.write_array_header_1_0(
                f, {"descr": "|u1", "fortran_order": False,
                    "shape": (int(n_rounds), n_bytes)})
            for block in blocks:
                b = np.asarray(block, bool)
                if b.ndim != 2 or b.shape[1] != n_clients:
                    raise ValueError(f"trace block must be (L, {n_clients}),"
                                     f" got shape {b.shape}")
                f.write(np.packbits(b, axis=1).tobytes())
                rows += b.shape[0]
        if rows != n_rounds:
            raise ValueError(f"trace blocks sum to {rows} rounds, header "
                             f"promised {n_rounds}")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _atomic_bytes(_sidecar(path), json.dumps(
        {"format": FORMAT, "n_clients": int(n_clients),
         "n_rounds": int(n_rounds)}).encode())
    return path


class TraceFile:
    """Read surface of a v1 trace: memmapped bit-packed masks.

    Attributes:
      path: the ``.npy`` payload path.
      n_clients: N, from the sidecar.
      n_rounds: T, from the sidecar.

    `read_block` is the only read primitive; everything downstream
    (`TraceReplay` windows, statistics passes) goes through it, so host
    mask residency is always bounded by the requested block length.
    """

    def __init__(self, path: str):
        self.path = path
        with open(_sidecar(path)) as f:
            meta = json.load(f)
        if meta.get("format") != FORMAT:
            raise ValueError(f"{_sidecar(path)}: expected format {FORMAT!r},"
                             f" got {meta.get('format')!r}")
        self.n_clients = int(meta["n_clients"])
        self.n_rounds = int(meta["n_rounds"])
        self._mm = np.load(path, mmap_mode="r")
        expect = (self.n_rounds, -(-self.n_clients // 8))
        if self._mm.shape != expect:
            raise ValueError(f"{path}: payload shape {self._mm.shape} does "
                             f"not match sidecar (expected {expect})")

    def read_block(self, t0: int, length: int) -> np.ndarray:
        """Masks for rounds [t0, t0+length) as a (length, N) bool array.

        Rounds past the end of the trace repeat the last recorded row
        (`TraceParticipation` clamp semantics), so callers can replay a
        trace shorter than the run.
        """
        idx = np.clip(np.arange(t0, t0 + length), 0, self.n_rounds - 1)
        packed = np.asarray(self._mm[idx])
        return np.unpackbits(packed, axis=1,
                             count=self.n_clients).astype(bool)


def open_trace(path: str) -> TraceFile:
    """Open a v1 trace file (payload + sidecar) for memmapped reading."""
    if not path.endswith(".npy"):
        path += ".npy"
    return TraceFile(path)


def synthesize_trace(path: str, *, n: int, horizon: int, seed: int = 0,
                     rate: float = 0.5, burst: float = 4.0,
                     churn_frac: float = 0.0, block: int = 256) -> str:
    """Record a synthetic device trace to disk, streamed block by block.

    Drives a Gilbert–Elliott host sampler (`seed`-keyed, stationary
    activity `rate`, expected off-burst `burst` rounds) for `horizon`
    rounds, writing `n` device columns to `path`, and ANDs in
    permanent departures: the first ``int(n * churn_frac)`` devices leave
    at deterministic, evenly spaced rounds and never return — under the
    replay clamp they stay dark past the end of the trace too, which puts
    the trace firmly in the arbitrary (no τ-bound) regime. The writer
    consumes (block, n) chunks, so this doubles as the reference recipe
    for converting a real availability log (docs/operations.md).

    Returns the payload path.
    """
    from repro.scenarios.processes import GilbertElliott
    sampler = GilbertElliott.from_rate_and_burst(
        rate, burst, n=n, seed=seed).host_sampler()
    k = int(n * churn_frac)
    depart = np.full(n, np.iinfo(np.int64).max, np.int64)
    if k:
        depart[:k] = (np.arange(1, k + 1) * horizon) // (k + 1)

    def blocks():
        for t0 in range(0, horizon, block):
            length = min(block, horizon - t0)
            rows = sampler.sample_block(t0, length)
            t = np.arange(t0, t0 + length)[:, None]
            yield rows & (t < depart[None, :])

    return write_trace(path, blocks(), n_clients=n, n_rounds=horizon)


def cached_trace(*, n: int, horizon: int, seed: int = 0, rate: float = 0.5,
                 burst: float = 4.0, churn_frac: float = 0.0,
                 cache_dir: str | None = None) -> str:
    """Synthesize-once path for a parametrised trace (benchmark axes).

    The filename is content-keyed by every recipe parameter, so repeated
    sweeps reuse the file; `write_trace`'s atomic rename makes concurrent
    writers safe (last complete writer wins with identical bytes).
    """
    d = cache_dir or os.path.join(tempfile.gettempdir(), "repro_traces")
    name = (f"trace_n{n}_t{horizon}_s{seed}_r{rate:g}"
            f"_b{burst:g}_c{churn_frac:g}.npy")
    path = os.path.join(d, name)
    if not (os.path.exists(path) and os.path.exists(_sidecar(path))):
        synthesize_trace(path, n=n, horizon=horizon, seed=seed, rate=rate,
                         burst=burst, churn_frac=churn_frac)
    return path


class TraceReplay(AvailabilityProcess):
    """Replay an on-disk trace through both scenario surfaces, windowed.

    The jit-side state is ``{"win": (W, N) bool, "win_t0": int32}`` — the
    `window` rounds of masks currently riding the scan carry. `sample_fn`
    indexes the window at ``t - win_t0`` (clamped); refreshing the window
    is a *host* responsibility through the window protocol below, which
    the engines wire up (scan: `pre_chunk` at chunk boundaries; loop:
    between rounds; fleet: both, stacked over trials). The process is
    `stateless` in the host-sampler sense: masks depend only on (file, t),
    so host sampling is random-access and the compiled runtime simulator's
    out-of-order arrival queries would be servable — the *windowed carry*
    is what keeps it off the compiled sim path (`sim_scan_supported`).

    Window protocol (duck-typed; any process exposing it is streamed by
    the engines — `ElasticProcess` forwards it to its inner process):

      * ``scan_window``                — W, the carried window length.
      * ``read_window(t0)``           — (W, N) bool rows from the backing
                                         store (host side, np).
      * ``load_window(state, t0)``    — new jit state with the window
                                         re-pointed at [t0, t0+W); must not
                                         *read* traced leaves, so the scan
                                         engine can call it mid-pipeline.
      * ``load_window_fleet(state, procs, t0)`` — stacked-trial form.
    """

    stateless = True

    def __init__(self, path: str, *, n: int | None = None, seed: int = 0,
                 window: int = 64):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.trace = open_trace(path)
        if n is not None and n != self.trace.n_clients:
            raise ValueError(
                f"trace {path!r} records {self.trace.n_clients} clients, "
                f"but n={n} was requested — trace replay cannot resize a "
                "recording")
        self.n = self.trace.n_clients
        self.seed = seed
        self.scan_window = int(window)
        self._stats_cache = None

    # -- window protocol --------------------------------------------------- #
    def read_window(self, t0: int) -> np.ndarray:
        """(W, N) bool masks for rounds [t0, t0+W) (clamped past the end)."""
        return self.trace.read_block(t0, self.scan_window)

    def load_window(self, state: dict, t0: int) -> dict:
        """Jit state with the carried window re-pointed at [t0, t0+W).

        Only *replaces* the window leaves with host-built arrays — never
        reads traced ones — so the scan engine's pipelined `pre_chunk`
        hook can call it while the device still owns the previous chunk.
        """
        return {**state, "win": jnp.asarray(self.read_window(t0)),
                "win_t0": jnp.int32(t0)}

    def load_window_fleet(self, state: dict, procs, t0: int) -> dict:
        """Stacked-trial `load_window`: state leaves lead with the trial
        axis K; `procs` are the K trials' (same-window) processes."""
        wins = np.stack([p.read_window(t0) for p in procs])
        return {**state, "win": jnp.asarray(wins),
                "win_t0": jnp.full((len(procs),), t0, jnp.int32)}

    # -- jit surface ------------------------------------------------------- #
    def init_state(self) -> dict:
        """Initial jit state: the window covering rounds [0, W)."""
        return {"win": jnp.asarray(self.read_window(0)),
                "win_t0": jnp.int32(0)}

    def sample_fn(self) -> Callable:
        """Pure window lookup; `key` is unused (replay is deterministic)."""
        w = self.scan_window

        def sample(key, t, state):
            del key
            row = state["win"][jnp.clip(t - state["win_t0"], 0, w - 1)]
            return jnp.where(t == 0, jnp.ones_like(row), row), state

        return sample

    # -- host surface ------------------------------------------------------ #
    def host_step(self, t: int, state: dict) -> tuple[np.ndarray, dict]:
        """Random-access host lookup, re-paging the window when t leaves it."""
        w = self.scan_window
        t0 = int(state["win_t0"])
        if not t0 <= t < t0 + w:
            t0 = (t // w) * w
            state = {**state, "win": self.read_window(t0),
                     "win_t0": np.int32(t0)}
        row = np.asarray(state["win"][t - t0], bool)
        return (np.ones(self.n, bool) if t == 0 else row), state

    # -- theory (post-hoc empirical) --------------------------------------- #
    def _scan_stats(self) -> dict:
        """One streamed pass over the trace: per-device activity counts,
        τ accumulators, the longest dark stretch, and whether any device
        is dark in the final row (=> dark forever under the clamp)."""
        if self._stats_cache is not None:
            return self._stats_cache
        T, n, w = self.trace.n_rounds, self.n, self.scan_window
        counts = np.zeros(n, np.int64)
        tau = np.zeros(n, np.int64)
        tau_sum = 0.0
        longest = 0
        last = np.ones(n, bool)
        for t0 in range(0, T, w):
            rows = self.trace.read_block(t0, min(w, T - t0))
            if t0 == 0:
                rows = rows.copy()
                rows[0] = True               # replay forces round 0 active
            for row in rows:
                counts += row
                tau = np.where(row, 0, tau + 1)
                tau_sum += float(tau.sum())
                longest = max(longest, int(tau.max()))
            last = rows[-1]
        self._stats_cache = {
            "rate": counts / max(T, 1), "mean_tau": tau_sum / max(T * n, 1),
            "longest_gap": longest, "dark_at_end": bool(~last.all())}
        return self._stats_cache

    def stationary_rate(self) -> np.ndarray:
        """(n,) empirical per-device activity rate over the recorded trace."""
        return self._scan_stats()["rate"]

    def tau_bound(self) -> TauBound:
        """Post-hoc empirical classification — a recording has no a-priori
        bound (the arbitrary regime); devices dark in the final row stay
        dark forever under the replay clamp, so t0 = ∞ then."""
        s = self._scan_stats()
        t0 = np.inf if s["dark_at_end"] else float(s["longest_gap"])
        return TauBound(
            deterministic=not s["dark_at_end"], t0=t0,
            expected_tau=s["mean_tau"],
            note="post-hoc empirical from the recorded trace; no a-priori "
                 "bound exists — the arbitrary-unavailability regime")


@register("trace_replay")
def _trace_replay(*, n: int, seed: int = 0, path: str | None = None,
                  horizon: int = 256, rate: float = 0.5, burst: float = 4.0,
                  churn: float = 0.0, window: int = 64,
                  cache_dir: str | None = None) -> TraceReplay:
    """Registry factory: replay `path` if given, else synthesize-and-cache
    a Gilbert–Elliott + churn trace keyed by (n, horizon, seed, rate,
    burst, churn) — the benchmark axis for the non-synthetic regime."""
    if path is None:
        path = cached_trace(n=n, horizon=horizon, seed=seed, rate=rate,
                            burst=burst, churn_frac=churn,
                            cache_dir=cache_dir)
    return TraceReplay(path, n=n, seed=seed, window=window)
