"""Concrete availability processes: correlated, non-stationary, jit-native.

Every process here implements BOTH surfaces of `base.AvailabilityProcess`
(pure-jnp `sample_fn` for in-jit sampling; NumPy `host_step` for `run_fl`/
`sim.engine`) from the SAME per-round uniform draws, so the two surfaces
produce identical masks at a fixed seed. The catalogue covers the regimes
the related work shows break FedAvg-style baselines while MIFA's memory
holds up (docs/scenarios.md maps each to the literature):

  * Bernoulli        — i.i.d. per-device rates (Definition 5.2 / paper §5.1);
                       jit-native port of `core.BernoulliParticipation`.
  * BernoulliDrift   — independent but non-stationary: rates drift linearly,
                       clipped to [lo, hi].
  * GilbertElliott   — per-device two-state Markov chain: correlated
                       availability with tunable burst length (Rodio et al.).
  * ClusterCorrelated— a shared regional-outage Markov chain per cluster
                       gates groups of devices (spatially correlated).
  * Diurnal          — day/night duty cycle: cyclo-stationary sine rates
                       with per-device phase (rolling time zones).
  * StagedBlackout   — piecewise-constant rate schedule that can sharpen
                       mid-run; with {0,1} rates it is fully deterministic.
  * Adversarial      — jit-native port of `core.AdversarialParticipation`
                       (periodic deterministic blackouts; exact same masks).

Layout contract: ALL numeric parameters live in the state pytree returned
by `init_state()` (chain state and constants alike), NOT in the sample
function's closure. That is what lets the fleet executor batch trials with
*different* scenario parameters (an availability grid) under one vmap —
the pure function is shared per scenario type; everything trial-specific
rides the stacked state. `host_step` consumes the NumPy mirror of the same
state (`init_state_host`), so the formulas are written once.

All processes force round 0 all-active (Definition 5.2(1)) and derive round
randomness as fold_in(key, t) — masks depend on (seed, t) only.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.scenarios.base import AvailabilityProcess, TauBound


def _per_device(x, n: int, lo: float = 0.0, hi: float = 1.0) -> np.ndarray:
    out = np.broadcast_to(np.asarray(x, np.float32), (n,)).copy()
    assert np.all((out >= lo) & (out <= hi)), (x, lo, hi)
    return out


def _geometric_expected_tau(rate: np.ndarray) -> float:
    """Stationary E[τ] averaged over devices for i.i.d. Bernoulli(rate):
    P(τ=k) = p(1−p)^k  =>  E[τ] = (1−p)/p."""
    p = np.asarray(rate, np.float64)
    return float(np.mean((1.0 - p) / np.maximum(p, 1e-12)))


class _ThresholdProcess(AvailabilityProcess):
    """Memoryless family: active iff u_t(i) < p_i(t).

    Subclasses implement `probs_at(t, state, xp)` with `xp` = numpy or
    jax.numpy — ONE formula serves both surfaces, reading its parameters
    from the (jnp or NumPy-mirror) state, in float32 on both sides so the
    threshold comparison agrees bit-for-bit.
    """

    stateless = True

    def probs_at(self, t, state, xp):
        """(n,) activity probabilities at round t (xp = np | jnp, f32)."""
        raise NotImplementedError

    def sample_fn(self) -> Callable:
        n = self.n
        probs_at = self.probs_at

        def sample(key, t, state):
            u = jax.random.uniform(jax.random.fold_in(key, t), (n,))
            mask = u < probs_at(t, state, jnp)
            mask = jnp.where(t == 0, jnp.ones_like(mask), mask)
            return mask, state

        return sample

    def host_step(self, t: int, state: dict) -> tuple[np.ndarray, dict]:
        if t == 0:
            return np.ones(self.n, bool), state
        u = self.uniforms(t, (self.n,))
        return u < self.probs_at(t, state, np), state


class Bernoulli(_ThresholdProcess):
    """i.i.d. Bernoulli activity with per-device rates (Definition 5.2).

    The jit-native counterpart of `core.BernoulliParticipation` (the legacy
    class keeps its NumPy RNG stream; this one draws from fold_in(key, t)).
    """

    def __init__(self, probs, n: int | None = None, seed: int = 0):
        self.n = n if n is not None else len(np.atleast_1d(probs))
        self.seed = seed
        self.probs = _per_device(probs, self.n)

    def init_state(self) -> dict:
        return {"probs": jnp.asarray(self.probs)}

    def probs_at(self, t, state, xp):
        return state["probs"]

    def stationary_rate(self) -> np.ndarray:
        return self.probs.astype(np.float64)

    def tau_bound(self) -> TauBound:
        if np.all(self.probs >= 1.0):
            return TauBound(True, 0.0, 0.0, "always active")
        return TauBound(False, np.inf,
                        _geometric_expected_tau(self.probs),
                        "geometric off-times: bounded only in probability")


class BernoulliDrift(_ThresholdProcess):
    """Independent but non-stationary: p_i(t) = clip(p0_i + drift_i·t, lo, hi).

    Models fleets whose participation erodes (negative drift: battery
    attrition, churn) or ramps (positive drift: staged rollout) over
    training. `stationary_rate` reports the limiting rate the clip pins
    each device to.
    """

    def __init__(self, p0, drift, lo: float = 0.05, hi: float = 1.0,
                 n: int | None = None, seed: int = 0):
        self.n = n if n is not None else len(np.atleast_1d(p0))
        self.seed = seed
        self.p0 = _per_device(p0, self.n)
        self.drift = np.broadcast_to(
            np.asarray(drift, np.float32), (self.n,)).copy()
        self.lo = np.float32(lo)
        self.hi = np.float32(hi)

    def init_state(self) -> dict:
        return {"p0": jnp.asarray(self.p0), "drift": jnp.asarray(self.drift),
                "lo": jnp.float32(self.lo), "hi": jnp.float32(self.hi)}

    def probs_at(self, t, state, xp):
        t32 = xp.asarray(t, xp.float32)
        return xp.clip(state["p0"] + state["drift"] * t32,
                       state["lo"], state["hi"])

    def stationary_rate(self) -> np.ndarray:
        limit = np.where(self.drift > 0, self.hi,
                         np.where(self.drift < 0, self.lo, self.p0))
        return limit.astype(np.float64)

    def tau_bound(self) -> TauBound:
        return TauBound(False, np.inf,
                        _geometric_expected_tau(self.stationary_rate()),
                        "limiting-rate geometric tail (non-stationary "
                        "transient ignored)")


class Diurnal(_ThresholdProcess):
    """Day/night duty cycle: p_i(t) = clip(base_i + amp_i·sin(2πt/period
    + phase_i), 0, 1) — cyclo-stationary, per-device phases model rolling
    time zones. The regime of "Federated Learning under Heterogeneous and
    Correlated Client Availability": availability correlated in time and
    across the devices sharing a phase.

    `period` is rounded to whole rounds and the probability table for one
    period is PRECOMPUTED on the host at construction; both surfaces index
    it by t mod period. Evaluating sin at sample time would let libm and
    XLA disagree by an ulp and (rarely) flip a threshold comparison —
    table lookup keeps the two surfaces bit-identical by construction.
    """

    def __init__(self, base, amplitude, period: float, phase=0.0,
                 n: int | None = None, seed: int = 0):
        self.n = n if n is not None else len(np.atleast_1d(base))
        self.seed = seed
        self.base = _per_device(base, self.n)
        self.amplitude = _per_device(amplitude, self.n)
        self.period = max(int(round(float(period))), 1)
        self.phase = np.broadcast_to(
            np.asarray(phase, np.float32), (self.n,)).copy()
        ts = np.arange(self.period, dtype=np.float32)[:, None]
        ang = np.float32(2.0 * np.pi / self.period) * ts + self.phase[None]
        self.table = np.clip(self.base[None]
                             + self.amplitude[None] * np.sin(ang),
                             0.0, 1.0).astype(np.float32)   # (P, n)

    def init_state(self) -> dict:
        return {"table": jnp.asarray(self.table)}

    def probs_at(self, t, state, xp):
        # table length is static per scenario type+period (like
        # StagedBlackout's stage count), so int mod is exact on both sides
        return state["table"][xp.asarray(t, xp.int32)
                              % state["table"].shape[0]]

    def stationary_rate(self) -> np.ndarray:
        """Exact time-average of p_i(t) over one period."""
        return self.table.mean(0).astype(np.float64)

    def tau_bound(self) -> TauBound:
        return TauBound(False, np.inf, np.nan,
                        "cyclo-stationary Bernoulli: no a.s. bound, no "
                        "closed-form E[τ]; estimate empirically")


class StagedBlackout(_ThresholdProcess):
    """Piecewise-constant rate schedule: stage s covers rounds
    [bounds[s-1], bounds[s]) and applies rates stage_probs[s] (S, n);
    the final stage persists forever. Rates in {0, 1} give deterministic
    staged blackouts (the "sharpening mid-run" regime of "Efficient
    Federated Learning against Heterogeneous and Non-stationary Client
    Unavailability"); fractional rates give a non-stationary mixture.
    """

    def __init__(self, stage_probs, bounds, n: int | None = None,
                 seed: int = 0):
        probs = np.asarray(stage_probs, np.float32)
        assert probs.ndim == 2, "stage_probs must be (n_stages, n)"
        self.n = n if n is not None else probs.shape[1]
        self.seed = seed
        self.stage_probs = np.stack(
            [_per_device(row, self.n) for row in probs])
        self.bounds = np.asarray(bounds, np.int32)
        assert len(self.bounds) == len(self.stage_probs) - 1
        assert np.all(np.diff(self.bounds) > 0) and np.all(self.bounds > 0)

    def init_state(self) -> dict:
        return {"stage_probs": jnp.asarray(self.stage_probs),
                "bounds": jnp.asarray(self.bounds)}

    def probs_at(self, t, state, xp):
        idx = xp.searchsorted(state["bounds"], xp.asarray(t, xp.int32),
                              side="right")
        return state["stage_probs"][idx]

    def stationary_rate(self) -> np.ndarray:
        """The persistent regime: the final stage's rates."""
        return self.stage_probs[-1].astype(np.float64)

    def tau_bound(self) -> TauBound:
        binary = np.all((self.stage_probs == 0) | (self.stage_probs == 1))
        if binary and np.all(self.stage_probs[-1] == 1):
            # deterministic: longest dark stretch over the finite schedule
            horizon = int(self.bounds[-1]) + 1
            state = self.init_state_host()
            masks = np.stack([self.probs_at(t, state, np) >= 1.0
                              for t in range(horizon)])
            masks[0] = True                      # round-0 convention
            t0 = _longest_dark_run(masks)
            return TauBound(True, float(t0), np.nan,
                            "deterministic schedule, final stage all-on")
        if np.any(self.stage_probs[-1] == 0):
            return TauBound(False, np.inf, np.inf,
                            "final stage darkens some device forever: "
                            "Assumption 4 fails, τ grows linearly")
        return TauBound(False, np.inf,
                        _geometric_expected_tau(self.stage_probs[-1]),
                        "stochastic stages: geometric tail in the final "
                        "regime")


def _longest_dark_run(masks: np.ndarray) -> int:
    """(T, n) bool -> the longest consecutive all-False run in any column."""
    dark = ~masks
    best = run = np.zeros(masks.shape[1], np.int64)
    for row in dark:
        run = np.where(row, run + 1, 0)
        best = np.maximum(best, run)
    return int(best.max(initial=0))


class GilbertElliott(AvailabilityProcess):
    """Per-device two-state Markov chain (Gilbert–Elliott): an active device
    fails with prob `p_fail` per round; an inactive one recovers with prob
    `p_recover`. Off-times are Geometric(p_recover) — expected burst length
    1/p_recover — so availability is *temporally correlated* with tunable
    burst length: the regime where i.i.d.-assuming baselines (FedAvg-IS)
    break and MIFA's memory pays off.

    Stationary activity rate: π_up = p_recover / (p_fail + p_recover).
    Stationary E[τ] has the closed form  p_fail / (p_recover·(p_fail +
    p_recover))  (pinned in tests/test_scenarios.py).
    """

    stateless = False

    def __init__(self, p_fail, p_recover, n: int | None = None,
                 seed: int = 0):
        self.n = n if n is not None else len(np.atleast_1d(p_fail))
        self.seed = seed
        self.p_fail = _per_device(p_fail, self.n, lo=0.0, hi=1.0)
        self.p_recover = _per_device(p_recover, self.n, lo=1e-6, hi=1.0)

    @classmethod
    def from_rate_and_burst(cls, rate, burst, n: int, seed: int = 0):
        """Parametrise by stationary activity `rate` and expected off-burst
        length `burst` (rounds): p_recover = 1/burst, p_fail solved from
        rate = p_recover/(p_fail + p_recover).

        Raises when the pair is infeasible (p_fail would exceed 1, i.e.
        burst < (1−rate)/rate) — clipping silently would deliver a
        different activity rate than the caller calibrated for."""
        rate = _per_device(rate, n, lo=1e-6, hi=1.0)
        burst = np.broadcast_to(
            np.asarray(burst, np.float32), (n,)).astype(np.float64)
        if np.any(burst < 1.0):
            raise ValueError(f"burst must be >= 1 round, got {burst.min()}")
        p_rec = 1.0 / burst
        p_fail = p_rec * (1.0 - rate) / np.maximum(rate, 1e-6)
        if np.any(p_fail > 1.0):
            bad = float(p_fail.max())
            raise ValueError(
                f"(rate, burst) infeasible: implied p_fail={bad:.3f} > 1 — "
                "need burst >= (1-rate)/rate so the on-times stay long "
                "enough to average `rate` activity")
        return cls(p_fail, p_rec, n=n, seed=seed)

    def init_state(self) -> dict:
        return {"up": jnp.ones((self.n,), bool),
                "p_fail": jnp.asarray(self.p_fail),
                "p_recover": jnp.asarray(self.p_recover)}

    def sample_fn(self) -> Callable:
        n = self.n

        def sample(key, t, state):
            u = jax.random.uniform(jax.random.fold_in(key, t), (n,))
            trans = jnp.where(state["up"], u >= state["p_fail"],
                              u < state["p_recover"])
            up = jnp.where(t == 0, jnp.ones_like(trans), trans)
            return up, {**state, "up": up}

        return sample

    def host_step(self, t: int, state: dict) -> tuple[np.ndarray, dict]:
        u = self.uniforms(t, (self.n,))
        trans = np.where(state["up"], u >= state["p_fail"],
                         u < state["p_recover"])
        up = np.ones(self.n, bool) if t == 0 else trans.astype(bool)
        return up, {**state, "up": up}

    def stationary_rate(self) -> np.ndarray:
        pf = self.p_fail.astype(np.float64)
        pr = self.p_recover.astype(np.float64)
        return pr / np.maximum(pf + pr, 1e-12)

    def expected_tau(self) -> float:
        """Closed-form stationary E[τ] averaged over devices:
        P(τ=k) = π_up·p_f·(1−p_r)^(k−1) for k>=1  =>
        E[τ] = π_up·p_f/p_r² = p_f / (p_r·(p_f + p_r))."""
        pf = self.p_fail.astype(np.float64)
        pr = self.p_recover.astype(np.float64)
        return float(np.mean(pf / np.maximum(pr * (pf + pr), 1e-12)))

    def tau_bound(self) -> TauBound:
        if np.all(self.p_fail == 0):
            return TauBound(True, 0.0, 0.0, "never fails")
        return TauBound(False, np.inf, self.expected_tau(),
                        "Geometric(p_recover) off-bursts: unbounded support")


class ClusterCorrelated(AvailabilityProcess):
    """Cluster-correlated availability: devices are partitioned into
    clusters (regions / carriers / time zones) and a SHARED two-state
    outage chain gates each cluster — cluster c fails with `q_fail[c]` per
    round and recovers with `q_recover[c]`. A device is active iff its
    cluster is up AND its own i.i.d. Bernoulli(p_device) draw succeeds.

    Availability is correlated ACROSS devices (a regional outage silences a
    whole cluster at once), the case Rodio et al. show biases
    active-cohort averaging hardest; MIFA replays the silenced cluster's
    remembered updates.
    """

    stateless = False

    def __init__(self, n: int, n_clusters: int, q_fail, q_recover,
                 p_device=1.0, assignment=None, seed: int = 0):
        self.n = n
        self.seed = seed
        self.n_clusters = int(n_clusters)
        self.q_fail = _per_device(q_fail, self.n_clusters)
        self.q_recover = _per_device(q_recover, self.n_clusters, lo=1e-6)
        self.p_device = _per_device(p_device, n)
        self.assignment = (np.arange(n) % self.n_clusters
                           if assignment is None
                           else np.asarray(assignment, np.int32))
        assert self.assignment.shape == (n,)
        assert self.assignment.max(initial=0) < self.n_clusters

    def init_state(self) -> dict:
        return {"cl_up": jnp.ones((self.n_clusters,), bool),
                "q_fail": jnp.asarray(self.q_fail),
                "q_recover": jnp.asarray(self.q_recover),
                "p_device": jnp.asarray(self.p_device),
                "assignment": jnp.asarray(self.assignment)}

    def sample_fn(self) -> Callable:
        n, m = self.n, self.n_clusters

        def sample(key, t, state):
            u = jax.random.uniform(jax.random.fold_in(key, t), (m + n,))
            u_cl, u_dev = u[:m], u[m:]
            trans = jnp.where(state["cl_up"], u_cl >= state["q_fail"],
                              u_cl < state["q_recover"])
            cl_up = jnp.where(t == 0, jnp.ones_like(trans), trans)
            mask = cl_up[state["assignment"]] & (u_dev < state["p_device"])
            mask = jnp.where(t == 0, jnp.ones_like(mask), mask)
            return mask, {**state, "cl_up": cl_up}

        return sample

    def host_step(self, t: int, state: dict) -> tuple[np.ndarray, dict]:
        m = self.n_clusters
        u = self.uniforms(t, (m + self.n,))
        u_cl, u_dev = u[:m], u[m:]
        trans = np.where(state["cl_up"], u_cl >= state["q_fail"],
                         u_cl < state["q_recover"])
        cl_up = (np.ones(m, bool) if t == 0 else trans.astype(bool))
        new = {**state, "cl_up": cl_up}
        if t == 0:
            return np.ones(self.n, bool), new
        mask = cl_up[state["assignment"]] & (u_dev < state["p_device"])
        return mask, new

    def stationary_rate(self) -> np.ndarray:
        qf = self.q_fail.astype(np.float64)
        qr = self.q_recover.astype(np.float64)
        pi_up = qr / np.maximum(qf + qr, 1e-12)
        return pi_up[self.assignment] * self.p_device.astype(np.float64)

    def tau_bound(self) -> TauBound:
        return TauBound(False, np.inf, np.nan,
                        "cluster outage × device Bernoulli: alternating "
                        "renewal, no closed-form E[τ]")


class Adversarial(_ThresholdProcess):
    """jit-native port of `core.AdversarialParticipation`: device i is dark
    for the first `offs[i]` slots of every `periods[i]`-round cycle (with
    per-device `phases`). Deterministic — both surfaces reproduce the
    legacy class's masks EXACTLY, and Assumption 4 holds with t0 =
    max(offs) (pinned in tests/test_participation.py).
    """

    stateless = True

    def __init__(self, periods, offs, phases=None, n: int | None = None,
                 seed: int = 0):
        self.n = n if n is not None else len(np.atleast_1d(periods))
        self.seed = seed
        self.periods = np.broadcast_to(
            np.asarray(periods, np.int32), (self.n,)).copy()
        self.offs = np.broadcast_to(
            np.asarray(offs, np.int32), (self.n,)).copy()
        self.phases = (np.zeros(self.n, np.int32) if phases is None
                       else np.broadcast_to(
                           np.asarray(phases, np.int32), (self.n,)).copy())
        assert np.all(self.offs < self.periods)

    def init_state(self) -> dict:
        return {"periods": jnp.asarray(self.periods),
                "offs": jnp.asarray(self.offs),
                "phases": jnp.asarray(self.phases)}

    def probs_at(self, t, state, xp):
        # deterministic: probability is the {0,1} indicator of the pattern
        ph = (xp.asarray(t, xp.int32) + state["phases"]) % state["periods"]
        return (ph >= state["offs"]).astype(xp.float32)

    def stationary_rate(self) -> np.ndarray:
        return 1.0 - self.offs.astype(np.float64) / self.periods

    def tau_bound(self) -> TauBound:
        offs = self.offs.astype(np.float64)
        exp_tau = float(np.mean(offs * (offs + 1) / (2.0 * self.periods)))
        return TauBound(True, float(self.offs.max(initial=0)), exp_tau,
                        "periodic blackouts: τ <= max(offs) surely")
