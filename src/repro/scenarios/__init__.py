"""Scenario subsystem: correlated, non-stationary, jit-native availability.

A `Scenario` composes an availability process with a latency model; every
process exposes a host (NumPy, for `run_fl`/`sim.engine`) and a jit-native
(pure ``(key, t, state) -> (mask, state)``, for `run_fl` and the fleet
executor) sampling surface drawing identical masks at a fixed seed. See
docs/scenarios.md for the taxonomy and theory mapping.
"""
from repro.scenarios.base import (AvailabilityProcess, HostSampler,  # noqa: F401
                                  Scenario, TauBound, as_process)
from repro.scenarios.processes import (Adversarial, Bernoulli,  # noqa: F401
                                       BernoulliDrift, ClusterCorrelated,
                                       Diurnal, GilbertElliott,
                                       StagedBlackout)
from repro.scenarios.registry import (make_process, make_scenario,  # noqa: F401
                                      register, scenario_names)
from repro.scenarios.trace_replay import (TraceFile, TraceReplay,  # noqa: F401
                                          cached_trace, open_trace,
                                          synthesize_trace, write_trace)
from repro.scenarios.elastic import (ElasticProcess,  # noqa: F401
                                     elastic_capacity, staged_arrivals)
