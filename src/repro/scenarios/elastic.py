"""Elastic fleets: clients that arrive and depart mid-run.

MIFA's state is one memory row per client, and every compiled engine in
this repo (scan carry, fleet vmap, banked cohorts) has ONE static client
axis — a fleet that literally grows would retrace and reallocate on every
arrival. `ElasticProcess` models membership churn the same way the banks
model variable cohorts: a *capacity-padded client axis*. Size the run for
the peak fleet (`elastic_capacity` rounds up to a pow-2 growth bucket,
the bank's padding idiom), and fold membership into availability:

    active(t, i) = inner_mask(t, i) AND join_i <= t < leave_i

Un-arrived and departed clients are plain inactive devices — `MemoryBank`
rows that stay zero until first participation, `TauStats` entries whose τ
grows, scan-carry rows that never change shape. No algorithm changes, no
retracing per arrival. The modelling consequence is the honest one: MIFA
averages its memory over the capacity N, so a client that has not arrived
yet contributes its zero-init row to mean_G — exactly the paper's
treatment of a device unseen since round 0 (the init convention behind
TauStats strict=False). Departures make availability *arbitrary* in the
paper's sense: a departed device has unbounded τ, so Assumption 4 fails
— the regime where MIFA's guarantees are the interesting ones.

Round-0 convention: the inner process forces round 0 all-active, but
elasticity ANDs in presence, so round 0 is "every *present* client" —
a documented deviation from Definition 5.2(1) that the runners already
accommodate (they construct `TauStats(strict=False)`; absent clients
count τ from the virtual round −1).

Composes over any registered inner process, including `trace_replay` —
the window protocol (docs: `scenarios.trace_replay.TraceReplay`) is
forwarded to the inner process, so elastic trace replay streams windows
exactly like the bare process.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.scenarios.base import AvailabilityProcess, TauBound
from repro.scenarios.registry import make_process, register

#: `leave` sentinel meaning "never departs" (any round beyond reach).
NEVER = 1 << 30


def elastic_capacity(peak_clients: int) -> int:
    """Pow-2 growth bucket for an elastic run's client capacity.

    Size the static client axis to `elastic_capacity(peak)` so arrivals
    up to the peak never outgrow the allocated rows — the same pow-2
    bucketing the cohort path uses for pad widths.
    """
    from repro.core.runner import _pow2_bucket
    return _pow2_bucket(peak_clients)


def staged_arrivals(n: int, *, n_initial: int, arrive_every: int = 16,
                    arrive_count: int | None = None) -> np.ndarray:
    """(n,) join rounds: `n_initial` clients at round 0, then batches of
    `arrive_count` (default: the remainder over 4 waves) every
    `arrive_every` rounds until the capacity is full."""
    if not 0 < n_initial <= n:
        raise ValueError(f"n_initial must be in (0, {n}], got {n_initial}")
    extras = n - n_initial
    if arrive_count is None:
        arrive_count = max(-(-extras // 4), 1)
    join = np.zeros(n, np.int64)
    for i in range(extras):
        join[n_initial + i] = arrive_every * (1 + i // arrive_count)
    return join


class ElasticProcess(AvailabilityProcess):
    """Membership churn folded into any inner availability process.

    State is ``{"inner": <inner state>, "join": (n,) int32, "leave": (n,)
    int32}`` — the join/leave schedules ride the jit state (not the
    closure) so fleet trials can carry different schedules. `n` is the
    CAPACITY (peak fleet size, see `elastic_capacity`); `leave` uses the
    `NEVER` sentinel for clients that stay.
    """

    # round 0 activates every PRESENT client, not every slot (module
    # docstring: the documented Definition 5.2(1) deviation)
    round0_all_active = False

    def __init__(self, inner: AvailabilityProcess,
                 join: np.ndarray | None = None,
                 leave: np.ndarray | None = None):
        self.inner = inner
        self.n = inner.n
        self.seed = inner.seed
        self.stateless = inner.stateless
        self.join = (np.zeros(self.n, np.int64) if join is None
                     else np.asarray(join, np.int64))
        self.leave = (np.full(self.n, NEVER, np.int64) if leave is None
                      else np.asarray(leave, np.int64))
        if self.join.shape != (self.n,) or self.leave.shape != (self.n,):
            raise ValueError(
                f"join/leave must be ({self.n},) round arrays, got "
                f"{self.join.shape} / {self.leave.shape}")

    # -- window protocol (forwarded to the inner process) ------------------ #
    @property
    def scan_window(self):
        """Inner process's carried-window length; None when the inner
        process has no streaming window (fully in-carry state)."""
        return getattr(self.inner, "scan_window", None)

    def load_window(self, state: dict, t0: int) -> dict:
        """Re-point the inner process's carried window at [t0, t0+W)."""
        return {**state, "inner": self.inner.load_window(state["inner"], t0)}

    def load_window_fleet(self, state: dict, procs, t0: int) -> dict:
        """Stacked-trial `load_window` over the trials' inner processes."""
        return {**state, "inner": self.inner.load_window_fleet(
            state["inner"], [p.inner for p in procs], t0)}

    # -- jit surface ------------------------------------------------------- #
    def init_state(self) -> dict:
        """Inner state plus the (n,) join/leave schedules as jnp leaves."""
        return {"inner": self.inner.init_state(),
                "join": jnp.asarray(self.join, jnp.int32),
                "leave": jnp.asarray(self.leave, jnp.int32)}

    def sample_fn(self) -> Callable:
        """Inner mask ANDed with presence; round 0 is every PRESENT client
        (τ for the rest counts from the virtual round −1)."""
        inner_fn = self.inner.sample_fn()

        def sample(key, t, state):
            mask, inner_state = inner_fn(key, t, state["inner"])
            present = (state["join"] <= t) & (t < state["leave"])
            return mask & present, {**state, "inner": inner_state}

        return sample

    # -- host surface ------------------------------------------------------ #
    def host_step(self, t: int, state: dict) -> tuple[np.ndarray, dict]:
        """NumPy mirror: inner host step ANDed with the same presence."""
        mask, inner_state = self.inner.host_step(t, state["inner"])
        present = (state["join"] <= t) & (t < state["leave"])
        return (np.asarray(mask, bool) & np.asarray(present, bool),
                {**state, "inner": inner_state})

    # -- theory ------------------------------------------------------------ #
    def stationary_rate(self) -> np.ndarray:
        """(n,) long-run rate: the inner rate for clients that eventually
        join and never leave, 0 for everyone else (departed / never-joined
        clients are dark in the long run)."""
        stays = (self.join < NEVER) & (self.leave >= NEVER)
        return np.where(stays, self.inner.stationary_rate(), 0.0)

    def tau_bound(self) -> TauBound:
        """Departures (or never-joining clients) break Assumption 4
        outright — τ of a departed device grows without bound. A purely
        growing fleet keeps the inner bound shifted by the last arrival."""
        inner_b = self.inner.tau_bound()
        if np.any(self.leave < NEVER) or np.any(self.join >= NEVER):
            return TauBound(
                deterministic=False, t0=np.inf, expected_tau=np.nan,
                note="departed clients never return: τ is unbounded on "
                     "every sample path (arbitrary-unavailability regime)")
        return TauBound(
            deterministic=inner_b.deterministic,
            t0=inner_b.t0 + float(self.join.max()),
            expected_tau=np.nan,
            note=f"growing fleet: inner bound ({inner_b.note or 'see inner'})"
                 " shifted by the last arrival round")


@register("elastic")
def _elastic(*, n: int, seed: int = 0, inner: str = "bernoulli",
             inner_kwargs: dict | None = None, join=None, leave=None,
             n_initial: int | None = None, arrive_every: int = 16,
             arrive_count: int | None = None, depart_frac: float = 0.0,
             depart_at: int | None = None) -> ElasticProcess:
    """Registry factory. `n` is the CAPACITY; the inner process is built
    at that size via the registry (`inner` + `inner_kwargs`). Default
    schedule: half the capacity present at round 0, the rest arriving in
    waves every `arrive_every` rounds (`staged_arrivals`); `depart_frac`
    of the capacity (the lowest client ids) leaves for good at
    `depart_at` (default ``2 * arrive_every``). Pass explicit `join` /
    `leave` (n,) round arrays to override."""
    proc = make_process(inner, n=n, seed=seed, **(inner_kwargs or {}))
    if join is None:
        n_init = n_initial if n_initial is not None else max(n // 2, 1)
        join = staged_arrivals(n, n_initial=n_init,
                               arrive_every=arrive_every,
                               arrive_count=arrive_count)
    if leave is None:
        leave = np.full(n, NEVER, np.int64)
        k = int(n * depart_frac)
        if k:
            leave[:k] = depart_at if depart_at is not None \
                else 2 * arrive_every
    return ElasticProcess(proc, join=join, leave=leave)
