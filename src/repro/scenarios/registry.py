"""Scenario registry: build named (process, latency) environments by string.

Benchmarks and sweep grids refer to scenarios by registry name + kwargs, so
"as many scenarios as you can imagine" is a data problem, not a code change:

    scen = make_scenario("gilbert_elliott", n=100, seed=3,
                         rate=0.5, burst=8.0)
    run_fl(model=model, algo=algo, scenario=scen, ...)          # in-jit
    FedSimEngine(runner, policy, *scen.sim_inputs())            # simulator

Third parties register their own with `register` (decorator or call).
"""
from __future__ import annotations

from typing import Any, Callable

import numpy as np

from repro.scenarios.base import AvailabilityProcess, Scenario
from repro.scenarios import processes as P

_REGISTRY: dict[str, Callable[..., AvailabilityProcess]] = {}


def register(name: str, factory: Callable | None = None):
    """Register `factory(n=..., seed=..., **kw) -> AvailabilityProcess`
    under `name`. Usable as a decorator (`@register("my_scenario")`) or a
    plain call; returns the factory."""
    def _do(f: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = f
        return f
    return _do(factory) if factory is not None else _do


def scenario_names() -> list[str]:
    """Registered scenario names, sorted."""
    return sorted(_REGISTRY)


def make_process(name: str, *, n: int, seed: int = 0,
                 **kwargs) -> AvailabilityProcess:
    """Build the bare availability process for `name` (see `make_scenario`)."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {scenario_names()}")
    return _REGISTRY[name](n=n, seed=seed, **kwargs)


def make_scenario(name: str, *, n: int, seed: int = 0, latency: Any = None,
                  **kwargs) -> Scenario:
    """Build a named `Scenario` from the registry.

    Args:
      name: registry key (see `scenario_names()`).
      n: device count.
      seed: base PRNG seed; both sampling surfaces derive all randomness
        from it, so (name, kwargs, seed) pins every mask.
      latency: optional `repro.sim.latency` model for simulator runs.
      **kwargs: forwarded to the scenario factory (rates, burst lengths,
        cluster counts, schedules, ...).

    Returns:
      `Scenario` with `.process`, `.latency`, and a reproducible `.name`
      tag (`name/k1=v1,k2=v2/seed<seed>`).
    """
    proc = make_process(name, n=n, seed=seed, **kwargs)
    tag = ",".join(f"{k}={_short(v)}" for k, v in sorted(kwargs.items()))
    full = name + (f"/{tag}" if tag else "") + f"/seed{seed}"
    return Scenario(process=proc, latency=latency, name=full)


def _short(v) -> str:
    if isinstance(v, (list, tuple, np.ndarray)):
        a = np.asarray(v)
        return f"arr{a.shape}"
    return str(v)


# --------------------------------------------------------------------------- #
# built-ins
# --------------------------------------------------------------------------- #

@register("bernoulli")
def _bernoulli(*, n: int, seed: int = 0, probs=0.5) -> P.Bernoulli:
    return P.Bernoulli(probs, n=n, seed=seed)


@register("bernoulli_drift")
def _bernoulli_drift(*, n: int, seed: int = 0, p0=0.8, drift=-0.004,
                     lo: float = 0.05, hi: float = 1.0) -> P.BernoulliDrift:
    return P.BernoulliDrift(p0, drift, lo=lo, hi=hi, n=n, seed=seed)


@register("gilbert_elliott")
def _gilbert_elliott(*, n: int, seed: int = 0, rate=0.5,
                     burst=4.0) -> P.GilbertElliott:
    return P.GilbertElliott.from_rate_and_burst(rate, burst, n=n, seed=seed)


@register("cluster")
def _cluster(*, n: int, seed: int = 0, n_clusters: int = 4, q_fail=0.05,
             q_recover=0.25, p_device=0.9, assignment=None,
             contiguous: bool = True) -> P.ClusterCorrelated:
    """`contiguous` (default) assigns clients to clusters in blocks, so a
    regional outage silences a contiguous id range — aligned with
    label-skew partitions, the data-correlated case that biases FedAvg."""
    if assignment is None and contiguous:
        assignment = (np.arange(n) * n_clusters) // max(n, 1)
    return P.ClusterCorrelated(n, n_clusters, q_fail, q_recover,
                               p_device=p_device, assignment=assignment,
                               seed=seed)


@register("diurnal")
def _diurnal(*, n: int, seed: int = 0, base=0.55, amplitude=0.45,
             period: float = 24.0, spread_phases: bool = True,
             phase=None) -> P.Diurnal:
    if phase is None:
        phase = (np.linspace(0.0, 2.0 * np.pi, n, endpoint=False)
                 if spread_phases else 0.0)
    return P.Diurnal(base, amplitude, period, phase=phase, n=n, seed=seed)


@register("staged_blackout")
def _staged_blackout(*, n: int, seed: int = 0, stage_probs=None,
                     bounds=None, dark_frac: float = 0.5,
                     stage_len: int = 20) -> P.StagedBlackout:
    """Default schedule: full activity, then a growing fraction of the
    fleet (up to `dark_frac`) hard-blacked-out in stages that sharpen —
    the final stage restores everyone (so Assumption 4 holds)."""
    if stage_probs is None:
        n_dark = int(n * dark_frac)
        s0 = np.ones(n)
        s1, s2 = np.ones(n), np.ones(n)
        s1[:n_dark // 2] = 0.0          # first wave of the outage
        s2[:n_dark] = 0.0               # sharpened: the full dark set
        s3 = np.ones(n)                 # recovery
        stage_probs = np.stack([s0, s1, s2, s3])
        bounds = np.array([stage_len, 2 * stage_len, 3 * stage_len])
    return P.StagedBlackout(stage_probs, bounds, n=n, seed=seed)


@register("adversarial")
def _adversarial(*, n: int, seed: int = 0, periods=8, offs=3,
                 phases=None) -> P.Adversarial:
    return P.Adversarial(periods, offs, phases=phases, n=n, seed=seed)
