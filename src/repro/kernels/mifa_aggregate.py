"""Fused MIFA aggregation Pallas kernel.

The aggregation  G ← where(active, U, G);  w ← w − η·mean(G, axis=0)  is purely
memory-bound: naively it reads G and U, writes G, re-reads G for the mean, and
writes w — 4·N·M + 2·M element moves. The fused kernel streams each (N, TM)
column tile through VMEM ONCE: select, accumulate the client mean, and update
the weight tile in a single pass — 2·N·M + 2·M moves, ~2x less HBM traffic on
the dominant term (the roofline win for the memory-bound MIFA server step).

Grid: one program per column tile of M (model dimension, flattened). The client
axis N stays whole inside the tile (N ≤ a few hundred; N·TM·4B ≤ VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _kernel(active_ref, eta_ref, g_ref, u_ref, w_ref, g_out_ref, w_out_ref):
    act = active_ref[...] > 0.5                     # (N, 1)
    g = jnp.where(act, u_ref[...].astype(g_ref.dtype), g_ref[...])
    g_out_ref[...] = g
    mean_g = jnp.mean(g.astype(jnp.float32), axis=0)  # (TM,)
    eta = eta_ref[0]
    w_out_ref[...] = (w_ref[...].astype(jnp.float32)
                      - eta * mean_g).astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _mifa_aggregate(g_old: jnp.ndarray, updates: jnp.ndarray,
                    active: jnp.ndarray, w: jnp.ndarray, eta,
                    *, block_m: int, interpret: bool):
    n, m = g_old.shape
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)

    act2 = active.astype(jnp.float32).reshape(n, 1)
    eta_arr = jnp.asarray([eta], jnp.float32)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # active, whole
            pl.BlockSpec(memory_space=pl.ANY),            # eta scalar
            pl.BlockSpec((n, bm), lambda i: (0, i)),      # G tile
            pl.BlockSpec((n, bm), lambda i: (0, i)),      # U tile
            pl.BlockSpec((bm,), lambda i: (i,)),          # w tile
        ],
        out_specs=[
            pl.BlockSpec((n, bm), lambda i: (0, i)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), g_old.dtype),
            jax.ShapeDtypeStruct((m,), w.dtype),
        ],
        interpret=interpret,
    )(act2, eta_arr, g_old, updates, w)


def mifa_aggregate(g_old: jnp.ndarray, updates: jnp.ndarray,
                   active: jnp.ndarray, w: jnp.ndarray, eta,
                   *, block_m: int = 512, interpret: bool | None = None):
    """g_old,updates (N,M); active (N,); w (M,); eta scalar.

    Returns (g_new (N,M) [g_old.dtype], w_new (M,) [w.dtype]).
    M must be padded to a multiple of block_m by the caller (ops.py does).
    interpret=None auto-detects: interpret on CPU, compiled otherwise.
    """
    return _mifa_aggregate(g_old, updates, active, w, eta, block_m=block_m,
                           interpret=resolve_interpret(interpret))
