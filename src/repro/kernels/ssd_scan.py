"""Mamba2 SSD chunked-scan Pallas kernel.

Grid (B, H, n_chunks), chunk axis innermost/sequential; the running SSM state
(P x N, f32) lives in VMEM scratch across chunk steps — the HBM traffic is one
read of (x, dA, B, C) and one write of y per token, with the O(Q^2) intra-chunk
attention-like matmuls (MXU work) kept entirely in VMEM. This is the TPU
re-blocking of the paper's SSD algorithm (GPU version uses one kernel per
matmul + a separate state pass; on TPU a single fused kernel avoids 3 HBM
round-trips of the chunk intermediates).

Single SSM group (G=1): B and C are shared across heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _kernel(x_ref, da_ref, b_ref, c_ref, y_ref, h_out_ref, h_scr, *,
            nc: int, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)         # (Q, P)
    da = da_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    B = b_ref[0].astype(jnp.float32)                  # (Q, N)
    C = c_ref[0].astype(jnp.float32)                  # (Q, N)

    cum = jnp.cumsum(da)                              # (Q,)
    # L[i,j] = exp(cum[i] - cum[j]) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    att = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * L  # (Q,Q)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)        # (Q,P)

    # contribution of the carried state: y += exp(cum) * (C @ h^T)
    h = h_scr[...]                                    # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h_new = h * exp(cum[-1]) + x^T @ (B * exp(cum[-1]-cum))
    decay = jnp.exp(cum[q - 1] - cum)                 # (Q,)
    bw = B * decay[:, None]                           # (Q, N)
    h_scr[...] = h * jnp.exp(cum[q - 1]) + jax.lax.dot_general(
        x, bw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        h_out_ref[0, 0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan(x: jnp.ndarray, dA: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
              *, chunk: int, interpret: bool):
    b, S, H, P = x.shape
    N = B.shape[-1]
    q = min(chunk, S)
    assert S % q == 0, (S, q)
    nc = S // q

    kernel = functools.partial(_kernel, nc=nc, q=q)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, q, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, q, N), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, q, N), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dA, B, C)
    return y, h_final


def ssd_scan(x: jnp.ndarray, dA: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
             *, chunk: int = 256, interpret: bool | None = None):
    """x (b,S,h,p); dA (b,S,h); B,C (b,S,n). Returns (y (b,S,h,p), h_final
    (b,h,p,n) f32). S must be divisible by the chunk size.

    interpret=None auto-detects: interpret on CPU, compiled otherwise.
    """
    return _ssd_scan(x, dA, B, C, chunk=chunk,
                     interpret=resolve_interpret(interpret))
