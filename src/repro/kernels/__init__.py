"""Pallas TPU kernels (+ jit wrappers in ops.py, jnp oracles in ref.py)."""
from repro.kernels.backend import use_pallas  # noqa: F401
from repro.kernels.bank_scatter import bank_scatter  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.mifa_aggregate import mifa_aggregate  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401
