"""Pallas TPU kernels (+ jit wrappers in ops.py, jnp oracles in ref.py)."""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.mifa_aggregate import mifa_aggregate  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401
