"""Blockwise (flash) attention Pallas kernel for 32k-token prefill.

TPU tiling: grid (B, H, nq, nk) with the kv axis innermost ("arbitrary"
semantics — sequential on TPU); VMEM scratch carries the online-softmax state
(running max m, normalizer l, f32 accumulator) across kv steps, so the (S x S)
score matrix never leaves VMEM and HBM traffic is O(S·hd) per head. Block
shapes default to (128, head_dim) — MXU-aligned (multiples of 128 on the
contracting/lane dims). GQA is handled in the k/v index maps (h -> h // group),
so kv heads are never materialized `group`-times in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                             # (bq, hd)
    k = k_ref[0, :, 0, :]                             # (bk, hd)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def _flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     causal: bool, block_q: int, block_k: int,
                     interpret: bool) -> jnp.ndarray:
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               block_q=bq, block_k=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik, g=g: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik, g=g: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q (B,S,H,hd); k,v (B,T,KV,hd) with H % KV == 0. Returns (B,S,H,hd).

    interpret=None auto-detects: interpret on CPU, compiled otherwise.
    """
    return _flash_attention(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k,
                            interpret=resolve_interpret(interpret))
