"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mifa_aggregate_ref(g_old: jnp.ndarray, updates: jnp.ndarray,
                       active: jnp.ndarray, w: jnp.ndarray, eta):
    """g_old,u (N,M); active (N,); w (M,). Returns (g_new (N,M), w_new (M,))."""
    act = active.reshape(-1, 1).astype(bool)
    g_new = jnp.where(act, updates.astype(g_old.dtype), g_old)
    mean_g = jnp.mean(g_new.astype(jnp.float32), axis=0)
    w_new = (w.astype(jnp.float32) - eta * mean_g).astype(w.dtype)
    return g_new, w_new


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True) -> jnp.ndarray:
    """q (B,S,H,hd); k,v (B,T,KV,hd). Exact softmax attention in f32."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    kk = jnp.repeat(k, g, axis=2) if g > 1 else k
    vv = jnp.repeat(v, g, axis=2) if g > 1 else v
    s = jnp.einsum("bqhk,bthk->bhqt", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bthk->bqhk", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dA: jnp.ndarray, B: jnp.ndarray,
                 C: jnp.ndarray):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x (b,S,h,p); dA (b,S,h); B,C (b,S,n). Returns (y (b,S,h,p), h_final).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        xt, dat, bt, ct = inp           # (b,h,p), (b,h), (b,n), (b,n)
        h = h * jnp.exp(dat)[..., None, None] + jnp.einsum(
            "bn,bhp->bhpn", bt, xt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dA.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final
