"""Fused row-gather / delta / scatter Pallas kernel for the memory bank.

The bank update touched by a cohort round is

    old_a      = bank[ids[a]]                      (gather)
    delta_sum += Σ_a valid_a · (u_a − old_a)       (running-sum maintenance)
    bank[ids[a]] = u_a        if valid_a           (scatter)

Done naively with jnp this is three passes over the cohort rows (gather,
delta reduction, `.at[ids].set`) plus a full-array copy for the scatter.
The kernel streams each active row's column tile through VMEM exactly once
— read old, accumulate the delta, write the fresh update back in place
(`input_output_aliases` donates the bank buffer, so untouched rows are
never copied). HBM traffic is O(|A|·d) regardless of the bank's N.

Grid: (column tiles, cohort rows) — the cohort axis is innermost so the
delta-sum output tile stays resident in VMEM and accumulates across rows
(the classic k-loop pattern). Row ids arrive via scalar prefetch
(`PrefetchScalarGridSpec`), so the BlockSpec index map can address
`bank[ids[a]]` before the body runs — the canonical dynamic-gather idiom.

Padded cohort slots (valid=0) must point `ids` at a dedicated dummy row
(the caller uses row index N of an (N+1)-row bank): the kernel writes the
row's own old value back (a no-op, deterministic even when every pad slot
aliases the same dummy row) and contributes zero to the delta sum.

Blocks are (1, block_m): a single bank row per step, since gathered rows
are not contiguous. On real TPUs a (1, 512) f32 tile is below the (8, 128)
sublane optimum — acceptable for a DMA-bound gather (same trade the
embedding-lookup kernels make).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _kernel(ids_ref, valid_ref, u_ref, bank_ref, bank_out_ref, dsum_ref):
    a = pl.program_id(1)
    valid = valid_ref[a] > 0
    old = bank_ref[...]                                   # (1, bm) bank dtype
    u = u_ref[...]                                        # (1, bm) f32

    @pl.when(a == 0)
    def _init():
        dsum_ref[...] = jnp.zeros_like(dsum_ref)

    # delta uses the *stored* (dtype-cast) value, not the raw f32 update —
    # keeps G_sum == Σ rows exact for bf16 banks (same as the jnp path)
    u_st = u.astype(bank_ref.dtype)
    dsum_ref[...] += jnp.where(
        valid, u_st.astype(jnp.float32) - old.astype(jnp.float32), 0.0)
    bank_out_ref[...] = jnp.where(valid, u_st, old)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _bank_scatter(bank, updates, ids, valid, *, block_m, interpret):
    r, m = bank.shape
    c = updates.shape[0]
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    assert updates.shape == (c, m), (updates.shape, (c, m))
    assert ids.shape == valid.shape == (c,), (ids.shape, valid.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                            # ids, valid
        grid=(m // bm, c),
        in_specs=[
            pl.BlockSpec((1, bm), lambda j, a, ids, valid: (a, j)),
            pl.BlockSpec((1, bm), lambda j, a, ids, valid: (ids[a], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda j, a, ids, valid: (ids[a], j)),
            pl.BlockSpec((1, bm), lambda j, a, ids, valid: (0, j)),
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((r, m), bank.dtype),
                   jax.ShapeDtypeStruct((1, m), jnp.float32)],
        input_output_aliases={3: 0},                      # bank updated in place
        interpret=interpret,
    )(ids, valid, updates, bank)


def bank_scatter(bank: jnp.ndarray, updates: jnp.ndarray, ids: jnp.ndarray,
                 valid: jnp.ndarray, *, block_m: int = 512,
                 interpret: bool | None = None):
    """bank (R, M); updates (C, M) f32; ids (C,) int32 < R; valid (C,) bool.

    Returns (new_bank (R, M) [bank.dtype], delta_sum (M,) f32) where
    delta_sum = Σ_{valid a} (updates[a] − bank[ids[a]]). Duplicate ids are
    only allowed when at most one of them is valid (pad slots share the
    dummy row). M must be a multiple of block_m (ops.py pads).
    """
    new_bank, dsum = _bank_scatter(
        bank, updates.astype(jnp.float32), ids.astype(jnp.int32),
        valid.astype(jnp.int32), block_m=block_m,
        interpret=resolve_interpret(interpret))
    return new_bank, dsum[0]


# --------------------------------------------------------------------------- #
# batched (fleet) variant: K independent banks in one launch
# --------------------------------------------------------------------------- #

def _kernel_batched(ids_ref, valid_ref, u_ref, bank_ref, bank_out_ref,
                    dsum_ref):
    k = pl.program_id(0)
    a = pl.program_id(2)
    valid = valid_ref[k, a] > 0
    old = bank_ref[...]                                   # (1, 1, bm)
    u = u_ref[...]                                        # (1, 1, bm) f32

    @pl.when(a == 0)
    def _init():
        dsum_ref[...] = jnp.zeros_like(dsum_ref)

    u_st = u.astype(bank_ref.dtype)
    dsum_ref[...] += jnp.where(
        valid, u_st.astype(jnp.float32) - old.astype(jnp.float32), 0.0)
    bank_out_ref[...] = jnp.where(valid, u_st, old)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _bank_scatter_batched(banks, updates, ids, valid, *, block_m, interpret):
    K, r, m = banks.shape
    c = updates.shape[1]
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    assert updates.shape == (K, c, m), (updates.shape, (K, c, m))
    assert ids.shape == valid.shape == (K, c), (ids.shape, valid.shape)

    # trial axis outermost, cohort rows innermost: the (k, j) delta-sum tile
    # stays resident in VMEM and accumulates across that trial's cohort,
    # exactly like the single-trial kernel's k-loop
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                            # ids, valid (K, C)
        grid=(K, m // bm, c),
        in_specs=[
            pl.BlockSpec((1, 1, bm), lambda k, j, a, ids, valid: (k, a, j)),
            pl.BlockSpec((1, 1, bm),
                         lambda k, j, a, ids, valid: (k, ids[k, a], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bm),
                         lambda k, j, a, ids, valid: (k, ids[k, a], j)),
            pl.BlockSpec((1, 1, bm), lambda k, j, a, ids, valid: (k, 0, j)),
        ],
    )
    return pl.pallas_call(
        _kernel_batched,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((K, r, m), banks.dtype),
                   jax.ShapeDtypeStruct((K, 1, m), jnp.float32)],
        input_output_aliases={3: 0},                      # banks in place
        interpret=interpret,
    )(ids, valid, updates, banks)


def bank_scatter_batched(banks: jnp.ndarray, updates: jnp.ndarray,
                         ids: jnp.ndarray, valid: jnp.ndarray, *,
                         block_m: int = 512,
                         interpret: bool | None = None):
    """Grid-axis batched `bank_scatter` for the fleet executor.

    banks (K, R, M); updates (K, C, M) f32; ids (K, C) int32 < R;
    valid (K, C) bool. Returns (new_banks (K, R, M), delta_sum (K, M) f32) —
    per trial k exactly what `bank_scatter(banks[k], ...)` returns. The K
    trials share one kernel launch: the trial index is the outermost grid
    dimension, so each trial's cohort streams through VMEM back-to-back with
    no host round-trips between trials.
    """
    new_banks, dsum = _bank_scatter_batched(
        banks, updates.astype(jnp.float32), ids.astype(jnp.int32),
        valid.astype(jnp.int32), block_m=block_m,
        interpret=resolve_interpret(interpret))
    return new_banks, dsum[:, 0]


# --------------------------------------------------------------------------- #
# paged variants: rows addressed through a page-table indirection
# --------------------------------------------------------------------------- #
#
# The paged device bank (bank/paged_device.py) stores rows in fixed-size
# physical pages: logical row `lid` lives at physical row
#
#     page_table[lid // page_size] * page_size + lid % page_size
#
# The page table rides the scan carry as a plain int32 array, so it arrives
# here via scalar prefetch exactly like the row ids — the page LOOKUP happens
# inside the BlockSpec index map, before the kernel body runs. Non-resident
# logical pages map to the dedicated dummy slot (the caller's sentinel), so a
# stray access reads zeros and writes are no-ops; the bank's `prepare` hook
# guarantees every *valid* row is resident before a round executes.
#
# The kernel bodies are identical to the flat kernels above (read old,
# accumulate the masked delta, write the fresh update back in place) — only
# the addressing differs, which is exactly why paged trajectories stay
# fp32 bit-exact against the flat bank: reductions run over the cohort axis,
# never over physical rows, so slot placement can never change a value.


def _paged_kernel(pt_ref, lids_ref, valid_ref, u_ref, pages_ref,
                  pages_out_ref, dsum_ref):
    a = pl.program_id(1)
    valid = valid_ref[a] > 0
    old = pages_ref[...]                                  # (1, bm) page dtype
    u = u_ref[...]                                        # (1, bm) f32

    @pl.when(a == 0)
    def _init():
        dsum_ref[...] = jnp.zeros_like(dsum_ref)

    u_st = u.astype(pages_ref.dtype)
    dsum_ref[...] += jnp.where(
        valid, u_st.astype(jnp.float32) - old.astype(jnp.float32), 0.0)
    pages_out_ref[...] = jnp.where(valid, u_st, old)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "block_m", "interpret"))
def _paged_bank_scatter(pages, updates, page_table, lids, valid, *,
                        page_size, block_m, interpret):
    r, m = pages.shape
    c = updates.shape[0]
    ps = page_size
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    assert updates.shape == (c, m), (updates.shape, (c, m))
    assert lids.shape == valid.shape == (c,), (lids.shape, valid.shape)

    # the page lookup IS the index map: scalar-prefetched page_table + lids
    # resolve each cohort slot to its physical row before the body runs
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                            # pt, lids, valid
        grid=(m // bm, c),
        in_specs=[
            pl.BlockSpec((1, bm), lambda j, a, pt, lids, valid: (a, j)),
            pl.BlockSpec(
                (1, bm),
                lambda j, a, pt, lids, valid:
                    (pt[lids[a] // ps] * ps + lids[a] % ps, j)),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, bm),
                lambda j, a, pt, lids, valid:
                    (pt[lids[a] // ps] * ps + lids[a] % ps, j)),
            pl.BlockSpec((1, bm), lambda j, a, pt, lids, valid: (0, j)),
        ],
    )
    return pl.pallas_call(
        _paged_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((r, m), pages.dtype),
                   jax.ShapeDtypeStruct((1, m), jnp.float32)],
        input_output_aliases={4: 0},                      # pages in place
        interpret=interpret,
    )(page_table, lids, valid, updates, pages)


def paged_bank_scatter(pages: jnp.ndarray, updates: jnp.ndarray,
                       page_table: jnp.ndarray, lids: jnp.ndarray,
                       valid: jnp.ndarray, *, page_size: int,
                       block_m: int = 512, interpret: bool | None = None):
    """Fused gather/delta/scatter through a page-table indirection.

    pages (R, M) with R = (slots+1)·page_size; updates (C, M) f32;
    page_table (P,) int32 slot per logical page (sentinel -> dummy slot);
    lids (C,) int32 *sanitized* logical rows (pad slots already remapped to
    the dummy logical page by the caller); valid (C,) bool. Returns
    (new_pages, delta_sum (M,) f32) — per slot exactly `bank_scatter` on the
    physically-addressed rows.
    """
    new_pages, dsum = _paged_bank_scatter(
        pages, updates.astype(jnp.float32), page_table.astype(jnp.int32),
        lids.astype(jnp.int32), valid.astype(jnp.int32),
        page_size=page_size, block_m=block_m,
        interpret=resolve_interpret(interpret))
    return new_pages, dsum[0]


def _paged_gather_kernel(pt_ref, lids_ref, pages_ref, out_ref):
    del pt_ref, lids_ref
    out_ref[...] = pages_ref[...].astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "block_m", "interpret"))
def _paged_bank_gather(pages, page_table, lids, *, page_size, block_m,
                       interpret):
    r, m = pages.shape
    c = lids.shape[0]
    ps = page_size
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                            # pt, lids
        grid=(m // bm, c),
        in_specs=[
            pl.BlockSpec(
                (1, bm),
                lambda j, a, pt, lids:
                    (pt[lids[a] // ps] * ps + lids[a] % ps, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda j, a, pt, lids: (a, j)),
        ],
    )
    (out,) = pl.pallas_call(
        _paged_gather_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((c, m), jnp.float32)],
        interpret=interpret,
    )(page_table, lids, pages)
    return out


def paged_bank_gather(pages: jnp.ndarray, page_table: jnp.ndarray,
                      lids: jnp.ndarray, *, page_size: int,
                      block_m: int = 512, interpret: bool | None = None):
    """Row gather through the page table: (C, M) f32 rows for `lids`.

    Non-resident logical pages read the dummy slot (exact zeros by the
    bank's invariant); the caller masks or `prepare`s as needed.
    """
    return _paged_bank_gather(
        pages, page_table.astype(jnp.int32), lids.astype(jnp.int32),
        page_size=page_size, block_m=block_m,
        interpret=resolve_interpret(interpret))


def _paged_kernel_batched(pt_ref, lids_ref, valid_ref, u_ref, pages_ref,
                          pages_out_ref, dsum_ref):
    k = pl.program_id(0)
    a = pl.program_id(2)
    valid = valid_ref[k, a] > 0
    old = pages_ref[...]                                  # (1, 1, bm)
    u = u_ref[...]                                        # (1, 1, bm) f32

    @pl.when(a == 0)
    def _init():
        dsum_ref[...] = jnp.zeros_like(dsum_ref)

    u_st = u.astype(pages_ref.dtype)
    dsum_ref[...] += jnp.where(
        valid, u_st.astype(jnp.float32) - old.astype(jnp.float32), 0.0)
    pages_out_ref[...] = jnp.where(valid, u_st, old)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "block_m", "interpret"))
def _paged_bank_scatter_batched(pages, updates, page_table, lids, valid, *,
                                page_size, block_m, interpret):
    K, r, m = pages.shape
    c = updates.shape[1]
    ps = page_size
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    assert updates.shape == (K, c, m), (updates.shape, (K, c, m))
    assert lids.shape == valid.shape == (K, c), (lids.shape, valid.shape)

    def _prow(k, a, pt, lids):
        return pt[k, lids[k, a] // ps] * ps + lids[k, a] % ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                            # pt, lids, valid
        grid=(K, m // bm, c),
        in_specs=[
            pl.BlockSpec((1, 1, bm),
                         lambda k, j, a, pt, lids, valid: (k, a, j)),
            pl.BlockSpec((1, 1, bm),
                         lambda k, j, a, pt, lids, valid:
                             (k, _prow(k, a, pt, lids), j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bm),
                         lambda k, j, a, pt, lids, valid:
                             (k, _prow(k, a, pt, lids), j)),
            pl.BlockSpec((1, 1, bm),
                         lambda k, j, a, pt, lids, valid: (k, 0, j)),
        ],
    )
    return pl.pallas_call(
        _paged_kernel_batched,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((K, r, m), pages.dtype),
                   jax.ShapeDtypeStruct((K, 1, m), jnp.float32)],
        input_output_aliases={4: 0},                      # pages in place
        interpret=interpret,
    )(page_table, lids, valid, updates, pages)


def paged_bank_scatter_batched(pages: jnp.ndarray, updates: jnp.ndarray,
                               page_table: jnp.ndarray, lids: jnp.ndarray,
                               valid: jnp.ndarray, *, page_size: int,
                               block_m: int = 512,
                               interpret: bool | None = None):
    """Grid-axis batched `paged_bank_scatter` for the fleet executor.

    pages (K, R, M); updates (K, C, M) f32; page_table (K, P) int32 (the
    fleet keeps identical per-trial copies — one shared residency mapping);
    lids/valid (K, C). Returns (new_pages (K, R, M), delta_sum (K, M) f32),
    per trial k exactly `paged_bank_scatter(pages[k], ...)`.
    """
    new_pages, dsum = _paged_bank_scatter_batched(
        pages, updates.astype(jnp.float32), page_table.astype(jnp.int32),
        lids.astype(jnp.int32), valid.astype(jnp.int32),
        page_size=page_size, block_m=block_m,
        interpret=resolve_interpret(interpret))
    return new_pages, dsum[:, 0]
