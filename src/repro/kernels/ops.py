"""Jit'd wrappers binding the Pallas kernels into the framework.

* `mifa_aggregate_tree` — applies the fused aggregation kernel across a whole
  parameter pytree (flatten each leaf's model dims, pad to the block size).
* `bank_update_tree` — the fused cohort gather/delta/scatter over a memory-
  bank pytree (DenseBank's Pallas path). The `*_pure` variants are the same
  bodies without the jit wrapper, for callers that are already tracing
  (jitted round functions, `lax.scan` bodies, vmapped fleet programs).
* `attention` / `ssd` — drop-in replacements for the jnp paths in
  repro.models (callers opt in; `use_pallas(True/False/None)` only forces
  compiled vs interpret for code that already routes through these wrappers).

Interpret vs compiled is auto-detected per process (`kernels.backend`):
interpret on CPU — numerically exact but slow — compiled Mosaic on real
accelerators. Every wrapper takes `interpret=None` (auto) and resolves it
*before* entering jit, so the cache is keyed on the resolved bool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import resolve_interpret, use_pallas  # noqa: F401
from repro.kernels.bank_scatter import (bank_scatter, bank_scatter_batched,
                                        paged_bank_gather, paged_bank_scatter,
                                        paged_bank_scatter_batched)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mifa_aggregate import mifa_aggregate
from repro.kernels.ssd_scan import ssd_scan


def _pad_to(x: jnp.ndarray, m: int, axis: int = -1):
    size = x.shape[axis]
    pad = (-size) % m
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _mifa_aggregate_tree(g_tree, u_tree, active, params, eta, *,
                         block_m, interpret):
    def one(g, u, w):
        n = g.shape[0]
        g2, m = _pad_to(g.reshape(n, -1), block_m)
        u2, _ = _pad_to(u.reshape(n, -1), block_m)
        w2, _ = _pad_to(w.reshape(-1), block_m)
        gn, wn = mifa_aggregate(g2, u2, active, w2, eta,
                                block_m=min(block_m, g2.shape[1]),
                                interpret=interpret)
        return (gn[:, :m].reshape(g.shape), wn[:m].reshape(w.shape))

    out = jax.tree.map(one, g_tree, u_tree, params)
    g_new = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    p_new = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    return g_new, p_new


def mifa_aggregate_tree(g_tree, u_tree, active, params, eta, *,
                        block_m: int = 512, interpret: bool | None = None):
    """Fused MIFA aggregation over a pytree.

    g_tree / u_tree: leaves (N, *shape); params: leaves (*shape).
    Returns (new_g_tree, new_params).
    """
    return _mifa_aggregate_tree(g_tree, u_tree, active, params, eta,
                                block_m=block_m,
                                interpret=resolve_interpret(interpret))


# widest single-tile row the bank kernel takes before column-blocking kicks
# in; (1, 8192) f32 is ~32 KB/buffer in VMEM, well under budget
_BANK_SINGLE_BLOCK = 8192


def _bank_update_tree_body(rows_tree, upd_tree, ids, valid, *, block_m,
                           interpret):
    def one(rows, u):
        r, c = rows.shape[0], u.shape[0]
        m_raw = int(np.prod(rows.shape[1:]))
        if m_raw <= _BANK_SINGLE_BLOCK:
            # one tile per row: no padding, no O(N·d) bank copy
            rows2, m = rows.reshape(r, -1), m_raw
            u2 = u.reshape(c, -1)
            bm = m_raw
        else:
            # wide leaves get column-blocked; padding copies the bank, so
            # production models should keep flattened widths divisible by
            # block_m (true for power-of-two dims) to stay zero-copy
            rows2, m = _pad_to(rows.reshape(r, -1), block_m)
            u2, _ = _pad_to(u.reshape(c, -1), block_m)
            bm = min(block_m, rows2.shape[1])
        rn, ds = bank_scatter(rows2, u2, ids, valid, block_m=bm,
                              interpret=interpret)
        return (rn[:, :m].reshape(rows.shape),
                ds[:m].reshape(rows.shape[1:]))

    out = jax.tree.map(one, rows_tree, upd_tree)
    rows_new = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda o: isinstance(o, tuple))
    dsum = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda o: isinstance(o, tuple))
    return rows_new, dsum


_bank_update_tree = functools.partial(
    jax.jit, static_argnames=("block_m", "interpret"))(_bank_update_tree_body)


def bank_update_tree(rows_tree, upd_tree, ids, valid, *, block_m: int = 512,
                     interpret: bool | None = None):
    """Fused cohort bank update over a pytree.

    rows_tree: leaves (R, *shape); upd_tree: leaves (C, *shape) f32;
    ids (C,) int32 rows to update (pad slots -> dummy row); valid (C,) bool.
    Returns (new_rows_tree, delta_sum_tree with leaves (*shape,) f32).
    """
    return _bank_update_tree(rows_tree, upd_tree, ids, valid,
                             block_m=block_m,
                             interpret=resolve_interpret(interpret))


def bank_update_tree_pure(rows_tree, upd_tree, ids, valid, *,
                          block_m: int = 512,
                          interpret: bool | None = None):
    """`bank_update_tree` without the jit wrapper — for callers that are
    already inside a trace (a jitted round function, a `lax.scan` body, a
    vmapped fleet program), where a nested jit with donated buffers is at
    best a no-op and at worst a trace-time surprise. Same math, same
    kernel; interpret is still resolved eagerly so the Pallas call sees a
    concrete bool."""
    return _bank_update_tree_body(rows_tree, upd_tree, ids, valid,
                                  block_m=block_m,
                                  interpret=resolve_interpret(interpret))


def _fleet_bank_update_tree_body(rows_tree, upd_tree, ids, valid, *, block_m,
                                 interpret):
    def one(rows, u):
        K, r = rows.shape[0], rows.shape[1]
        c = u.shape[1]
        m_raw = int(np.prod(rows.shape[2:]))
        if m_raw <= _BANK_SINGLE_BLOCK:
            rows2, m = rows.reshape(K, r, -1), m_raw
            u2 = u.reshape(K, c, -1)
            bm = m_raw
        else:
            rows2, m = _pad_to(rows.reshape(K, r, -1), block_m)
            u2, _ = _pad_to(u.reshape(K, c, -1), block_m)
            bm = min(block_m, rows2.shape[2])
        rn, ds = bank_scatter_batched(rows2, u2, ids, valid, block_m=bm,
                                      interpret=interpret)
        return (rn[:, :, :m].reshape(rows.shape),
                ds[:, :m].reshape((K,) + rows.shape[2:]))

    out = jax.tree.map(one, rows_tree, upd_tree)
    rows_new = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda o: isinstance(o, tuple))
    dsum = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda o: isinstance(o, tuple))
    return rows_new, dsum


_fleet_bank_update_tree = functools.partial(
    jax.jit,
    static_argnames=("block_m", "interpret"))(_fleet_bank_update_tree_body)


def fleet_bank_update_tree(rows_tree, upd_tree, ids, valid, *,
                           block_m: int = 512,
                           interpret: bool | None = None):
    """Batched (K-trial) fused bank update over a pytree.

    rows_tree: leaves (K, R, *shape); upd_tree: leaves (K, C, *shape) f32;
    ids/valid (K, C). Returns (new_rows_tree, delta_sum_tree with leaves
    (K, *shape) f32) — per trial identical to `bank_update_tree`.
    """
    return _fleet_bank_update_tree(rows_tree, upd_tree, ids, valid,
                                   block_m=block_m,
                                   interpret=resolve_interpret(interpret))


def fleet_bank_update_tree_pure(rows_tree, upd_tree, ids, valid, *,
                                block_m: int = 512,
                                interpret: bool | None = None):
    """Un-jitted `fleet_bank_update_tree` (see `bank_update_tree_pure`):
    the entry the scan-native fleet path traces inside its own program."""
    return _fleet_bank_update_tree_body(rows_tree, upd_tree, ids, valid,
                                        block_m=block_m,
                                        interpret=resolve_interpret(interpret))


def _paged_bank_update_tree_body(pages_tree, upd_tree, page_table, lids,
                                 valid, *, page_size, block_m, interpret):
    def one(pages, u):
        r, c = pages.shape[0], u.shape[0]
        m_raw = int(np.prod(pages.shape[1:]))
        if m_raw <= _BANK_SINGLE_BLOCK:
            pages2, m = pages.reshape(r, -1), m_raw
            u2 = u.reshape(c, -1)
            bm = m_raw
        else:
            pages2, m = _pad_to(pages.reshape(r, -1), block_m)
            u2, _ = _pad_to(u.reshape(c, -1), block_m)
            bm = min(block_m, pages2.shape[1])
        pn, ds = paged_bank_scatter(pages2, u2, page_table, lids, valid,
                                    page_size=page_size, block_m=bm,
                                    interpret=interpret)
        return (pn[:, :m].reshape(pages.shape),
                ds[:m].reshape(pages.shape[1:]))

    out = jax.tree.map(one, pages_tree, upd_tree)
    pages_new = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda o: isinstance(o, tuple))
    dsum = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda o: isinstance(o, tuple))
    return pages_new, dsum


_paged_bank_update_tree = functools.partial(
    jax.jit, static_argnames=("page_size", "block_m", "interpret"))(
        _paged_bank_update_tree_body)


def paged_bank_update_tree(pages_tree, upd_tree, page_table, lids, valid, *,
                           page_size: int, block_m: int = 512,
                           interpret: bool | None = None):
    """Fused cohort bank update through a page-table indirection.

    pages_tree: leaves (R, *shape) with R = (slots+1)·page_size; upd_tree:
    leaves (C, *shape) f32; page_table (P,) int32; lids (C,) int32 sanitized
    logical rows (pad slots -> dummy logical page); valid (C,) bool.
    Returns (new_pages_tree, delta_sum_tree with leaves (*shape,) f32).
    """
    return _paged_bank_update_tree(pages_tree, upd_tree, page_table, lids,
                                   valid, page_size=page_size,
                                   block_m=block_m,
                                   interpret=resolve_interpret(interpret))


def paged_bank_update_tree_pure(pages_tree, upd_tree, page_table, lids,
                                valid, *, page_size: int, block_m: int = 512,
                                interpret: bool | None = None):
    """Un-jitted `paged_bank_update_tree` (see `bank_update_tree_pure`) —
    what the paged bank traces inside scan bodies and fleet programs."""
    return _paged_bank_update_tree_body(
        pages_tree, upd_tree, page_table, lids, valid, page_size=page_size,
        block_m=block_m, interpret=resolve_interpret(interpret))


def fleet_paged_bank_update_tree_pure(pages_tree, upd_tree, page_table, lids,
                                      valid, *, page_size: int,
                                      block_m: int = 512,
                                      interpret: bool | None = None):
    """Batched (K-trial) paged bank update, un-jitted.

    pages_tree: leaves (K, R, *shape); upd_tree: leaves (K, C, *shape) f32;
    page_table (K, P); lids/valid (K, C). Per trial identical to
    `paged_bank_update_tree`.
    """
    interpret = resolve_interpret(interpret)

    def one(pages, u):
        K, r = pages.shape[0], pages.shape[1]
        c = u.shape[1]
        m_raw = int(np.prod(pages.shape[2:]))
        if m_raw <= _BANK_SINGLE_BLOCK:
            pages2, m = pages.reshape(K, r, -1), m_raw
            u2 = u.reshape(K, c, -1)
            bm = m_raw
        else:
            pages2, m = _pad_to(pages.reshape(K, r, -1), block_m)
            u2, _ = _pad_to(u.reshape(K, c, -1), block_m)
            bm = min(block_m, pages2.shape[2])
        pn, ds = paged_bank_scatter_batched(pages2, u2, page_table, lids,
                                            valid, page_size=page_size,
                                            block_m=bm, interpret=interpret)
        return (pn[:, :, :m].reshape(pages.shape),
                ds[:, :m].reshape((K,) + pages.shape[2:]))

    out = jax.tree.map(one, pages_tree, upd_tree)
    pages_new = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda o: isinstance(o, tuple))
    dsum = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda o: isinstance(o, tuple))
    return pages_new, dsum


def paged_bank_gather_tree_pure(pages_tree, page_table, lids, *,
                                page_size: int, block_m: int = 512,
                                interpret: bool | None = None):
    """Row gather through the page table over a pytree: leaves (C, *shape)
    f32 for the requested logical rows (non-resident pages read the dummy
    slot's zeros). Un-jitted, for callers already inside a trace."""
    interpret = resolve_interpret(interpret)

    def one(pages):
        r = pages.shape[0]
        c = lids.shape[0]
        m_raw = int(np.prod(pages.shape[1:]))
        if m_raw <= _BANK_SINGLE_BLOCK:
            pages2, m = pages.reshape(r, -1), m_raw
            bm = m_raw
        else:
            pages2, m = _pad_to(pages.reshape(r, -1), block_m)
            bm = min(block_m, pages2.shape[1])
        rows = paged_bank_gather(pages2, page_table, lids,
                                 page_size=page_size, block_m=bm,
                                 interpret=interpret)
        return rows[:, :m].reshape((c,) + pages.shape[1:])

    return jax.tree.map(one, pages_tree)


def attention(q, k, v, *, causal=True, block_q=128, block_k=128,
              interpret: bool | None = None):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k,
                           interpret=resolve_interpret(interpret))


def ssd(x, dA, B, C, *, chunk=256, interpret: bool | None = None):
    return ssd_scan(x, dA, B, C, chunk=chunk,
                    interpret=resolve_interpret(interpret))
