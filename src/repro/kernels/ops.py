"""Jit'd wrappers binding the Pallas kernels into the framework.

* `mifa_aggregate_tree` — applies the fused aggregation kernel across a whole
  parameter pytree (flatten each leaf's model dims, pad to the block size).
* `attention` / `ssd` — drop-in replacements for the jnp paths in
  repro.models; `use_pallas(True)` flips the model zoo onto the kernels
  (interpret=True on CPU, compiled on real TPUs).

On this CPU container the kernels run in interpret mode — numerically exact but
slow — so the model default stays on the jnp paths; tests sweep both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mifa_aggregate import mifa_aggregate
from repro.kernels.ssd_scan import ssd_scan

_INTERPRET = True  # no TPU in this container


def _pad_to(x: jnp.ndarray, m: int, axis: int = -1):
    size = x.shape[axis]
    pad = (-size) % m
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("block_m",))
def mifa_aggregate_tree(g_tree, u_tree, active, params, eta, *,
                        block_m: int = 512):
    """Fused MIFA aggregation over a pytree.

    g_tree / u_tree: leaves (N, *shape); params: leaves (*shape).
    Returns (new_g_tree, new_params).
    """
    def one(g, u, w):
        n = g.shape[0]
        g2, m = _pad_to(g.reshape(n, -1), block_m)
        u2, _ = _pad_to(u.reshape(n, -1), block_m)
        w2, _ = _pad_to(w.reshape(-1), block_m)
        gn, wn = mifa_aggregate(g2, u2, active, w2, eta,
                                block_m=min(block_m, g2.shape[1]),
                                interpret=_INTERPRET)
        return (gn[:, :m].reshape(g.shape), wn[:m].reshape(w.shape))

    out = jax.tree.map(one, g_tree, u_tree, params)
    g_new = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    p_new = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    return g_new, p_new


def attention(q, k, v, *, causal=True, block_q=128, block_k=128):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=_INTERPRET)


def ssd(x, dA, B, C, *, chunk=256):
    return ssd_scan(x, dA, B, C, chunk=chunk, interpret=_INTERPRET)
