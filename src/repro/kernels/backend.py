"""Pallas backend selection: compiled on real accelerators, interpret on CPU.

Every kernel entry point takes `interpret: bool | None = None`; `None` means
"auto": interpret mode iff `jax.default_backend() == "cpu"` (this container),
compiled Mosaic otherwise. `use_pallas(True/False/None)` forces compiled /
interpret / auto globally — resolution happens *outside* the jitted wrappers,
so flipping it mid-process retriggers compilation instead of hitting a stale
jit cache keyed on `interpret=None`.

Detection is deliberately lazy (a function, not a module-level constant):
importing a kernels module must never initialize the JAX backend — the
dry-run driver sets XLA_FLAGS for 512 host devices before first JAX use.
"""
from __future__ import annotations

import jax

_FORCED: bool | None = None


def use_pallas(enabled: bool | None) -> None:
    """Force compiled Pallas (True), interpret mode (False), or auto (None)."""
    global _FORCED
    _FORCED = enabled


def interpret_default() -> bool:
    """True when kernels should run in interpret mode on this process.

    Compiled only on real TPUs: the kernels use pltpu primitives (VMEM
    scratch, PrefetchScalarGridSpec) that Mosaic cannot lower for GPU, so a
    CUDA host must fall back to interpret mode exactly like CPU.
    """
    if _FORCED is not None:
        return not _FORCED
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return interpret_default() if interpret is None else bool(interpret)


def pallas_partition_safe(mesh) -> bool:
    """May a pallas_call run under callers sharded over `mesh`?

    A pallas_call — compiled Mosaic or interpret mode alike — is a
    single-device program: it has no SPMD partitioning rule, so tracing one
    inside a jit whose operands are sharded over a >1-device mesh either
    fails to lower or silently gathers the full operand onto every device.
    The pure-jnp scatter bodies, by contrast, partition fine (gather /
    `.at[ids].set` lower to collectives). Callers that hold a mesh
    (e.g. `bank.DenseBank`) consult this before choosing the kernel path
    and fall back to jnp when it returns False.

    `mesh` may be None (no mesh: safe), a concrete `jax.sharding.Mesh`, or
    an `AbstractMesh` — anything exposing `.size` or a `.shape` mapping.
    """
    if mesh is None:
        return True
    n = getattr(mesh, "size", None)
    if n is None:
        n = 1
        for extent in dict(mesh.shape).values():
            n *= extent
    return n <= 1
