"""Pallas backend selection: compiled on real accelerators, interpret on CPU.

Every kernel entry point takes `interpret: bool | None = None`; `None` means
"auto": interpret mode iff `jax.default_backend() == "cpu"` (this container),
compiled Mosaic otherwise. `use_pallas(True/False/None)` forces compiled /
interpret / auto globally — resolution happens *outside* the jitted wrappers,
so flipping it mid-process retriggers compilation instead of hitting a stale
jit cache keyed on `interpret=None`.

Detection is deliberately lazy (a function, not a module-level constant):
importing a kernels module must never initialize the JAX backend — the
dry-run driver sets XLA_FLAGS for 512 host devices before first JAX use.
"""
from __future__ import annotations

import jax

_FORCED: bool | None = None


def use_pallas(enabled: bool | None) -> None:
    """Force compiled Pallas (True), interpret mode (False), or auto (None)."""
    global _FORCED
    _FORCED = enabled


def interpret_default() -> bool:
    """True when kernels should run in interpret mode on this process.

    Compiled only on real TPUs: the kernels use pltpu primitives (VMEM
    scratch, PrefetchScalarGridSpec) that Mosaic cannot lower for GPU, so a
    CUDA host must fall back to interpret mode exactly like CPU.
    """
    if _FORCED is not None:
        return not _FORCED
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return interpret_default() if interpret is None else bool(interpret)
