"""Plain SGD (+momentum, weight decay) — the paper's optimizer, server side.

The FL algorithms apply `w -= η·Ḡ` themselves; this module is the standalone
optimizer used by non-FL training paths and the momentum variant of the server
update (a beyond-paper option: server momentum over the MIFA mean update).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {}
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def sgd_step(params, grads, opt_state, *, eta, momentum: float = 0.0,
             weight_decay: float = 0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, w: g + weight_decay * w.astype(g.dtype),
                             grads, params)
    if momentum:
        m = jax.tree.map(lambda mm, g: momentum * mm + g.astype(jnp.float32),
                         opt_state["m"], grads)
        params = jax.tree.map(lambda w, mm: (w - eta * mm).astype(w.dtype),
                              params, m)
        return params, {"m": m}
    params = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype),
                          params, grads)
    return params, opt_state
