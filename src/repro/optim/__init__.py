from repro.optim.schedules import (constant, inv_t, paper_strongly_convex,  # noqa: F401
                                   nonconvex_fixed, cosine)
from repro.optim.sgd import sgd_init, sgd_step  # noqa: F401
