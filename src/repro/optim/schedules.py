"""Learning-rate schedules from the paper.

  * inv_t                — η_t = η0 / t, the paper's experimental schedule (§7).
  * paper_strongly_convex— η_t = 4 / (μ K (t + a)), a = max{100, 40 t0}(L/μ)^1.5
                           (Theorem 5.1).
  * nonconvex_fixed      — η = sqrt(N / (K T L (1 + ν̄))) (Theorem 6.1).
  * constant / cosine    — framework staples.
"""
from __future__ import annotations

import math


def constant(eta0: float):
    return lambda t: eta0


def inv_t(eta0: float):
    return lambda t: eta0 / max(t, 1)


def paper_strongly_convex(mu: float, L: float, K: int, t0: float = 0.0):
    a = max(100.0, 40.0 * t0) * (L / mu) ** 1.5
    return lambda t: 4.0 / (mu * K * (t + a))


def nonconvex_fixed(N: int, K: int, T: int, L: float, nu_bar: float = 0.0):
    eta_tilde = math.sqrt(N / (K * T * L * (1.0 + nu_bar)))
    return lambda t: eta_tilde / K  # paper states η (per-step); η̃ = Kη


def cosine(eta0: float, total: int, warmup: int = 0, floor: float = 0.0):
    def f(t):
        if t < warmup:
            return eta0 * (t + 1) / max(warmup, 1)
        p = (t - warmup) / max(total - warmup, 1)
        return floor + 0.5 * (eta0 - floor) * (1 + math.cos(math.pi * min(p, 1.0)))
    return f
