"""Sharded memory-bank subsystem: cohort-sized MIFA server state (docs/architecture.md §3)."""
from repro.bank.base import MemoryBank  # noqa: F401
from repro.bank.dense import DenseBank  # noqa: F401
from repro.bank.host import HostBank  # noqa: F401
from repro.bank.int8_paged import Int8PagedBank  # noqa: F401
from repro.bank.mifa_bank import BankedMIFA  # noqa: F401
from repro.bank.paged_device import PagedDeviceBank  # noqa: F401

_BACKENDS = {"dense": DenseBank, "host": HostBank,
             "int8_paged": Int8PagedBank, "paged_device": PagedDeviceBank}


def make_bank(backend: str = "dense", **kwargs) -> MemoryBank:
    """backend: 'dense' | 'host' | 'int8_paged' | 'paged_device'
    (kwargs -> backend ctor)."""
    try:
        return _BACKENDS[backend](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown bank backend {backend!r}; "
            f"choose from {sorted(_BACKENDS)}") from None
