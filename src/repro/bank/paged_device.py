"""PagedDeviceBank — device-resident pages behind a jit-native page table.

The missing bridge between the two big bank ideas: DenseBank is jittable (so
the scan engine and the fleet can trace it) but holds all N rows on device;
the host backends stay flat to N=10⁶ but live outside jit and force the
per-round dispatch loop. This backend keeps a *bounded* number of rows on
device — `n_slots` fixed-size pages plus one dummy page — and addresses them
through a page-table indirection that is a plain int32 jnp array riding the
scan carry:

    phys_row(lid) = page_table[lid // page_size] * page_size + lid % page_size

Everything on the hot path (gather, the fused gather/delta/scatter, the
G_sum delta identity) is pure jnp / Pallas over `phys_row`, so it traces
cleanly inside `lax.scan` bodies and under `vmap` for fleets. Residency is
managed *between* jitted programs by `prepare(state, ids)` — an eager,
host-side step that pages the cohort's (or chunk union's) logical pages in,
spilling deterministic-LRU victims to host RAM. The scan engine calls it at
chunk boundaries through the pipelined-flush hook; the per-round loop and the
fleet executor call it before each round.

Why paging never changes the numbers: a gather returns the same values no
matter which physical slot a row occupies, and every reduction (delta sum,
loss) runs over the *cohort* axis, never over physical rows. So trajectories
are fp32 bit-exact against DenseBank — even when the loop and the scan page
on different schedules — as long as every row a round touches is resident
when it executes (which `prepare` guarantees, and raises loudly when it
can't).

State layout (all jnp, scan-carry safe):
    pages      : pytree, leaves ((n_slots+1)·page_size, *shape) `dtype`;
                 the last page is the dummy page — always exact zeros —
                 that pad slots and non-resident reads resolve to.
    page_table : (logical_pages+1,) int32; sentinel (= n_slots, the dummy
                 slot) marks non-resident pages; the last entry is the
                 dummy logical page, pinned to the dummy slot.
    g_sum      : pytree, leaves (*shape,) f32 — running Σ_i G^i (over
                 dequantized values when dtype="int8", as Int8PagedBank).
    scales     : (dtype="int8" only) pytree, leaves (n_rows,) f32 absmax
                 scales per physical row.

Host-side bookkeeping (never traced): a numpy mirror of the page table, a
slot→logical-page reverse map, a free list, LRU timestamps, and the spill
store `{logical_page: per-leaf numpy blocks}` for evicted pages.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank.base import MemoryBank, broadcast_valid, check_unique_ids
from repro.bank.dense import _scatter_jnp, _traced
from repro.core import quantized_memory as qm
from repro.core.runner import _pow2_bucket


def _phys_rows(page_table, lids, page_size: int):
    return page_table[lids // page_size] * page_size + lids % page_size


def _scatter_pure(pages, scales, g_sum, page_table, ids, valid, updates, rng,
                  *, page_size: int, n_clients: int, dummy_lrow: int,
                  quantized: bool, use_pallas: bool):
    """Paged gather/delta/scatter body — trace-safe (scan/vmap/jit).

    Assumes every valid id's logical page is resident (`prepare` ran).
    Pad ids (>= n_clients) are remapped to the dummy logical row, whose
    writes are masked out by `valid` — so they never touch G_sum or a page.
    """
    lids = jnp.where(ids >= n_clients, dummy_lrow, ids).astype(jnp.int32)
    if quantized:
        leaves, treedef = jax.tree.flatten(pages)
        sc_leaves = treedef.flatten_up_to(scales)
        gs_leaves = treedef.flatten_up_to(g_sum)
        u_leaves = treedef.flatten_up_to(updates)
        rngs = jax.random.split(rng, len(leaves))
        phys = _phys_rows(page_table, lids, page_size)
        new_p, new_s, new_g = [], [], []
        for r, sc, gs, u, key in zip(leaves, sc_leaves, gs_leaves, u_leaves,
                                     rngs):
            # key rounding noise by logical id, not cohort slot, so pad
            # slots never shift the draws of the real rows
            row_keys = jax.vmap(jax.random.fold_in, (None, 0))(key, lids)
            q, qs = jax.vmap(
                lambda k, x: jax.tree.map(
                    lambda a: a[0], qm.quantize_leaf(k, x[None]))
            )(row_keys, u.astype(jnp.float32))
            u_eff = qm.dequantize_leaf(q, qs)        # what the bank stores
            old = qm.dequantize_leaf(r[phys], sc[phys])
            vb = broadcast_valid(valid, u_eff)
            delta = jnp.where(vb, u_eff - old, 0.0)
            new_p.append(r.at[phys].set(jnp.where(vb, q, r[phys])))
            new_s.append(sc.at[phys].set(jnp.where(valid, qs, sc[phys])))
            new_g.append(gs + jnp.sum(delta, axis=0))
        return (jax.tree.unflatten(treedef, new_p),
                jax.tree.unflatten(treedef, new_s),
                jax.tree.unflatten(treedef, new_g))
    if use_pallas:
        from repro.kernels.ops import paged_bank_update_tree_pure
        pages_new, dsum = paged_bank_update_tree_pure(
            pages, updates, page_table, lids, valid, page_size=page_size)
        g_sum = jax.tree.map(jnp.add, g_sum, dsum)
        return pages_new, scales, g_sum
    phys = _phys_rows(page_table, lids, page_size)
    pages_new, g_new = _scatter_jnp(pages, g_sum, phys, valid, updates)
    return pages_new, scales, g_new


_STATIC = ("page_size", "n_clients", "dummy_lrow", "quantized", "use_pallas")

_scatter = partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=_STATIC)(_scatter_pure)


def _scatter_fleet_pure(pages, scales, g_sum, page_table, ids, valid,
                        updates, rng, *, page_size: int, n_clients: int,
                        dummy_lrow: int, quantized: bool, use_pallas: bool):
    """Batched (K-trial) paged scatter: pages (K, R, ...), page_table (K, P),
    ids/valid (K, C), rng (K, 2) — per trial bit-identical to
    `_scatter_pure`. The Pallas fp path uses the grid-axis batched kernel;
    everything else vmaps the per-trial body."""
    if use_pallas and not quantized:
        lids = jnp.where(ids >= n_clients, dummy_lrow, ids).astype(jnp.int32)
        from repro.kernels.ops import fleet_paged_bank_update_tree_pure
        pages_new, dsum = fleet_paged_bank_update_tree_pure(
            pages, updates, page_table, lids, valid, page_size=page_size)
        g_sum = jax.tree.map(jnp.add, g_sum, dsum)
        return pages_new, scales, g_sum
    body = partial(_scatter_pure, page_size=page_size, n_clients=n_clients,
                   dummy_lrow=dummy_lrow, quantized=quantized,
                   use_pallas=False)
    return jax.vmap(body)(pages, scales, g_sum, page_table, ids, valid,
                          updates, rng)


_scatter_fleet = partial(jax.jit, donate_argnums=(0, 1, 2),
                         static_argnames=_STATIC)(_scatter_fleet_pure)


class PagedDeviceBank(MemoryBank):
    """Bounded device memory, jit-native addressing; see module docstring.

    page_size : rows per page (power of two — the same capacity-bucket
                discipline the cohort padding uses, so page row ranges stay
                aligned for the kernels' index maps).
    n_slots   : device pages resident at once (None => enough for all of
                N, i.e. fully resident — still useful: the page table rides
                the carry and the scan path works unchanged).
    dtype     : "float32" | "bfloat16" | "int8". int8 reuses the stochastic
                rounding quantizer (per-physical-row absmax scales) and
                maintains G_sum over dequantized values, like Int8PagedBank.
    """

    jittable = True

    def __init__(self, *, page_size: int = 64, n_slots: int | None = None,
                 dtype: str = "float32", use_pallas: bool | None = None):
        if page_size <= 0 or page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got "
                             f"{page_size}")
        if n_slots is not None and n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.page_size = page_size
        self._n_slots_cfg = n_slots
        self.quantized = dtype == "int8"
        self.dtype = jnp.dtype(dtype)
        self._use_pallas = use_pallas
        self.n = 0
        self.n_slots = 0
        self.lp = 0            # logical pages holding real rows
        self.dummy_lrow = 0    # sanitized logical row for pad slots
        self.sentinel = 0      # page-table value meaning "not resident"
        # residency bookkeeping (host side, never traced)
        self._pt = np.zeros(0, np.int32)     # mirror of state["page_table"]
        self._slot_lp = np.zeros(0, np.int64)
        self._free: list[int] = []
        self._lru: dict[int, int] = {}
        self._clock = 0
        self._spill: dict[int, dict] = {}    # lp -> {"pages": [...], ...}
        self.faults = 0
        self.evictions = 0

    # ------------------------------------------------------------------ #
    def _pallas(self) -> bool:
        if self.quantized:
            return False                     # quantizer path is jnp-only
        if self._use_pallas is not None:
            return self._use_pallas
        from repro.kernels.backend import interpret_default
        return not interpret_default()

    def init(self, params, n_clients: int) -> dict:
        ps = self.page_size
        self.n = n_clients
        self.lp = -(-n_clients // ps)
        self.n_slots = (self.lp if self._n_slots_cfg is None
                        else self._n_slots_cfg)
        self.dummy_lrow = self.lp * ps
        self.sentinel = self.n_slots         # the dummy slot doubles as it
        n_rows = (self.n_slots + 1) * ps
        self._pt = np.full(self.lp + 1, self.sentinel, np.int32)
        self._pt[self.lp] = self.n_slots     # dummy logical page, pinned
        self._slot_lp = np.full(self.n_slots, -1, np.int64)
        self._free = list(range(self.n_slots - 1, -1, -1))   # pop() -> 0,1,..
        self._lru = {}
        self._clock = 0
        self._spill = {}
        self.faults = 0
        self.evictions = 0
        state = {
            "pages": jax.tree.map(
                lambda p: jnp.zeros((n_rows,) + p.shape, self.dtype), params),
            "page_table": jnp.asarray(self._pt),
            "g_sum": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        if self.quantized:
            state["scales"] = jax.tree.map(
                lambda p: jnp.zeros((n_rows,), jnp.float32), params)
        return state

    # ------------------------------------------------------------------ #
    # residency management — eager only, between jitted programs
    # ------------------------------------------------------------------ #

    def _is_fleet(self, state: dict) -> bool:
        return state["page_table"].ndim == 2

    def prepare(self, state: dict, ids) -> dict:
        """Make every logical page that `ids` touches device-resident.

        Eager (host-side): evicts deterministic-LRU victims to the spill
        store and uploads faulted pages (spilled data, or zeros for pages
        never written) in one batched device write per leaf. Returns the
        new state; a no-op (same state object) when everything is already
        resident. Raises when the working set cannot fit in `n_slots`.
        """
        ps = self.page_size
        ids = np.asarray(ids).reshape(-1)
        ids = ids[(ids >= 0) & (ids < self.n)]
        need = np.unique(ids // ps).astype(np.int64)
        if len(need) > self.n_slots:
            raise ValueError(
                f"cohort working set spans {len(need)} pages but "
                f"PagedDeviceBank has only {self.n_slots} slots "
                f"(page_size={ps}); raise n_slots, lower page_size, or — "
                "under engine='scan', where residency is per chunk union — "
                "lower scan_chunk")
        self._clock += 1
        for l in need:
            self._lru[int(l)] = self._clock
        missing = [int(l) for l in need if self._pt[l] == self.sentinel]
        if not missing:
            return state
        self.faults += len(missing)
        fleet = self._is_fleet(state)

        # 1) host bookkeeping: pick a slot per faulted page, evicting
        #    deterministic-LRU victims (oldest timestamp, ties by page id)
        needset = {int(l) for l in need}
        assign: list[tuple[int, int]] = []   # (lp, slot)
        evict: list[tuple[int, int]] = []    # (victim_lp, slot)
        for l in missing:
            if self._free:
                slot = self._free.pop()
            else:
                cands = [(t, lp_) for lp_, t in self._lru.items()
                         if self._pt[lp_] != self.sentinel
                         and lp_ not in needset]
                if not cands:
                    raise ValueError(
                        "no evictable page — all resident pages are in the "
                        "current working set (internal invariant violation)")
                _, victim = min(cands)
                slot = int(self._pt[victim])
                evict.append((victim, slot))
                self._pt[victim] = self.sentinel
                self._slot_lp[slot] = -1
                del self._lru[victim]
                self.evictions += 1
            assign.append((l, slot))

        pages_leaves, treedef = jax.tree.flatten(state["pages"])
        sc_leaves = (treedef.flatten_up_to(state["scales"])
                     if self.quantized else None)

        # 2) one batched device->host read for all evicted slots; the row
        #    batch is padded to a pow-2 page count with dummy-page reads
        #    (discarded below) so XLA sees few distinct gather shapes
        if evict:
            ev_rows = np.concatenate(
                [np.arange(s * ps, (s + 1) * ps) for _, s in evict]
                + [np.arange(self.n_slots * ps, (self.n_slots + 1) * ps)]
                * (_pow2_bucket(len(evict)) - len(evict)))
            ev_pages = [np.asarray(leaf[:, ev_rows] if fleet
                                   else leaf[ev_rows])
                        for leaf in pages_leaves]
            ev_scales = ([np.asarray(sc[:, ev_rows] if fleet else sc[ev_rows])
                          for sc in sc_leaves] if self.quantized else None)
            for k, (victim, _) in enumerate(evict):
                sl = (slice(None), slice(k * ps, (k + 1) * ps))
                blk = sl if fleet else sl[1]
                entry = {"pages": [p[blk].copy() for p in ev_pages]}
                if self.quantized:
                    entry["scales"] = [s[blk].copy() for s in ev_scales]
                self._spill[victim] = entry

        # 3) one batched host->device write for all faulted pages; pages
        #    with no spill entry (never written, or written only as zeros)
        #    upload zeros — REQUIRED, the slot may hold stale evicted data.
        #    The batch is padded to a pow-2 page count with zero writes to
        #    the dummy page (which is pinned to zero, so they are no-ops)
        #    to keep the number of distinct scatter shapes XLA compiles low.
        n_pad = _pow2_bucket(len(assign)) - len(assign)
        up_rows = np.concatenate(
            [np.arange(s * ps, (s + 1) * ps) for _, s in assign]
            + [np.arange(self.n_slots * ps, (self.n_slots + 1) * ps)] * n_pad)
        spilled = {l: self._spill.pop(l) for l, _ in assign
                   if l in self._spill}

        def upload(leaf, j, kind):
            blocks = []
            shape = ((leaf.shape[0], ps) + leaf.shape[2:] if fleet
                     else (ps,) + leaf.shape[1:])
            for l, _ in assign:
                sp = spilled.get(l)
                blocks.append(np.zeros(shape, leaf.dtype) if sp is None
                              else sp[kind][j])
            blocks += [np.zeros(shape, leaf.dtype)] * n_pad
            vals = np.concatenate(blocks, axis=1 if fleet else 0)
            idx = (slice(None), up_rows) if fleet else up_rows
            return leaf.at[idx].set(jnp.asarray(vals))

        new_pages = [upload(leaf, j, "pages")
                     for j, leaf in enumerate(pages_leaves)]
        new_state = dict(state)
        new_state["pages"] = jax.tree.unflatten(treedef, new_pages)
        if self.quantized:
            new_sc = [upload(sc, j, "scales")
                      for j, sc in enumerate(sc_leaves)]
            new_state["scales"] = jax.tree.unflatten(treedef, new_sc)

        for l, slot in assign:
            self._pt[l] = slot
            self._slot_lp[slot] = l
        pt_dev = jnp.asarray(self._pt)
        if fleet:
            pt_dev = jnp.broadcast_to(pt_dev, state["page_table"].shape)
        new_state["page_table"] = pt_dev
        return new_state

    # ------------------------------------------------------------------ #
    def gather(self, state: dict, ids):
        ids = jnp.asarray(ids, jnp.int32)
        lids = jnp.where(ids >= self.n, self.dummy_lrow, ids)
        phys = _phys_rows(state["page_table"], lids, self.page_size)
        if self.quantized:
            out = jax.tree.map(
                lambda r, sc: qm.dequantize_leaf(r[phys], sc[phys]),
                state["pages"], state["scales"])
        else:
            out = jax.tree.map(lambda r: r[phys].astype(jnp.float32),
                               state["pages"])
        if _traced((state, ids)) or self._is_fleet(state):
            # inside a trace `prepare` has already made the rows resident;
            # fleet states keep one shared residency map, same argument
            return out
        # eager: patch rows whose page currently lives in the spill store
        ids_np = np.asarray(ids)
        patch = [(c, int(i)) for c, i in enumerate(ids_np)
                 if 0 <= i < self.n and (i // self.page_size) in self._spill]
        if not patch:
            return out
        leaves, treedef = jax.tree.flatten(out)
        leaves = [np.array(leaf) for leaf in leaves]   # writable copies
        for c, i in patch:
            l, off = divmod(i, self.page_size)
            sp = self._spill[l]
            for j in range(len(leaves)):
                row = sp["pages"][j][off]
                if self.quantized:
                    row = row.astype(np.float32) * sp["scales"][j][off]
                leaves[j][c] = row
        return jax.tree.unflatten(treedef,
                                  [jnp.asarray(leaf) for leaf in leaves])

    def _scatter_rows(self, state: dict, ids, updates, *, valid,
                      rng=None) -> dict:
        if self.quantized:
            assert rng is not None, "int8 pages need an rng for rounding"
        traced = _traced((state, ids, updates))
        if not traced:
            ids_np = np.asarray(ids)
            valid_np = (np.ones(ids_np.shape, bool) if valid is None
                        else np.asarray(valid, bool))
            state = self.prepare(state, ids_np[valid_np])
        ids = jnp.asarray(ids, jnp.int32)
        valid = (jnp.ones(ids.shape, bool) if valid is None
                 else jnp.asarray(valid, bool))
        if rng is None:
            rng = jax.random.PRNGKey(0)      # unused on the fp paths
        fn = _scatter_pure if traced else _scatter
        pages, scales, g_sum = fn(
            state["pages"], state.get("scales"), state["g_sum"],
            state["page_table"], ids, valid, updates, rng,
            page_size=self.page_size, n_clients=self.n,
            dummy_lrow=self.dummy_lrow, quantized=self.quantized,
            use_pallas=self._pallas())
        new = {"pages": pages, "page_table": state["page_table"],
               "g_sum": g_sum}
        if self.quantized:
            new["scales"] = scales
        return new

    def scatter_fleet(self, state: dict, ids, updates, *, valid=None,
                      rng=None) -> dict:
        """Stacked-trial paged scatter: leaves (K, R, ...), page_table
        (K, P) — identical per-trial copies, one shared residency map (the
        union of all trials' cohorts is paged in together)."""
        if self.quantized:
            assert rng is not None, "int8 pages need an rng for rounding"
        traced = _traced((state, ids, updates))
        if not traced:
            ids_np = np.asarray(ids)
            valid_np = (np.ones(ids_np.shape, bool) if valid is None
                        else np.asarray(valid, bool))
            for k in range(ids_np.shape[0]):
                check_unique_ids(ids_np[k], valid_np[k])
            state = self.prepare(state, ids_np[valid_np])
        ids = jnp.asarray(ids, jnp.int32)
        valid = (jnp.ones(ids.shape, bool) if valid is None
                 else jnp.asarray(valid, bool))
        K = ids.shape[0]
        if rng is None:
            rngs = jnp.zeros((K, 2), jnp.uint32)   # unused on the fp paths
        else:
            rng = jnp.asarray(rng)
            # the fleet passes per-trial keys (K, 2); a single key is split
            rngs = rng if rng.ndim == 2 else jax.random.split(rng, K)
        fn = _scatter_fleet_pure if traced else _scatter_fleet
        pages, scales, g_sum = fn(
            state["pages"], state.get("scales"), state["g_sum"],
            state["page_table"], ids, valid, updates, rngs,
            page_size=self.page_size, n_clients=self.n,
            dummy_lrow=self.dummy_lrow, quantized=self.quantized,
            use_pallas=self._pallas())
        new = {"pages": pages, "page_table": state["page_table"],
               "g_sum": g_sum}
        if self.quantized:
            new["scales"] = scales
        return new

    def mean_g(self, state: dict):
        return jax.tree.map(lambda g: g / self.n, state["g_sum"])

    # ------------------------------------------------------------------ #
    def host_state(self) -> dict:
        """Serialise the host-side residency bookkeeping for a snapshot.

        The jit state (`pages` / `page_table` / `g_sum`) rides the run
        snapshot through `runner.state`; this captures its host mirrors —
        page-table mirror, slot ownership, the free list IN ORDER (slot
        assignment order is part of the trajectory), LRU stamps, fault
        counters, and every spilled page's bytes — so a resumed run pages
        exactly like the uninterrupted one.
        """
        lps = sorted(self._spill)
        tree = {
            "pt": self._pt, "slot_lp": self._slot_lp,
            "free": np.asarray(self._free, np.int64),
            "lru_keys": np.asarray(sorted(self._lru), np.int64),
            "lru_vals": np.asarray([self._lru[k] for k in sorted(self._lru)],
                                   np.int64),
            "clock": np.int64(self._clock),
            "faults": np.int64(self.faults),
            "evictions": np.int64(self.evictions),
            "spill_lp": np.asarray(lps, np.int64),
        }
        if lps:
            tree["spill"] = [self._spill[lp] for lp in lps]
        return tree

    def load_host_state(self, tree: dict) -> None:
        """Restore `host_state` bookkeeping (after `init`, before rounds)."""
        if not tree:
            return
        self._pt = np.asarray(tree["pt"], np.int32).copy()
        self._slot_lp = np.asarray(tree["slot_lp"], np.int64).copy()
        self._free = [int(s) for s in np.asarray(tree["free"])]
        self._lru = {int(k): int(v) for k, v in
                     zip(np.asarray(tree["lru_keys"]),
                         np.asarray(tree["lru_vals"]))}
        self._clock = int(tree["clock"])
        self.faults = int(tree["faults"])
        self.evictions = int(tree["evictions"])
        self._spill = {}
        for lp, entry in zip(np.asarray(tree["spill_lp"], np.int64),
                             tree.get("spill", [])):
            e = {"pages": [np.asarray(p) for p in entry["pages"]]}
            if "scales" in entry:
                e["scales"] = [np.asarray(s) for s in entry["scales"]]
            self._spill[int(lp)] = e

    def n_resident(self) -> int:
        return int((self._pt[:self.lp] != self.sentinel).sum())

    def memory_bytes(self, state: dict) -> dict:
        pages_b = sum(leaf.nbytes for leaf in jax.tree.leaves(state["pages"]))
        if self.quantized:
            pages_b += sum(leaf.nbytes
                           for leaf in jax.tree.leaves(state["scales"]))
        dev = pages_b + state["page_table"].nbytes
        dev += sum(leaf.nbytes for leaf in jax.tree.leaves(state["g_sum"]))
        host = sum(a.nbytes for e in self._spill.values()
                   for arrs in e.values() for a in arrs)
        # device_pages isolates the bounded allocation the paging bound is
        # stated over: (n_slots+1) pages x page_size x d, independent of N
        return {"device": dev, "host": host, "device_pages": pages_b}

    def check_invariants(self, state: dict | None = None) -> None:
        """Page-table invariants: no aliased slots, free-list conservation,
        mirror consistency, no page both resident and spilled; with `state`,
        also that the device table matches the mirror and the dummy page is
        exact zeros."""
        resident = {int(l): int(s) for l, s in enumerate(self._pt[:self.lp])
                    if s != self.sentinel}
        slots = list(resident.values())
        assert len(slots) == len(set(slots)), "aliased physical slots"
        assert all(0 <= s < self.n_slots for s in slots), "slot out of range"
        assert int(self._pt[self.lp]) == self.n_slots, "dummy page unpinned"
        assert len(self._free) + len(resident) == self.n_slots, \
            "free-list conservation violated"
        assert set(self._free).isdisjoint(slots), "slot both free and mapped"
        for l, s in resident.items():
            assert int(self._slot_lp[s]) == l, "slot->page mirror drifted"
        for s in self._free:
            assert int(self._slot_lp[s]) == -1, "free slot still mapped"
        assert set(self._spill).isdisjoint(resident), \
            "page both resident and spilled"
        if state is not None:
            pt = np.asarray(state["page_table"])
            fleet = pt.ndim == 2
            if fleet:
                assert (pt == pt[0]).all(), "fleet page tables diverged"
                pt = pt[0]
            assert (pt == self._pt).all(), "device page table != host mirror"
            start = self.n_slots * self.page_size
            for leaf in jax.tree.leaves(state["pages"]):
                dummy = np.asarray(leaf[:, start:] if fleet
                                   else leaf[start:])
                assert (dummy == 0).all(), "dummy page not zero"
