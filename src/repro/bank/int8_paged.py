"""Int8PagedBank — lazily-paged int8 rows + per-(row, leaf) absmax scales.

Reuses `core.quantized_memory`'s stochastic-rounding quantizer (the same
unbiasedness argument: the stored row stays an unbiased estimator of the true
update, which is what MIFA's analysis needs). Beyond the 4x dtype saving,
rows are allocated in fixed-size *pages* only when a client in that page
first participates — under production availability (|A(t)| ≪ N, long-tail
clients that never show up) the resident set is proportional to the number of
clients *ever seen*, not N.

Layout (host RAM, per parameter leaf):
    pages[leaf][p] = int8  (page_size, *leaf_shape)   quantized rows
    scales[leaf][p] = f32  (page_size,)               absmax / 127 per row
A missing page reads as exact zeros (every client's initial G^i = 0, scale 0
=> dequantizes to 0 exactly, matching the fp32 banks at init).

G_sum is maintained in f32 over *dequantized* values, so the invariant
G_sum == Σ_i dequant(row_i) holds exactly (modulo fp summation order) and
mean_g is consistent with what gather returns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank.base import MemoryBank
from repro.core import quantized_memory as qm


class Int8PagedBank(MemoryBank):
    jittable = False

    def __init__(self, *, page_size: int = 1024):
        assert page_size > 0
        self.page_size = page_size
        self.n = 0

    # ------------------------------------------------------------------ #
    def init(self, params, n_clients: int) -> dict:
        self.n = n_clients
        leaves, treedef = jax.tree.flatten(params)
        return {
            "treedef": treedef,
            "shapes": [tuple(leaf.shape) for leaf in leaves],
            "pages": [{} for _ in leaves],    # page idx -> int8 rows
            "scales": [{} for _ in leaves],   # page idx -> f32 scales
            "g_sum": [np.zeros(tuple(leaf.shape), np.float32)
                      for leaf in leaves],
        }

    def _rows(self, state: dict, li: int, ids: np.ndarray) -> np.ndarray:
        """Dequantized rows (len(ids), *shape) for leaf li; zeros if unseen."""
        shape = state["shapes"][li]
        out = np.zeros((len(ids),) + shape, np.float32)
        pages, scales = state["pages"][li], state["scales"][li]
        for k, i in enumerate(ids):
            p, off = divmod(int(i), self.page_size)
            if p in pages:
                sc = scales[p][off]
                out[k] = pages[p][off].astype(np.float32) * sc
        return out

    def gather(self, state: dict, ids):
        ids = np.asarray(ids, np.int64)
        leaves = [jnp.asarray(self._rows(state, li, ids))
                  for li in range(len(state["shapes"]))]
        return jax.tree.unflatten(state["treedef"], leaves)

    def _scatter_rows(self, state: dict, ids, updates, *, valid=None,
                      rng=None) -> dict:
        assert rng is not None, "int8 bank needs an rng for rounding"
        ids = np.asarray(ids, np.int64)
        keep = (np.ones(ids.shape, bool) if valid is None
                else np.asarray(valid, bool))
        ids = ids[keep]
        if ids.size == 0:    # empty round (e.g. a blackout under Impatient)
            return state
        u_leaves, treedef = jax.tree.flatten(updates)
        assert treedef == state["treedef"], (treedef, state["treedef"])
        rngs = jax.random.split(rng, len(u_leaves))

        for li, u in enumerate(u_leaves):
            u = jnp.asarray(u, jnp.float32)[np.flatnonzero(keep)]
            q, s = qm.quantize_leaf(rngs[li], u)
            q, s = np.asarray(q), np.asarray(s, np.float32)
            # what the bank will answer for these rows from now on
            u_eff = q.astype(np.float32) * s.reshape((-1,) + (1,) * (q.ndim - 1))
            old = self._rows(state, li, ids)
            state["g_sum"][li] += (u_eff - old).sum(axis=0, dtype=np.float32)
            pages, scales = state["pages"][li], state["scales"][li]
            shape = state["shapes"][li]
            for k, i in enumerate(ids):
                p, off = divmod(int(i), self.page_size)
                if p not in pages:
                    pages[p] = np.zeros((self.page_size,) + shape, np.int8)
                    scales[p] = np.zeros((self.page_size,), np.float32)
                pages[p][off] = q[k]
                scales[p][off] = s[k]
        return state

    def mean_g(self, state: dict):
        leaves = [jnp.asarray(g / self.n) for g in state["g_sum"]]
        return jax.tree.unflatten(state["treedef"], leaves)

    # ------------------------------------------------------------------ #
    def n_pages(self, state: dict) -> int:
        return max((len(p) for p in state["pages"]), default=0)

    def memory_bytes(self, state: dict) -> dict:
        host = sum(a.nbytes for leaf in state["pages"] for a in leaf.values())
        host += sum(a.nbytes for leaf in state["scales"]
                    for a in leaf.values())
        host += sum(g.nbytes for g in state["g_sum"])
        return {"device": 0, "host": host}
