"""HostBank — fp32 rows in host RAM; zero device memory for the bank.

The O(N·d) memory lives where it is cheapest (host DRAM); only the cohort's
rows ever cross the host↔device boundary: updates (|A|, d) come down once per
round, mean_G (d,) goes up once per round. Gather/scatter are numpy fancy
indexing — O(|A|·d) — and G_sum is maintained with the same delta identity as
every other backend, so host rounds are exactly equivalent to DenseBank
rounds (fp32, modulo summation order).

State arrays are mutated in place (numpy), but the state dict itself is
returned fresh each scatter to keep the backend-agnostic "new state" calling
convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank.base import MemoryBank


class HostBank(MemoryBank):
    jittable = False

    def __init__(self):
        self.n = 0

    def init(self, params, n_clients: int) -> dict:
        self.n = n_clients
        rows = jax.tree.map(
            lambda p: np.zeros((n_clients,) + tuple(p.shape), np.float32),
            params)
        g_sum = jax.tree.map(
            lambda p: np.zeros(tuple(p.shape), np.float32), params)
        return {"rows": rows, "g_sum": g_sum}

    def gather(self, state: dict, ids):
        ids = np.asarray(ids, np.int64)
        return jax.tree.map(lambda r: jnp.asarray(r[ids]), state["rows"])

    def _scatter_rows(self, state: dict, ids, updates, *, valid=None,
                      rng=None) -> dict:
        ids = np.asarray(ids, np.int64)
        if valid is None:
            keep = np.ones(ids.shape, bool)
        else:
            keep = np.asarray(valid, bool)
        ids = ids[keep]

        def one(r, gs, u):
            u = np.asarray(u, np.float32)[keep]        # cohort rows only
            gs += (u - r[ids]).sum(axis=0, dtype=np.float32)
            r[ids] = u

        jax.tree.map(one, state["rows"], state["g_sum"], updates)
        return {"rows": state["rows"], "g_sum": state["g_sum"]}

    def mean_g(self, state: dict):
        return jax.tree.map(lambda g: jnp.asarray(g / self.n),
                            state["g_sum"])

    def memory_bytes(self, state: dict) -> dict:
        host = sum(leaf.nbytes for leaf in jax.tree.leaves(state["rows"]))
        host += sum(leaf.nbytes for leaf in jax.tree.leaves(state["g_sum"]))
        return {"device": 0, "host": host}
