"""BankedMIFA — MIFA driven through a MemoryBank: O(|A(t)|·d) rounds.

Mathematically identical to `core.mifa.MIFA(memory="array")` (property-tested
in tests/test_bank.py): each round the cohort's fresh updates replace their
stored rows, and the server moves by η · G_sum / N. The difference is purely
operational — the round only ever *touches* cohort rows, so compute, memory
traffic, and (for the paged backend) resident memory scale with the cohort,
not with N.

`RoundRunner` detects `cohort_based = True` and switches to the compact round
path: `client_updates` runs on (|A|, ...) batches and this class applies them
through the bank. The synchronous `run_fl` loop and the discrete-event
`sim.engine.FedSimEngine` both drive that path unchanged (they only ever see
`runner.step(t, mask)`).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.bank.base import MemoryBank


class BankedMIFA:
    """memory-bank MIFA; `bank` picks the storage backend."""

    cohort_based = True
    # same regime as MIFA: memorisation, no availability-law knowledge
    assumes = "arbitrary"

    def __init__(self, bank: MemoryBank):
        self.bank = bank

    def init_state(self, params, n_clients: int) -> dict:
        return {"bank": self.bank.init(params, n_clients),
                "t": jnp.zeros((), jnp.int32)}

    def prepare_cohort(self, state: dict, ids) -> dict:
        """Eager residency hook: page in the rows `ids` (concrete, real
        client ids) before the jitted round / chunk runs. Identity for
        non-paging backends (MemoryBank.prepare default)."""
        return {**state, "bank": self.bank.prepare(state["bank"], ids)}

    def round_step_cohort(self, state: dict, ids, valid, updates, losses,
                          rng=None):
        """ids (C,) padded row indices; valid (C,) mask; updates/losses for
        the padded cohort. Returns (new_state, mean_G, metrics)."""
        bank_state = self.bank.scatter(state["bank"], ids, updates,
                                       valid=valid, rng=rng)
        mean_g = self.bank.mean_g(bank_state)
        v = jnp.asarray(valid, jnp.float32)
        loss = jnp.sum(jnp.asarray(losses) * v) / jnp.maximum(jnp.sum(v), 1.0)
        metrics = {"loss": loss, "n_active": jnp.sum(v)}
        return ({"bank": bank_state, "t": state["t"] + 1}, mean_g, metrics)

    def round_step_cohort_fleet(self, state: dict, ids, valid, updates,
                                losses, rng=None):
        """Stacked-trial cohort round: ids/valid (K, C), update leaves
        (K, C, ...), losses (K, C). Same math as `round_step_cohort` per
        trial — the bank applies all K scatters in one batched call
        (vmapped jnp or the grid-axis Pallas kernel) and the loss/metric
        reductions run along axis 1. Returns (new_state, mean_G (K, ...),
        metrics with (K,) leaves). Jittable banks only."""
        bank_state = self.bank.scatter_fleet(state["bank"], ids, updates,
                                             valid=valid, rng=rng)
        mean_g = self.bank.mean_g(bank_state)     # elementwise: (K, ...) ok
        v = jnp.asarray(valid, jnp.float32)
        loss = (jnp.sum(jnp.asarray(losses) * v, axis=1)
                / jnp.maximum(jnp.sum(v, axis=1), 1.0))
        metrics = {"loss": loss, "n_active": jnp.sum(v, axis=1)}
        return ({"bank": bank_state, "t": state["t"] + 1}, mean_g, metrics)
