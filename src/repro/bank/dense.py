"""DenseBank — on-device jnp rows; the exact-equivalence reference backend.

State layout:
    rows  : pytree, leaves (N+1, *param_shape) `dtype` — row N is the dummy
            row that padded cohort slots scatter into (a no-op write).
    g_sum : pytree, leaves (*param_shape,) f32 — running Σ_{i<N} rows[i].

`scatter` is one jitted call (buffers donated, so the rows update in place on
backends that support donation). Two implementations, property-tested against
each other:
  * jnp reference — gather + masked delta + `.at[ids].set`;
  * fused Pallas  — `kernels.bank_scatter` streams only the cohort rows
    through VMEM (use_pallas=True, or auto on real TPUs).

With `mesh`/`cfg` given, rows are laid out with `sharding.rules.bank_row_specs`
— the client axis sharded over the mesh's data (and pod) axes, exactly like
the dense MIFA update array. The row count is padded up so the client axis
divides the mesh (sharding.rules.padded_bank_rows).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank.base import MemoryBank, broadcast_valid, check_unique_ids


def _scatter_jnp(rows, g_sum, ids, valid, updates):
    """The jnp gather/delta/scatter body — pure, so the fleet executor can
    vmap it over a leading trial axis (the SAME code as the per-trial path)."""
    def one(r, u, gs):
        old = r[ids]                                   # (C, ...) r.dtype
        u_st = u.astype(r.dtype)
        vb = broadcast_valid(valid, u)
        delta = jnp.where(vb, u_st.astype(jnp.float32)
                          - old.astype(jnp.float32), 0.0)
        r_new = r.at[ids].set(jnp.where(vb, u_st, old))
        return r_new, gs + jnp.sum(delta, axis=0)

    out = jax.tree.map(one, rows, updates, g_sum)
    rows_new = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda o: isinstance(o, tuple))
    g_new = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    return rows_new, g_new


def _scatter_pure(rows, g_sum, ids, valid, updates, *, use_pallas: bool):
    """Scatter body with no jit wrapper — scan/vmap/jit-trace safe."""
    if use_pallas:
        from repro.kernels.ops import bank_update_tree_pure
        rows_new, dsum = bank_update_tree_pure(rows, updates, ids, valid)
        g_sum = jax.tree.map(jnp.add, g_sum, dsum)
        return rows_new, g_sum
    return _scatter_jnp(rows, g_sum, ids, valid, updates)


_scatter = partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=("use_pallas",))(_scatter_pure)


def _scatter_fleet_pure(rows, g_sum, ids, valid, updates, *,
                        use_pallas: bool):
    """Batched (K-trial) scatter: rows (K, R, ...), ids/valid (K, C).

    use_pallas routes to the grid-axis batched kernel
    (`kernels.bank_scatter_batched`); otherwise the per-trial jnp body is
    vmapped — bit-identical per trial to the sequential `_scatter`.
    """
    if use_pallas:
        from repro.kernels.ops import fleet_bank_update_tree_pure
        rows_new, dsum = fleet_bank_update_tree_pure(rows, updates, ids,
                                                     valid)
        g_sum = jax.tree.map(jnp.add, g_sum, dsum)
        return rows_new, g_sum
    return jax.vmap(_scatter_jnp)(rows, g_sum, ids, valid, updates)


_scatter_fleet = partial(jax.jit, donate_argnums=(0, 1),
                         static_argnames=("use_pallas",))(_scatter_fleet_pure)


def _traced(tree) -> bool:
    """True when any leaf is abstract — i.e. we are already inside a jit /
    scan / vmap trace, where the jitted+donating wrappers must be bypassed
    (donation inside a trace is meaningless and a nested jit only costs an
    extra dispatch layer)."""
    import jax.core
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree.leaves(tree))


class DenseBank(MemoryBank):
    jittable = True

    def __init__(self, *, dtype: str = "float32",
                 use_pallas: bool | None = None, mesh=None, cfg=None):
        self.dtype = jnp.dtype(dtype)
        self._use_pallas = use_pallas
        self.mesh = mesh
        self.cfg = cfg
        self.n = 0
        self.n_rows = 0

    # ------------------------------------------------------------------ #
    def _pallas(self) -> bool:
        from repro.kernels.backend import (interpret_default,
                                           pallas_partition_safe)
        # a pallas_call is a single-device program with no SPMD partitioning
        # rule — under a >1-device mesh the jnp scatter bodies (which lower
        # to collectives) are the only safe path, even when forced
        if not pallas_partition_safe(self.mesh):
            return False
        if self._use_pallas is not None:
            return self._use_pallas
        # interpret-mode Pallas is orders of magnitude slower than jnp on
        # CPU; only take the kernel path when it would actually compile.
        return not interpret_default()

    def init(self, params, n_clients: int) -> dict:
        self.n = n_clients
        if self.mesh is not None:
            from repro.sharding.rules import padded_bank_rows
            self.n_rows = padded_bank_rows(n_clients, self.mesh)
        else:
            self.n_rows = n_clients + 1
        rows = jax.tree.map(
            lambda p: jnp.zeros((self.n_rows,) + p.shape, self.dtype), params)
        g_sum = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from repro.sharding.rules import bank_row_specs
            specs = bank_row_specs(params, self.cfg, self.mesh,
                                   n_rows=self.n_rows)
            rows = jax.tree.map(
                lambda r, s: jax.device_put(r, NamedSharding(self.mesh, s)),
                rows, specs)
        return {"rows": rows, "g_sum": g_sum}

    def gather(self, state: dict, ids):
        ids = jnp.asarray(ids, jnp.int32)
        return jax.tree.map(lambda r: r[ids].astype(jnp.float32),
                            state["rows"])

    def _scatter_rows(self, state: dict, ids, updates, *, valid,
                      rng=None) -> dict:
        ids = jnp.asarray(ids, jnp.int32)
        valid = (jnp.ones(ids.shape, bool) if valid is None
                 else jnp.asarray(valid, bool))
        fn = _scatter_pure if _traced((state, ids, updates)) else _scatter
        rows, g_sum = fn(state["rows"], state["g_sum"], ids, valid,
                         updates, use_pallas=self._pallas())
        return {"rows": rows, "g_sum": g_sum}

    def scatter_fleet(self, state: dict, ids, updates, *, valid=None,
                      rng=None) -> dict:
        """Stacked-trial scatter: state leaves (K, R, ...), ids/valid (K, C).

        The Pallas path runs the batched kernel (trial axis = outermost grid
        dim); the jnp path vmaps the identical per-trial body."""
        import jax.core
        if not isinstance(ids, jax.core.Tracer):
            ids_np = np.asarray(ids)
            valid_np = None if valid is None else np.asarray(valid)
            for k in range(ids_np.shape[0]):
                check_unique_ids(ids_np[k],
                                 None if valid_np is None else valid_np[k])
        ids = jnp.asarray(ids, jnp.int32)
        valid = (jnp.ones(ids.shape, bool) if valid is None
                 else jnp.asarray(valid, bool))
        fn = (_scatter_fleet_pure if _traced((state, ids, updates))
              else _scatter_fleet)
        rows, g_sum = fn(state["rows"], state["g_sum"], ids, valid,
                         updates, use_pallas=self._pallas())
        return {"rows": rows, "g_sum": g_sum}

    def mean_g(self, state: dict):
        return jax.tree.map(lambda g: g / self.n, state["g_sum"])

    def memory_bytes(self, state: dict) -> dict:
        dev = sum(leaf.nbytes for leaf in jax.tree.leaves(state["rows"]))
        dev += sum(leaf.nbytes for leaf in jax.tree.leaves(state["g_sum"]))
        return {"device": dev, "host": 0}
