"""MemoryBank — sparse server memory for cohort-sized MIFA rounds.

MIFA's server state is one row per client: G^i, the client's latest K-step
update. The dense implementation (core.mifa) rewrites the whole (N, d) array
every round; a MemoryBank exposes the same state through row-sparse access so
a round touches only the active cohort A(t):

    gather(state, ids)            -> the cohort's stored rows (|A|, ...)
    scatter(state, ids, updates)  -> new state with those rows replaced

and maintains the running sum  G_sum = Σ_i G^i  incrementally via the delta
identity (docs/architecture.md §3)

    G_sum += Σ_{a ∈ A} (u_a − G_old_a)

so the server step's  mean_G = G_sum / N  is O(d), never O(N·d). A cohort
round is therefore O(|A|·d) compute + traffic regardless of N.

Backends (bank/__init__.py `make_bank`):
  * DenseBank     — jnp (N+1, ...) rows on device; exact reference; jittable;
                    optional fused Pallas gather/delta/scatter path; rows can
                    be sharded over the mesh's client/data axes.
  * HostBank      — fp32 rows in host RAM (numpy); only cohort rows cross the
                    host↔device boundary; zero device memory for the bank.
  * Int8PagedBank — host-resident int8 rows + per-(row, leaf) absmax scales
                    (core.quantized_memory), allocated lazily in fixed-size
                    pages: clients that never participated cost nothing.

Padding convention: drivers pad a variable-size cohort to a fixed capacity so
jit traces are reused. Pad slots carry `valid=False` and point `ids` at the
dummy row index N (DenseBank allocates N+1 rows; host backends simply drop
invalid slots). Pad slots never touch G_sum or any real row.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np


class MemoryBank:
    """Interface; see backend modules for the concrete layouts.

    `init` must be called exactly once per training run — backends are cheap
    config holders until then and remember `n_clients` afterwards.

    `scatter` is a template method: it enforces the duplicate-id invariant
    (`check_unique_ids`) for EVERY backend at a single point, then delegates
    to the backend's `_scatter_rows`. Backends must not re-implement
    `scatter` — that is how the host/int8 paths once drifted out from under
    the check the dense path had.
    """

    #: True when `scatter` consumes/produces jnp pytrees and may run under jit.
    jittable: bool = False

    def init(self, params: Any, n_clients: int) -> dict:
        """Zero-filled bank state for `n_clients` rows shaped like `params`."""
        raise NotImplementedError

    def gather(self, state: dict, ids) -> Any:
        """Read rows `ids` (C,) out of the bank `state`: an f32 pytree
        with leading axis C = len(ids). Never mutates the state."""
        raise NotImplementedError

    def scatter(self, state: dict, ids, updates, *, valid=None,
                rng=None) -> dict:
        """Write the cohort's fresh updates and maintain G_sum.

        ids (C,) int row indices; updates: f32 pytree, leaves (C, ...);
        valid (C,) bool (None => all valid); rng only for quantizing backends.
        Returns the new state (the old one must not be reused).
        """
        check_unique_ids(ids, valid)
        return self._scatter_rows(state, ids, updates, valid=valid, rng=rng)

    def _scatter_rows(self, state: dict, ids, updates, *, valid,
                      rng) -> dict:
        """Backend scatter body; `scatter` has already validated the ids."""
        raise NotImplementedError

    def prepare(self, state: dict, ids) -> dict:
        """Eager pre-round residency hook: make the rows `ids` (real client
        ids, already de-padded) cheap to access before a jitted program
        runs. The default is the identity — only backends that page rows
        on/off the device (PagedDeviceBank) override it. Drivers call it
        per round (dispatch loop, fleet) or per chunk union (scan engine's
        pipelined pre-chunk hook) with concrete numpy ids — never under a
        trace."""
        return state

    def host_state(self) -> dict:
        """Host-side bookkeeping to persist in a run snapshot.

        Backends whose correctness depends on state OUTSIDE the jit state
        pytree (PagedDeviceBank's residency mirrors, LRU clocks, spilled
        pages) return it here as a pytree of arrays, consumed by
        `checkpoint.run_state.save_run`. The default is empty: for fully
        in-jit backends (DenseBank) the snapshot's `runner.state` already
        holds everything.
        """
        return {}

    def load_host_state(self, tree: dict) -> None:
        """Restore what `host_state` returned (checkpoint resume hook).

        Must be called after `init` (the bank's shapes exist) and before
        the first round of the resumed run. The default is a no-op.
        """
        del tree

    def mean_g(self, state: dict) -> Any:
        """G_sum / N as a device (jnp) pytree with param-shaped leaves."""
        raise NotImplementedError

    def memory_bytes(self, state: dict) -> dict:
        """{'device': bytes, 'host': bytes} currently held by the bank."""
        raise NotImplementedError

    # ------------------------------------------------------------------ #
    # fleet (leading trial axis) — jittable backends only
    # ------------------------------------------------------------------ #

    def _require_fleet(self) -> None:
        if not self.jittable:
            raise NotImplementedError(
                f"{type(self).__name__} is host-offloaded (jittable=False): "
                "its rows live outside jit, so it cannot run under the "
                "vmapped fleet path (docs/architecture.md §7). Jittable "
                "backends — DenseBank ('dense') and PagedDeviceBank "
                "('paged_device') — support the fleet; otherwise run trials "
                "sequentially")

    def gather_fleet(self, state: dict, ids) -> Any:
        """Batched gather over stacked trial `state`: leaves (K, N+1, ...),
        `ids` (K, C) -> rows (K, C, ...). Gather has no rng, so the vmapped
        per-trial gather is the correct default for any jittable backend."""
        self._require_fleet()
        import jax
        return jax.vmap(self.gather)(state, ids)

    def scatter_fleet(self, state: dict, ids, updates, *, valid=None,
                      rng=None) -> dict:
        """Batched scatter over stacked trial `state`: `ids`/`valid`
        (K, C), `updates` leaves (K, C, ...) -> new stacked state, with
        per-trial G_sum maintenance. Jittable backends must override —
        `rng` threading is backend-specific (a quantizing backend must
        give each trial its OWN stream, never one shared key): DenseBank."""
        self._require_fleet()
        raise NotImplementedError(
            f"{type(self).__name__} is jittable but does not implement the "
            "batched fleet scatter (rng threading is backend-specific); "
            "backends that do: DenseBank, PagedDeviceBank")


def broadcast_valid(valid: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """valid (C,) -> broadcastable to leaf (C, ...)."""
    return valid.reshape((valid.shape[0],) + (1,) * (leaf.ndim - 1))


def check_unique_ids(ids, valid=None) -> None:
    """Reject duplicate *valid* ids in one scatter call.

    With duplicates, each copy's delta is computed against the original row
    but only one write survives — G_sum would silently diverge from the sum
    of rows forever after. Cohorts are sets; samplers drawing with
    replacement must np.unique first (see benchmarks/bank_scale.py).

    Best-effort eager validation only: under a jit trace (DenseBank is
    jittable) ids are abstract and the check is skipped.
    """
    import jax.core
    if isinstance(ids, jax.core.Tracer):
        return
    ids = np.asarray(ids)
    if valid is not None:
        ids = ids[np.asarray(valid, bool)]
    if len(np.unique(ids)) != len(ids):
        raise ValueError(
            "duplicate client ids in one scatter call would corrupt G_sum; "
            "deduplicate the cohort (np.unique) before applying it")
