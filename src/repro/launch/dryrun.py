import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------- #
# Multi-pod dry-run driver (deliverable e).
#
# For every (architecture x input shape) pair, lower + compile the appropriate
# step (train_step for train shapes, serve_step for prefill/decode) on the
# production mesh — 16x16 single-pod and 2x16x16 multi-pod — and record
# memory_analysis / cost_analysis / parsed collective schedule into JSON
# artifacts consumed by EXPERIMENTS.md §Dry-run and §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
#       --shape train_4k --mesh pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
# --------------------------------------------------------------------------- #

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import Skip, plan
from repro.models import build_model
from repro.roofline.analysis import (HW, analyze_compiled, model_flops,
                                     roofline_terms)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def count_params(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts from abstract shapes (no allocation)."""
    import numpy as np
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0
    inactive = 0

    def walk(tree, path):
        nonlocal total, inactive
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
            return
        n = int(np.prod(tree.shape))
        total += n
        if "moe" in path and path[-1] in ("w1", "w2", "w3"):
            frac = 1.0 - cfg.top_k / cfg.n_experts
            inactive += int(n * frac)
        elif path[-1] == "embed":
            inactive += n  # table lookup, not a matmul: no 2/6 flops-per-param

    walk(params, ())
    return total, total - inactive


def run_one(arch: str, shape_name: str, mesh_kind: str, *, out_dir: str,
            overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    p = plan(arch, shape_name, mesh, **(overrides or {}))
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    if overrides:
        tag += "__" + "_".join(f"{k}-{v}" for k, v in sorted(overrides.items()))
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "overrides": overrides or {}}
    if isinstance(p, Skip):
        record["status"] = "skip"
        record["reason"] = p.reason
        _save(out_dir, tag, record)
        print(f"[skip] {tag}: {p.reason}")
        return record

    try:
        t0 = time.time()
        jitted = jax.jit(p.fn, in_shardings=p.in_shardings,
                         out_shardings=p.out_shardings,
                         donate_argnums=p.donate_argnums)
        lowered = jitted.lower(*p.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        analysis = analyze_compiled(compiled)
        shape = INPUT_SHAPES[shape_name]
        total, active = count_params(arch)
        n_chips = mesh.devices.size
        mf = model_flops(get_config(arch), total, active, shape, p.kind)
        terms = roofline_terms(analysis)
        hlo_flops_global = max(analysis["hlo_flops_parsed"],
                               analysis["cost_analysis_flops"]) * n_chips

        record.update({
            "status": "ok",
            "kind": p.kind,
            "meta": p.meta,
            "n_chips": n_chips,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "params_total": total,
            "params_active": active,
            "analysis": analysis,
            "roofline": terms,
            "model_flops": mf,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": (mf / hlo_flops_global
                                   if hlo_flops_global else None),
            "hw": HW,
        })
        mem = analysis["memory"]
        print(f"[ok]   {tag}: compile={t2 - t1:.0f}s "
              f"mem/chip={mem['peak_estimate_bytes'] / 1e9:.2f}GB "
              f"bottleneck={terms['bottleneck']} "
              f"t>={terms['step_time_lower_bound_s'] * 1e3:.1f}ms "
              f"useful={record['useful_flops_ratio'] and round(record['useful_flops_ratio'], 3)}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
    _save(out_dir, tag, record)
    return record


def _save(out_dir: str, tag: str, record: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--memory-dtype", default=None)
    ap.add_argument("--sequential-clients", default=None,
                    choices=["true", "false"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--ce-chunk", type=int, default=None)
    ap.add_argument("--fsdp", default=None, choices=["true", "false"])
    ap.add_argument("--pad-heads", action="store_true")
    ap.add_argument("--inner-update-constraint", action="store_true")
    ap.add_argument("--seq-shard-prefill", action="store_true")
    args = ap.parse_args()

    overrides = {}
    if args.memory_dtype:
        overrides["memory_dtype"] = args.memory_dtype
    if args.sequential_clients:
        overrides["sequential_clients"] = args.sequential_clients == "true"
    if args.capacity_factor:
        overrides["moe_capacity_factor"] = args.capacity_factor
    if args.ce_chunk is not None:
        overrides["ce_chunk"] = args.ce_chunk
    if args.fsdp:
        overrides["fsdp"] = args.fsdp == "true"
    if args.pad_heads:
        overrides["pad_heads"] = True
    if args.inner_update_constraint:
        overrides["inner_update_constraint"] = True
    if args.seq_shard_prefill:
        overrides["seq_shard_prefill"] = True

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                results.append(run_one(arch, shape, mesh_kind,
                                       out_dir=args.out,
                                       overrides=overrides or None))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    fail = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run summary: {ok} ok / {skip} skip / {fail} fail ==")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
