"""Dry-run planning: ShapeDtypeStruct inputs + shardings per (arch x shape).

`plan(arch, shape, mesh)` returns a DryrunPlan (step fn, abstract args,
in/out shardings, donated args) ready for `.lower().compile()`, or a Skip
with the documented reason (docs/architecture.md §4): encoder-only archs have no decode;
long_500k only runs for sub-quadratic-capable archs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ArchConfig, get_config
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.sharding import rules


@dataclass
class Skip:
    arch: str
    shape: str
    reason: str


@dataclass
class DryrunPlan:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple          # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _param_sds(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _train_batch(cfg: ArchConfig, n: int, K: int, mb: int, S: int,
                 compute_dtype):
    if cfg.modality == "vision_text":
        text = S - cfg.n_patches
        return {"tokens": _sds((n, K, mb, text), jnp.int32),
                "patches": _sds((n, K, mb, cfg.n_patches, cfg.d_model),
                                compute_dtype)}
    if cfg.modality == "audio":
        return {"frames": _sds((n, K, mb, S, cfg.d_model), compute_dtype),
                "labels": _sds((n, K, mb, S), jnp.int32)}
    return {"tokens": _sds((n, K, mb, S), jnp.int32)}


def _serve_batch(cfg: ArchConfig, B: int, S: int, compute_dtype):
    if cfg.modality == "vision_text":
        return {"tokens": _sds((B, S - cfg.n_patches), jnp.int32),
                "patches": _sds((B, cfg.n_patches, cfg.d_model),
                                compute_dtype)}
    if cfg.modality == "audio":
        return {"frames": _sds((B, S, cfg.d_model), compute_dtype),
                "labels": _sds((B, S), jnp.int32)}
    return {"tokens": _sds((B, S), jnp.int32)}


def plan(arch: str, shape_name: str, mesh, *,
         memory_dtype: str | None = None,
         sequential_clients: bool | None = None,
         moe_capacity_factor: float | None = None,
         ce_chunk: int | None = None,
         fsdp: bool | None = None,
         pad_heads: bool | None = None,
         inner_update_constraint: bool | None = None,
         seq_shard_prefill: bool | None = None):
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if memory_dtype is not None:
        cfg = cfg.replace(memory_dtype=memory_dtype)
    if sequential_clients is not None:
        cfg = cfg.replace(sequential_clients=sequential_clients)
    if moe_capacity_factor is not None:
        cfg = cfg.replace(moe_capacity_factor=moe_capacity_factor)
    if ce_chunk is not None:
        cfg = cfg.replace(ce_chunk=ce_chunk)
    if fsdp is not None:
        cfg = cfg.replace(fsdp=fsdp)
    if pad_heads:
        up = lambda n: ((n + 15) // 16) * 16
        cfg = cfg.replace(pad_q_heads=up(cfg.n_heads),
                          pad_kv_heads=up(cfg.n_kv_heads))

    # ---- documented skips (docs/architecture.md §4) ----
    if shape.kind == "decode" and not cfg.supports_decode:
        return Skip(arch, shape_name,
                    "encoder-only architecture: no autoregressive decode")
    if shape_name == "long_500k" and not cfg.supports_long_decode:
        return Skip(arch, shape_name,
                    "long_500k requires sub-quadratic attention; "
                    f"{arch} is full-attention")
    if shape_name == "long_500k" and cfg.family == "hybrid":
        # window the shared attention block for the long-context mode
        cfg = cfg.replace(shared_attn_window=4096)

    dax = rules.data_axes(mesh)
    n_data = 1
    for a in dax:
        n_data *= mesh.shape[a]

    model = build_model(cfg)
    compute_dtype = model.compute_dtype
    params_sds = _param_sds(model)
    pspecs = rules.param_specs(params_sds, cfg, mesh)

    if shape.kind == "train":
        seq = cfg.sequential_clients
        n = cfg.fl_clients if seq else n_data
        K = cfg.fl_local_steps
        mb = shape.global_batch // (n * K)
        assert mb >= 1, (arch, shape_name, n, K)
        mem_dt = jnp.dtype(cfg.memory_dtype)
        G_sds = jax.tree.map(lambda p: _sds((n,) + p.shape, mem_dt),
                             params_sds)
        gspecs = rules.client_state_specs(params_sds, cfg, mesh,
                                          sequential_clients=seq,
                                          n_clients=n)
        batch_sds = _train_batch(cfg, n, K, mb, shape.seq_len, compute_dtype)
        bspecs = rules.batch_specs(batch_sds, mesh, client_axis=True,
                                   sequential_clients=seq)
        active_sds = _sds((n,), jnp.bool_)
        eta_sds = _sds((), jnp.float32)
        # NOTE (§Perf H2): constraining per-client updates to the 2-D G
        # sharding *inside* the client scan makes XLA re-shard activations to
        # d-over-data with the minibatch replicated — attention/MLP partial
        # sums then all-reduce at activation size (31.8 TB/chip for llava).
        # The G out_shardings already enforce final placement; the update
        # constraint stays off by default (flag kept for the §Perf A/B).
        update_spec = None
        if inner_update_constraint is None:
            inner_update_constraint = cfg.inner_update_constraint
        if seq and inner_update_constraint:
            update_spec = _ns(mesh, rules.param_specs(
                params_sds, cfg.replace(fsdp=True), mesh))
        fn = steps_lib.make_train_step(model, cfg, n, K,
                                       update_spec=update_spec)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, gspecs), _ns(mesh, bspecs),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, gspecs),
                  {"loss": NamedSharding(mesh, P())})
        return DryrunPlan(
            arch, shape_name, "train", fn,
            (params_sds, G_sds, batch_sds, active_sds, eta_sds),
            in_sh, out_sh, donate_argnums=(1,),
            meta={"n_clients": n, "k_steps": K, "mb": mb,
                  "sequential": seq, "memory_dtype": cfg.memory_dtype,
                  "tokens_per_round": shape.global_batch * shape.seq_len})

    B, S = shape.global_batch, shape.seq_len
    batch_sharded = B % n_data == 0 and B >= n_data
    bax = dax if batch_sharded else None

    if shape.kind == "prefill":
        if cfg.encoder_only:
            fn = steps_lib.make_encoder_step(model)
            batch_sds = _serve_batch(cfg, B, S, compute_dtype)
            bspec = jax.tree.map(
                lambda l: P(*((bax,) + (None,) * (l.ndim - 1))), batch_sds)
            in_sh = (_ns(mesh, pspecs), _ns(mesh, bspec))
            out_sh = NamedSharding(mesh, P())
            return DryrunPlan(arch, shape_name, "encode", fn,
                              (params_sds, batch_sds), in_sh, out_sh, (),
                              {"batch": B, "seq": S})
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
        cspecs = rules.cache_specs(cache_sds, cfg, mesh, B)
        batch_sds = _serve_batch(cfg, B, S, compute_dtype)
        if seq_shard_prefill:
            # H2 (§Perf): sequence-parallel prefill — shard the token/seq dim
            # over `model` so activations stay seq-sharded and attention
            # gathers (small GQA) K/V instead of all-reducing the residual.
            bspec = jax.tree.map(
                lambda l: P(*rules.sanitize(
                    (bax, rules.MODEL) + (None,) * (l.ndim - 2),
                    l.shape, mesh)), batch_sds)
        else:
            bspec = jax.tree.map(
                lambda l: P(*((bax,) + (None,) * (l.ndim - 1))), batch_sds)
        fn = steps_lib.make_prefill_step(model)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, cspecs), _ns(mesh, bspec))
        lspec = P(*rules.sanitize((bax, rules.MODEL), (B, cfg.vocab_size),
                                  mesh))
        out_sh = (NamedSharding(mesh, lspec), _ns(mesh, cspecs))
        return DryrunPlan(arch, shape_name, "prefill", fn,
                          (params_sds, cache_sds, batch_sds), in_sh, out_sh,
                          donate_argnums=(1,), meta={"batch": B, "seq": S})

    # decode: one new token against a seq_len cache
    cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
    cspecs = rules.cache_specs(cache_sds, cfg, mesh, B)
    tokens_sds = _sds((B, 1), jnp.int32)
    pos_sds = _sds((), jnp.int32)
    fn = steps_lib.make_decode_step(model)
    in_sh = (_ns(mesh, pspecs), _ns(mesh, cspecs),
             NamedSharding(mesh, P(bax, None)), NamedSharding(mesh, P()))
    lspec = P(*rules.sanitize((bax, rules.MODEL), (B, cfg.vocab_size), mesh))
    out_sh = (NamedSharding(mesh, lspec), _ns(mesh, cspecs))
    return DryrunPlan(arch, shape_name, "decode", fn,
                      (params_sds, cache_sds, tokens_sds, pos_sds),
                      in_sh, out_sh, donate_argnums=(1,),
                      meta={"batch": B, "cache_len": S,
                            "batch_sharded": batch_sharded})
