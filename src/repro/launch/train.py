"""FL training driver: MIFA over any registered architecture.

CPU-scale entry point (smoke configs + synthetic token streams); the same
step function lowers on the production mesh via launch/dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \
      --rounds 50 --clients 8 --p-min 0.2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_config, get_smoke_config
from repro.core import MIFA, BernoulliParticipation, TauStats
from repro.core.local_update import client_updates
from repro.data import TokenBatcher
from repro.models import build_model
from repro.optim import constant, inv_t


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU scale)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--k-steps", type=int, default=1)
    ap.add_argument("--mb", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--p-min", type=float, default=0.3)
    ap.add_argument("--eta0", type=float, default=0.25)
    ap.add_argument("--lr-schedule", default="inv_t",
                    choices=["inv_t", "constant"])
    ap.add_argument("--memory", default="array",
                    choices=["array", "delta", "int8"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    cfg = cfg.replace(fl_clients=args.clients, fl_local_steps=args.k_steps)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    print(f"arch={cfg.name} params={model.param_count(params):,} "
          f"clients={args.clients} K={args.k_steps}")

    batcher = TokenBatcher(n_clients=args.clients, vocab=cfg.vocab_size,
                           seq_len=args.seq, batch_size=args.mb,
                           k_steps=args.k_steps, seed=args.seed)
    probs = np.linspace(args.p_min, 1.0, args.clients)
    part = BernoulliParticipation(probs, seed=args.seed + 1)
    algo = MIFA(memory=args.memory,
                memory_dtype="float32" if args.memory != "int8" else "int8")
    state = algo.init_state(params, args.clients)
    sched = (inv_t(args.eta0) if args.lr_schedule == "inv_t"
             else constant(args.eta0))
    stats = TauStats(args.clients)

    @jax.jit
    def round_fn(state, params, batch, active, eta, key):
        updates, losses = client_updates(model.loss_fn, params, batch, eta,
                                         K=args.k_steps)
        return algo.round_step(state, params, updates, losses, active, eta,
                               rng=key)

    t0 = time.time()
    for t in range(args.rounds):
        active = part.sample(t)
        stats.update(active)
        batch = {k: jnp.asarray(v) for k, v in batcher.sample_round(t).items()}
        eta = jnp.float32(sched(t + 1))
        rng, sub = jax.random.split(rng)
        state, params, metrics = round_fn(state, params, batch,
                                          jnp.asarray(active), eta, sub)
        if t % args.log_every == 0 or t == args.rounds - 1:
            print(f"round {t:4d} loss={float(metrics['loss']):.4f} "
                  f"active={int(active.sum())}/{args.clients} "
                  f"eta={float(eta):.4f} "
                  f"({(time.time() - t0) / (t + 1):.2f}s/round)")

    print(json.dumps({"final_loss": float(metrics["loss"]),
                      "tau_bar": stats.tau_bar, "tau_max": stats.tau_max,
                      "wall_s": round(time.time() - t0, 1)}))
    if args.checkpoint:
        save_pytree(args.checkpoint, params)
        print(f"saved params -> {args.checkpoint}")


if __name__ == "__main__":
    main()
