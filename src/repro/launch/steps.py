"""Distributed step builders: MIFA FL train_step and serve_step per arch.

train_step(params, G, batch, active, eta) -> (params, G, metrics)
  * vmap mode (default): all clients' local updates computed in parallel —
    client axis sharded over data/pod (client-parallel simulation).
  * sequential mode (fsdp archs): lax.scan over clients, each client's K-step
    update computed with the batch sharded over the data axis (per-client
    gradients live once, sharded 2-D) — the memory-feasible path for 110B
    (docs/architecture.md §3).

serve_step:
  * decode: (params, cache, tokens, pos) -> (logits, cache) — ONE new token
    against a seq_len KV cache (the assigned decode shapes).
  * prefill: (params, cache, batch) -> (logits, cache).
  * encoder score (hubert): (params, batch) -> logits.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.local_update import client_updates, device_update
from repro.models import Model


def make_train_step(model: Model, cfg: ArchConfig, n_clients: int,
                    k_steps: int, update_spec=None) -> Callable:
    """MIFA round as a pure function (array-memory layout, inlined)."""

    if not cfg.sequential_clients:
        def train_step(params, G, batch, active, eta):
            updates, losses = client_updates(model.loss_fn, params, batch,
                                             eta, K=k_steps)
            def sel(g_old, u):
                act = active.reshape((-1,) + (1,) * (u.ndim - 1))
                return jnp.where(act, u.astype(g_old.dtype), g_old)
            G_new = jax.tree.map(sel, G, updates)
            mean_G = jax.tree.map(
                lambda g: jnp.mean(g.astype(jnp.float32), axis=0), G_new)
            params = jax.tree.map(
                lambda w, g: (w - eta * g).astype(w.dtype), params, mean_G)
            act = active.astype(jnp.float32)
            loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
            return params, G_new, {"loss": loss}
        return train_step

    def train_step(params, G, batch, active, eta):
        """Sequential clients: scan; per-client grads sharded over the mesh."""
        def body(acc, xs):
            g_i, batch_i, a_i = xs
            u_i, loss_i = device_update(model.loss_fn, params, batch_i, eta)
            if update_spec is not None:
                u_i = jax.lax.with_sharding_constraint(u_i, update_spec)
            def sel(g_old, u):
                return jnp.where(a_i, u.astype(g_old.dtype), g_old)
            g_new = jax.tree.map(sel, g_i, u_i)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, g_new)
            return acc, (g_new, loss_i)

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        accN, (G_new, losses) = jax.lax.scan(body, acc0, (G, batch, active))
        params = jax.tree.map(
            lambda w, a: (w - eta * a / n_clients).astype(w.dtype),
            params, accN)
        act = active.astype(jnp.float32)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        return params, G_new, {"loss": loss}

    return train_step


def make_decode_step(model: Model) -> Callable:
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, tokens, pos, cache)
    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, cache, batch):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_encoder_step(model: Model) -> Callable:
    """Encoder-only 'serving' = a scoring forward pass (no cache)."""
    def encode_step(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return metrics["ce"]
    return encode_step
