"""Production mesh builders (docs/architecture.md §5).

Functions, not module-level constants: importing this module never touches JAX
device state. The dry-run sets XLA_FLAGS for 512 host devices *before* any JAX
import; smoke tests and benchmarks see the single real CPU device.

Construction goes through version-portable helpers: the installed JAX may or
may not expose `jax.sharding.AxisType` / accept `axis_types=` in
`jax.make_mesh`, and `AbstractMesh` switched from positional (shape, names)
to a single ((name, size), ...) shape_tuple.
"""
from __future__ import annotations

import jax


def _validate_axes(shape: tuple, axes: tuple) -> None:
    """Reject malformed mesh requests up front, naming the bad axis.

    JAX itself accepts duplicate axis names in AbstractMesh (the second
    silently shadows the first in `mesh.shape`) and lets non-positive
    sizes surface later as opaque reshape errors — both have bitten the
    sharding rules, which key on axis names and divide by axis sizes.
    """
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} and axes {axes} differ "
                         "in length")
    seen = set()
    for name, size in zip(axes, shape):
        if name in seen:
            raise ValueError(f"duplicate mesh axis name {name!r} in {axes}")
        seen.add(name)
        if not isinstance(size, int) or size < 1:
            raise ValueError(f"mesh axis {name!r} has non-positive size "
                             f"{size!r}; every axis needs an int >= 1")


def make_abstract_mesh(shape: tuple, axes: tuple):
    """AbstractMesh across JAX versions.

    Newer JAX takes one shape_tuple of (name, size) pairs; older releases
    took positional (axis_shapes, axis_names).
    """
    _validate_axes(shape, axes)
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


def _make_mesh(shape: tuple, axes: tuple, devices=None):
    _validate_axes(shape, axes)
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes),
                                 **kwargs)
        except TypeError:
            pass  # this jax.make_mesh predates axis_types
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    The dry-run process exposes 512 host devices; the single-pod mesh takes
    the first 256 of them.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as _np
    n = int(_np.prod(shape))
    devices = jax.devices()[:n]
    return _make_mesh(shape, axes, devices=devices)


def data_parallel_size(mesh) -> int:
    """Total extent of the client/data axes ('pod' x 'data' on multi-pod) —
    the shard count for MemoryBank rows and the MIFA update array. Delegates
    to sharding.rules so mesh helpers and partition rules can't diverge."""
    from repro.sharding.rules import data_axis_size
    return data_axis_size(mesh)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small ("data", "model") mesh over whatever devices exist (tests /
    local runs / the forced-host-device worlds of tests/conftest.py)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"make_host_mesh({data}, {model}) needs {data * model} devices "
            f"but this process has {n}; force more host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=... before "
            "JAX initialises (see tests/conftest.py)")
    return _make_mesh((data, model), ("data", "model"))
