"""Production mesh builders (DESIGN.md §5).

Functions, not module-level constants: importing this module never touches JAX
device state. The dry-run sets XLA_FLAGS for 512 host devices *before* any JAX
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    The dry-run process exposes 512 host devices; the single-pod mesh takes
    the first 256 of them.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as _np
    n = int(_np.prod(shape))
    devices = jax.devices()[:n]
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                         devices=devices)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
