"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_pytree
from repro.configs import get_config, get_smoke_config
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--params", default=None, help="checkpoint to load")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = load_pytree(args.params) if args.params else model.init(rng)

    B, P, T = args.batch, args.prompt_len, args.new_tokens
    cache_len = P + T
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.modality == "vision_text":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model)) * 0.02

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    cache = model.init_cache(B, cache_len)
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    outs = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    base = P + (cfg.n_patches if cfg.modality == "vision_text" else 0)
    for i in range(T):
        outs.append(tok)
        logits, cache = decode(params, tok, jnp.int32(base + i), cache)
        tok = jnp.argmax(logits, -1)[:, None]
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} new={T}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({B * P / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode : {t_decode * 1e3:.1f} ms "
          f"({B * T / max(t_decode, 1e-9):.0f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  sample[{b}] -> {gen[b, :12].tolist()}")


if __name__ == "__main__":
    main()
