from repro.data.synthetic import make_classification  # noqa: F401
from repro.data.partition import label_skew_partition  # noqa: F401
from repro.data.pipeline import (ClientBatcher,  # noqa: F401
                                 JitProceduralBatcher, ProceduralBatcher,
                                 TokenBatcher)
