"""Non-i.i.d. label-skew partitioner (paper §7: 2 classes per device).

McMahan-style shard assignment: sort by label, cut into 2N shards, deal each
client 2 shards — so each device holds samples of (at most) two classes and
all devices hold equally many samples.
"""
from __future__ import annotations

import numpy as np


def label_skew_partition(y: np.ndarray, n_clients: int,
                         shards_per_client: int = 2, seed: int = 0):
    """Returns (client_indices: list[np.ndarray], client_labels: (N, 2) int)."""
    rng = np.random.default_rng(seed)
    n_shards = n_clients * shards_per_client
    order = np.argsort(y, kind="stable")
    shards = np.array_split(order, n_shards)
    shard_ids = rng.permutation(n_shards)
    client_indices, client_labels = [], []
    for i in range(n_clients):
        sids = shard_ids[i * shards_per_client:(i + 1) * shards_per_client]
        idx = np.concatenate([shards[s] for s in sids])
        client_indices.append(idx)
        labels = sorted({int(y[shards[s]][0]) for s in sids})
        if len(labels) == 1:
            labels = labels * 2
        client_labels.append(labels[:2])
    return client_indices, np.asarray(client_labels, np.int64)
