"""Synthetic datasets (the container is offline; see docs/architecture.md §6).

`make_classification` builds a Gaussian-prototype mixture that structurally
matches the paper's image-classification tasks: C classes, per-class prototype
in R^dim, isotropic noise. Logistic regression on it (+ l2) is strongly convex;
the MLP model on it is non-convex — the two regimes of the paper's theory.
"""
from __future__ import annotations

import numpy as np


def make_classification(n_classes: int = 10, dim: int = 64,
                        n_per_class: int = 500, noise: float = 0.8,
                        proto_scale: float = 1.0, seed: int = 0,
                        proto_seed: int = 1234):
    """Returns (X (n, dim) f32, y (n,) int32), features scaled to ~unit norm.

    `proto_seed` fixes the class prototypes independently of the sample seed,
    so train/test splits drawn with different `seed` share one distribution.
    """
    prng = np.random.default_rng(proto_seed)
    protos = prng.normal(0.0, proto_scale, (n_classes, dim))
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for c in range(n_classes):
        xs.append(protos[c] + rng.normal(0.0, noise, (n_per_class, dim)))
        ys.append(np.full(n_per_class, c, np.int32))
    X = np.concatenate(xs).astype(np.float32) / np.sqrt(dim)
    y = np.concatenate(ys)
    perm = rng.permutation(len(y))
    return X[perm], y[perm]


def make_token_stream(vocab: int, length: int, seed: int = 0,
                      zipf_a: float = 1.2, client_shift: int = 0):
    """Synthetic non-iid LM data: Zipf marginal with a per-client vocabulary
    rotation (clients see the same language 'shape' over disjoint-ish token
    identities — a strong distribution shift, like the paper's label skew)."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf_a, size=length).astype(np.int64)
    toks = (ranks + client_shift) % vocab
    return toks.astype(np.int32)
