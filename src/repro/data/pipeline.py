"""Deterministic per-client batching for the FL round loop.

`sample_round(t)` yields a pytree whose leaves have shape (N, K, mb, ...):
one minibatch per client per local step, reproducible from (seed, t).
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import make_token_stream


class ClientBatcher:
    """Tabular classification batches: {'x': (N,K,mb,dim), 'y': (N,K,mb)}."""

    def __init__(self, X: np.ndarray, y: np.ndarray,
                 client_indices: list[np.ndarray], *, batch_size: int,
                 k_steps: int, seed: int = 0):
        self.Xs = [X[idx] for idx in client_indices]
        self.ys = [y[idx] for idx in client_indices]
        self.n_clients = len(client_indices)
        self.batch_size = batch_size
        self.k_steps = k_steps
        self.seed = seed
        self.dim = X.shape[1]

    def sample_round(self, t: int) -> dict:
        mb, K, N = self.batch_size, self.k_steps, self.n_clients
        xs = np.empty((N, K, mb, self.dim), np.float32)
        ys = np.empty((N, K, mb), np.int32)
        for i in range(N):
            rng = np.random.default_rng((self.seed, t, i))
            idx = rng.integers(0, len(self.ys[i]), size=(K, mb))
            xs[i] = self.Xs[i][idx]
            ys[i] = self.ys[i][idx]
        return {"x": xs, "y": ys}


class TokenBatcher:
    """LM batches {'tokens': (N,K,mb,seq)} from per-client synthetic streams."""

    def __init__(self, *, n_clients: int, vocab: int, seq_len: int,
                 batch_size: int, k_steps: int, stream_len: int = 1 << 16,
                 seed: int = 0):
        self.streams = [
            make_token_stream(vocab, stream_len, seed=seed + i,
                              client_shift=i * (vocab // max(n_clients, 1)))
            for i in range(n_clients)]
        self.n_clients = n_clients
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.k_steps = k_steps
        self.seed = seed

    def sample_round(self, t: int) -> dict:
        mb, K, N, S = self.batch_size, self.k_steps, self.n_clients, self.seq_len
        out = np.empty((N, K, mb, S), np.int32)
        for i in range(N):
            rng = np.random.default_rng((self.seed, t, i, 7))
            starts = rng.integers(0, len(self.streams[i]) - S - 1, size=(K, mb))
            for k in range(K):
                for b in range(mb):
                    s = starts[k, b]
                    out[i, k, b] = self.streams[i][s:s + S]
        return {"tokens": out}
