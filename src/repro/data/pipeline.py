"""Deterministic per-client batching for the FL round loop.

`sample_round(t)` yields a pytree whose leaves have shape (N, K, mb, ...):
one minibatch per client per local step, reproducible from (seed, t).

`sample_round(t, client_ids=ids)` yields the *compact* cohort variant —
leaves (len(ids), K, mb, ...) holding exactly the rows the full call would
have produced for those clients (same (seed, t, i) streams), in `ids` order.
The cohort round path (core.runner / repro.bank) lives on this: batch
assembly is O(|A|), never O(N). `ProceduralBatcher` pushes that to the data
itself — client shards are regenerated from (seed, client) on demand, so
million-client runs hold no per-client state at all.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import make_token_stream


class ClientBatcher:
    """Tabular classification batches: {'x': (N,K,mb,dim), 'y': (N,K,mb)}."""

    def __init__(self, X: np.ndarray, y: np.ndarray,
                 client_indices: list[np.ndarray], *, batch_size: int,
                 k_steps: int, seed: int = 0):
        self.Xs = [X[idx] for idx in client_indices]
        self.ys = [y[idx] for idx in client_indices]
        self.n_clients = len(client_indices)
        self.batch_size = batch_size
        self.k_steps = k_steps
        self.seed = seed
        self.dim = X.shape[1]

    def sample_round(self, t: int, client_ids=None) -> dict:
        mb, K = self.batch_size, self.k_steps
        ids = (np.arange(self.n_clients) if client_ids is None
               else np.asarray(client_ids, np.int64))
        xs = np.empty((len(ids), K, mb, self.dim), np.float32)
        ys = np.empty((len(ids), K, mb), np.int32)
        for j, i in enumerate(ids):
            i = int(i)
            rng = np.random.default_rng((self.seed, t, i))
            idx = rng.integers(0, len(self.ys[i]), size=(K, mb))
            xs[j] = self.Xs[i][idx]
            ys[j] = self.ys[i][idx]
        return {"x": xs, "y": ys}


class TokenBatcher:
    """LM batches {'tokens': (N,K,mb,seq)} from per-client synthetic streams."""

    def __init__(self, *, n_clients: int, vocab: int, seq_len: int,
                 batch_size: int, k_steps: int, stream_len: int = 1 << 16,
                 seed: int = 0):
        self.streams = [
            make_token_stream(vocab, stream_len, seed=seed + i,
                              client_shift=i * (vocab // max(n_clients, 1)))
            for i in range(n_clients)]
        self.n_clients = n_clients
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.k_steps = k_steps
        self.seed = seed

    def sample_round(self, t: int, client_ids=None) -> dict:
        mb, K, S = self.batch_size, self.k_steps, self.seq_len
        ids = (np.arange(self.n_clients) if client_ids is None
               else np.asarray(client_ids, np.int64))
        out = np.empty((len(ids), K, mb, S), np.int32)
        for j, i in enumerate(ids):
            i = int(i)
            rng = np.random.default_rng((self.seed, t, i, 7))
            starts = rng.integers(0, len(self.streams[i]) - S - 1, size=(K, mb))
            for k in range(K):
                for b in range(mb):
                    s = starts[k, b]
                    out[j, k, b] = self.streams[i][s:s + S]
        return {"tokens": out}


class ProceduralBatcher:
    """Stateless tabular batches for million-client cohort runs.

    No per-client storage: client i's shard is an infinite stream defined by
    (seed, i) — features are a client-specific mean shift (non-iid, label-
    correlated like data.partition's label skew) plus noise, labels come from
    a fixed random linear teacher. Identical draws whether a client is
    sampled via the full path or a compact cohort, so ProceduralBatcher is a
    drop-in for ClientBatcher at any N.
    """

    def __init__(self, *, n_clients: int, dim: int, n_classes: int = 2,
                 batch_size: int, k_steps: int, shift: float = 1.0,
                 noise: float = 1.0, seed: int = 0):
        self.n_clients = n_clients
        self.dim = dim
        self.n_classes = n_classes
        self.batch_size = batch_size
        self.k_steps = k_steps
        self.shift = shift
        self.noise = noise
        self.seed = seed
        teacher_rng = np.random.default_rng((seed, 0x7EAC))
        self.teacher = teacher_rng.normal(size=(dim, n_classes)) \
            .astype(np.float32)

    def _client_mean(self, i: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, 0xC11E27, i))
        return (self.shift * rng.normal(size=self.dim)).astype(np.float32)

    def sample_round(self, t: int, client_ids=None) -> dict:
        mb, K = self.batch_size, self.k_steps
        ids = (np.arange(self.n_clients) if client_ids is None
               else np.asarray(client_ids, np.int64))
        xs = np.empty((len(ids), K, mb, self.dim), np.float32)
        ys = np.empty((len(ids), K, mb), np.int32)
        for j, i in enumerate(ids):
            i = int(i)
            rng = np.random.default_rng((self.seed, t, i))
            x = rng.normal(size=(K, mb, self.dim)).astype(np.float32) \
                * self.noise + self._client_mean(i)
            xs[j] = x
            ys[j] = np.argmax(x @ self.teacher, axis=-1).astype(np.int32)
        return {"x": xs, "y": ys}


class JitProceduralBatcher:
    """Procedural batches with a jit-native drawing surface (two surfaces,
    like `repro.scenarios` / `repro.sim.latency`).

    `ProceduralBatcher` regenerates client shards on demand but assembles
    each round with a Python loop over clients — O(N) host work per round,
    which dominates at N=10⁵⁺. This batcher draws the SAME kind of data
    (client-specific mean shifts + noise, labels from a fixed random linear
    teacher — different RNG streams, so draws are not bitwise equal to
    `ProceduralBatcher`'s) from `jax.random` counter streams instead:

      * `batch_fn()` returns a pure ``(t) -> {'x', 'y'}`` function drawing
        the whole round IN-program (keyed by fold_in, so round t's batch
        depends only on (seed, t)) — the compiled simulator's scan body
        calls it so no (L, N, ...) batch stack ever crosses the host.
      * `sample_round(t)` materialises the jitted surface to NumPy —
        bit-identical to the in-program draw, so loop/heap drivers see the
        same data as compiled ones.

    `eval_batch(n)` draws a held-out set (its own stream, shared by every
    round) for time-to-accuracy eval functions.
    """

    def __init__(self, *, n_clients: int, dim: int, n_classes: int = 2,
                 batch_size: int, k_steps: int, shift: float = 1.0,
                 noise: float = 1.0, seed: int = 0):
        import jax
        self.n_clients = n_clients
        self.dim = dim
        self.n_classes = n_classes
        self.batch_size = batch_size
        self.k_steps = k_steps
        self.shift = shift
        self.noise = noise
        self.seed = seed
        kt, km, kd, ke = jax.random.split(jax.random.PRNGKey(seed), 4)
        self._k_teacher, self._k_means = kt, km
        self._k_data, self._k_eval = kd, ke
        self._host_fn = None

    def batch_fn(self):
        """Pure ``(t) -> {'x': (N, K, mb, dim) f32, 'y': (N, K, mb) i32}``,
        jit/vmap/scan-safe; all draws keyed by fold_in(seed-derived keys, t)."""
        import jax
        import jax.numpy as jnp
        n, k, mb, d = (self.n_clients, self.k_steps, self.batch_size,
                       self.dim)
        teacher = jax.random.normal(self._k_teacher, (d, self.n_classes),
                                    jnp.float32)
        means = self.shift * jax.random.normal(self._k_means, (n, d),
                                               jnp.float32)
        noise, k_data = jnp.float32(self.noise), self._k_data

        def draw(t):
            z = jax.random.normal(jax.random.fold_in(k_data, t),
                                  (n, k, mb, d), jnp.float32)
            x = noise * z + means[:, None, None, :]
            y = jnp.argmax(x @ teacher, axis=-1).astype(jnp.int32)
            return {"x": x, "y": y}

        return draw

    def sample_round(self, t: int, client_ids=None) -> dict:
        """Round t's batch as NumPy (the jit surface materialised — identical
        to in-program draws); `client_ids` selects a compact cohort view."""
        import jax
        if self._host_fn is None:
            self._host_fn = jax.jit(self.batch_fn())
        batch = {k: np.asarray(v) for k, v in self._host_fn(t).items()}
        if client_ids is not None:
            ids = np.asarray(client_ids, np.int64)
            batch = {k: v[ids] for k, v in batch.items()}
        return batch

    def eval_batch(self, n_eval: int = 2048) -> dict:
        """Held-out {'x': (n_eval, dim), 'y': (n_eval,)} from the eval
        stream: global mean (no client shift) + noise, teacher labels."""
        import jax
        import jax.numpy as jnp
        teacher = np.asarray(jax.random.normal(
            self._k_teacher, (self.dim, self.n_classes), jnp.float32))
        x = self.noise * np.asarray(jax.random.normal(
            self._k_eval, (n_eval, self.dim), jnp.float32))
        y = np.argmax(x @ teacher, axis=-1).astype(np.int32)
        return {"x": x, "y": y}
