"""MIFA core: the paper's contribution (Algorithm 1 + baselines + availability)."""
from repro.core.mifa import MIFA  # noqa: F401
from repro.core.baselines import (BiasedFedAvg, CAFed, FedAR,  # noqa: F401
                                  FedAvgIS, FedAvgSampling, FedBuffAvg,
                                  SCAFFOLDSampling)
from repro.core.algorithms import (algorithm_assumes,  # noqa: F401
                                   algorithm_names, make_algorithm,
                                   register_algorithm)
from repro.core.participation import (AdversarialParticipation,  # noqa: F401
                                      BernoulliParticipation,
                                      TraceParticipation, TauStats,
                                      label_correlated_probs, tau_matrix)
from repro.core.runner import (run_fl, FLHistory,  # noqa: F401
                               RoundRunner, make_scan_round_fn)
from repro.core.scan_engine import ScanDriver, scan_supported  # noqa: F401
