"""Device-availability processes A(t) and the paper's τ statistics.

The paper (§3) makes *no distributional assumption* on participation; we provide:
  * BernoulliParticipation — §5.1 case study (i.i.d. with per-device p_i),
    including the paper's label-correlated probabilities
    p_i = p_min * min(j,k)/9 + (1 - p_min).
  * AdversarialParticipation — deterministic worst-case-style patterns obeying
    Assumption 4 (τ(t,i) <= t0 + t/b): periodic blackouts with device-specific
    phase and duty cycle.
  * TraceParticipation — replay a recorded (T, N) availability matrix.

All processes return the all-active mask at round 0 (paper Remark 5.2 /
Definition 5.2(1): every device responds in the first round).

τ statistics (Definition 5.1): τ(t,i) = t - max{t' <= t : i in A(t')}.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def label_correlated_probs(client_labels: np.ndarray, p_min: float,
                           n_label_values: int = 10) -> np.ndarray:
    """Paper §7: label-correlated participation probabilities.

    The paper prints ``p_i = p_min·min(j,k)/9 + (1−p_min)``, but that expression
    contradicts the surrounding text ("devices holding data of smaller labels
    participate less frequently"; "p_min controls the lower bound"): at
    min(j,k)=0 it gives 1−p_min, the *largest* value. We implement the reading
    consistent with the stated semantics:

        p_i = p_min + (1 − p_min) · min(j,k) / 9

    so min(j,k)=0 ⇒ p_i = p_min (rare stragglers holding the small labels) and
    min(j,k)=9 ⇒ p_i = 1. client_labels: (N,2) int classes each client holds.
    """
    m = np.minimum(client_labels[:, 0], client_labels[:, 1]).astype(np.float64)
    return p_min + (1.0 - p_min) * m / (n_label_values - 1)


class BernoulliParticipation:
    """i.i.d. Bernoulli participation (Definition 5.2)."""

    def __init__(self, probs: np.ndarray, seed: int = 0):
        self.probs = np.asarray(probs, np.float64)
        self.n = len(self.probs)
        self.rng = np.random.default_rng(seed)

    def sample(self, t: int) -> np.ndarray:
        """(N,) bool mask for round t (round 0 is forced all-active)."""
        if t == 0:
            return np.ones(self.n, bool)
        return self.rng.random(self.n) < self.probs


class AdversarialParticipation:
    """Deterministic periodic blackouts: device i is inactive for `off_i`
    consecutive rounds out of every `period_i`, with phase `phase_i`.

    With off_i <= t0 this satisfies Assumption 4 for any b. Non-stationary,
    non-independent — the regime the paper claims (and baselines lack).
    """

    def __init__(self, n: int, periods: np.ndarray, offs: np.ndarray,
                 phases: np.ndarray | None = None):
        self.n = n
        self.periods = np.asarray(periods, np.int64)
        self.offs = np.asarray(offs, np.int64)
        self.phases = (np.zeros(n, np.int64) if phases is None
                       else np.asarray(phases, np.int64))
        assert np.all(self.offs < self.periods)

    def sample(self, t: int) -> np.ndarray:
        """(N,) bool mask for round t (round 0 is forced all-active)."""
        if t == 0:
            return np.ones(self.n, bool)
        ph = (t + self.phases) % self.periods
        return ph >= self.offs  # first `off` slots of each period are dark


class TraceParticipation:
    """Replay a recorded (T, N) availability matrix; rounds past the end
    repeat the last row. Row 0 is forced all-active (on a copy)."""

    def __init__(self, trace: np.ndarray):
        # copy: np.asarray can alias the input, and we overwrite row 0 below
        self.trace = np.array(trace, bool, copy=True)
        self.trace[0, :] = True
        self.n = self.trace.shape[1]

    def sample(self, t: int) -> np.ndarray:
        """(N,) bool mask for round t (clamped to the trace length)."""
        return self.trace[min(t, len(self.trace) - 1)]


# --------------------------------------------------------------------------- #
# τ statistics
# --------------------------------------------------------------------------- #

def _check_first_round(active: np.ndarray, strict: bool, what: str) -> None:
    """Definition 5.1's τ(t,i) = t − max{t' <= t : i ∈ A(t')} is undefined
    when a device has never been active; the paper closes the gap by
    assuming every device responds at round 0 (Remark 5.2 / Definition
    5.2(1)). These statistics used to *silently* assume that; now they
    raise unless `strict=False`, which opts into the documented init
    convention: devices are treated as active at a virtual round −1 (the
    server memory's zero init), so τ(0, i) = 1 for a round-0 absentee."""
    if strict and not np.all(active):
        missing = np.flatnonzero(~np.asarray(active, bool))[:8].tolist()
        raise ValueError(
            f"{what}: round 0 must be all-active (Definition 5.2(1)); "
            f"devices {missing}... are inactive. Pass strict=False to use "
            "the init convention (τ counts from a virtual round −1).")


@dataclass
class TauStats:
    """Streaming tracker of the paper's inactivity statistics.

    `strict` (default True) raises if the first recorded round is not
    all-active — see `_check_first_round`. `RoundRunner` constructs its
    tracker with strict=False because simulator round policies (e.g.
    `sim.policies.Deadline`) legitimately drop round-0 responders.
    """

    n: int
    strict: bool = True

    def __post_init__(self):
        self.tau = np.zeros(self.n, np.int64)         # current τ(t, i)
        self.tau_max_per_dev = np.zeros(self.n, np.int64)
        self.sum_tau = 0.0                            # Σ_t Σ_i τ(t,i)
        self.sum_tau_sq = 0.0                         # Σ_t Σ_i τ(t,i)^2
        self.rounds = 0
        self.history: list[np.ndarray] = []
        self.times: list[float] = []      # simulated seconds, if stamped

    def update(self, active: np.ndarray, keep_history: bool = False,
               sim_time: float | None = None):
        """Call once per round *with the round's availability mask* (after the
        mask is applied: τ=0 for active devices). `sim_time` stamps the round
        with simulated seconds (runtime-simulator runs)."""
        if self.rounds == 0:
            _check_first_round(np.asarray(active, bool), self.strict,
                               "TauStats.update")
        self.tau = np.where(active, 0, self.tau + 1)
        self.tau_max_per_dev = np.maximum(self.tau_max_per_dev, self.tau)
        self.sum_tau += float(self.tau.sum())
        self.sum_tau_sq += float((self.tau.astype(np.float64) ** 2).sum())
        self.rounds += 1
        if keep_history or sim_time is not None:
            # times stays aligned with history: NaN for unstamped rounds
            self.times.append(np.nan if sim_time is None else float(sim_time))
            self.history.append(self.tau.copy())

    def absorb_scan(self, tau: np.ndarray, tau_max_per_dev: np.ndarray,
                    tau_sums: np.ndarray, tau_sq_sums: np.ndarray) -> None:
        """Merge one scan-engine chunk of device-accumulated τ statistics.

        The scan engine (docs/architecture.md §9) accumulates τ inside the
        compiled program — `tau` / `tau_max_per_dev` are the (N,) carry
        state after the chunk, `tau_sums` / `tau_sq_sums` the per-round
        Σ_i τ(t,i) and Σ_i τ(t,i)² ys — so no per-round (N,) mask ever
        reaches the host. Device sums are int32 (exact while Σ_i τ² per
        round < 2^31); the running totals stay float64 host-side exactly
        like per-round `update` calls.
        """
        tau_sums = np.asarray(tau_sums)
        if self.rounds == 0 and len(tau_sums) and self.strict \
                and tau_sums[0] != 0:
            raise ValueError(
                "absorb_scan: round 0 must be all-active (Definition "
                "5.2(1)); pass strict=False to use the init convention.")
        self.tau = np.asarray(tau, np.int64)
        self.tau_max_per_dev = np.asarray(tau_max_per_dev, np.int64)
        self.sum_tau += float(np.sum(tau_sums, dtype=np.float64))
        self.sum_tau_sq += float(np.sum(np.asarray(tau_sq_sums),
                                        dtype=np.float64))
        self.rounds += len(tau_sums)

    def timeline(self) -> tuple[np.ndarray, np.ndarray]:
        """Time-stamped view: (times (R,), τ history (R, N)), row-aligned.

        Populated by update() calls with sim_time or keep_history; rounds
        recorded without a timestamp carry NaN in `times`.
        """
        return (np.asarray(self.times, np.float64),
                np.stack(self.history) if self.history
                else np.zeros((0, self.n), np.int64))

    # Definition 5.1 quantities over the rounds seen so far
    @property
    def tau_bar(self) -> float:
        """τ̄_T: mean τ(t,i) over all rounds × devices seen so far."""
        return self.sum_tau / max(self.rounds * self.n, 1)

    @property
    def tau_max(self) -> int:
        """τ_max,T: the largest τ(t,i) seen by any device."""
        return int(self.tau_max_per_dev.max(initial=0))

    @property
    def d_bar(self) -> float:
        """\\bar d_T (App. C): mean of τ(t,i)² over rounds × devices."""
        return self.sum_tau_sq / max(self.rounds * self.n, 1)

    @property
    def d_max_bar(self) -> float:
        """\\bar d_max,T (App. B): mean over devices of (max_t τ(t,i))²."""
        return float((self.tau_max_per_dev.astype(np.float64) ** 2).mean())

    @property
    def tau_max_bar(self) -> float:
        """\\bar τ_max,T (App. C): mean over devices of max_t τ(t,i)."""
        return float(self.tau_max_per_dev.astype(np.float64).mean())


def tau_matrix(masks: np.ndarray, *, strict: bool = True) -> np.ndarray:
    """masks (T, N) bool -> τ(t,i) matrix (T, N).

    Raises if masks[0] is not all-active (the paper's Definition 5.2(1)
    convention that makes τ well defined); pass strict=False to fall back
    to the init convention (see `_check_first_round`)."""
    masks = np.asarray(masks, bool)
    T, N = masks.shape
    if T:
        _check_first_round(masks[0], strict, "tau_matrix")
    tau = np.zeros((T, N), np.int64)
    cur = np.zeros(N, np.int64)
    for t in range(T):
        cur = np.where(masks[t], 0, cur + 1)
        tau[t] = cur
    return tau
