"""Algorithm registry: build aggregation algorithms by name.

The scenario subsystem made availability a data problem (`make_scenario`);
this registry does the same for the *algorithm* axis, so benchmarks, the
scenario atlas, and parametrised tests sweep `algorithm × scenario × seed`
grids by string without hardcoding class lists (the hardcoded-gap-key bug
class in benchmarks/scenario_grid.py):

    algo = make_algorithm("fedar", n=100, decay=0.5)
    run_fl(model=model, algo=algo, scenario=scen, ...)

Every factory takes the client count `n` (some algorithms size per-client
parameters from it; others ignore it) plus the class's own kwargs, and every
registered algorithm follows the pure round-fn protocol
(`init_state` / `round_step(state, params, updates, losses, active, eta,
rng)`), so all of them inherit fleet vmapping and whole-run scan compilation
for free. `algorithm_assumes(name)` surfaces the availability regime the
mechanism needs (docs/scenarios.md "Algorithm taxonomy").
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.baselines import BiasedFedAvg, CAFed, FedAR, FedAvgIS
from repro.core.mifa import MIFA

_REGISTRY: dict[str, Callable] = {}


def register_algorithm(name: str, factory: Callable | None = None):
    """Register `factory(*, n, **kw) -> algorithm` under `name`. Usable as
    a decorator or a plain call; returns the factory."""
    def _do(f: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = f
        return f
    return _do(factory) if factory is not None else _do


def algorithm_names() -> list[str]:
    """Registered algorithm names, sorted."""
    return sorted(_REGISTRY)


def make_algorithm(name: str, *, n: int, **kwargs):
    """Build the algorithm registered under `name` for an `n`-client fleet."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {algorithm_names()}")
    return _REGISTRY[name](n=n, **kwargs)


def algorithm_assumes(name: str, *, n: int = 2) -> str:
    """The availability regime `name` needs: 'arbitrary' |
    'iid_known_probs' | 'stationary_mixing' | 'none'."""
    return make_algorithm(name, n=n).assumes


# --------------------------------------------------------------------------- #
# built-ins
# --------------------------------------------------------------------------- #

@register_algorithm("mifa")
def _mifa(*, n: int, memory: str = "array",
          memory_dtype: str = "float32") -> MIFA:
    del n
    return MIFA(memory=memory, memory_dtype=memory_dtype)


@register_algorithm("banked_mifa")
def _banked_mifa(*, n: int, backend: str = "dense", **bank_kw):
    del n
    from repro.bank import make_bank  # bank does not import core: no cycle
    from repro.bank.mifa_bank import BankedMIFA
    return BankedMIFA(make_bank(backend, **bank_kw))


@register_algorithm("fedavg")
def _fedavg(*, n: int) -> BiasedFedAvg:
    del n
    return BiasedFedAvg()


@register_algorithm("fedavg_is")
def _fedavg_is(*, n: int, probs=0.5) -> FedAvgIS:
    return FedAvgIS(tuple(np.broadcast_to(
        np.asarray(probs, np.float64), (n,)).tolist()))


@register_algorithm("fedar")
def _fedar(*, n: int, decay: float = 0.5) -> FedAR:
    del n
    return FedAR(decay=decay)


@register_algorithm("ca_fed")
def _ca_fed(*, n: int, rho: float = 0.1, pi_min: float = 0.05,
            d_max: float = 0.85) -> CAFed:
    del n
    return CAFed(rho=rho, pi_min=pi_min, d_max=d_max)
