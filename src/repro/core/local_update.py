"""K-step local SGD (paper Algorithm 1, DeviceUpdate).

An active device receives w_t, runs K steps of SGD at learning rate η_t on its
local objective, and returns G^i = (w_t − w^i_{t,K}) / η_t — which is *exactly*
the sum of its K stochastic gradients. We accumulate the gradient sum directly
(numerically cleaner than subtracting and dividing, and independent of η_t for
K=1), which is the identical quantity.

`client_updates` vmaps the device update over the leading client axis; under
pjit that axis is sharded over the mesh's `data` (and `pod`) axes, making the
simulation client-parallel.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def device_update(loss_fn: Callable, params, client_batch, eta: jnp.ndarray,
                  weight_decay: float = 0.0):
    """Run K local SGD steps for ONE device.

    client_batch: pytree whose leaves have leading axis K (one minibatch per
    local step). Returns (G = Σ_k ∇f(w_{t,k}), mean local loss).
    """
    def step(carry, mb):
        w, acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(w, mb)
        if weight_decay:
            g = jax.tree.map(lambda gg, ww: gg + weight_decay * ww, g, w)
        w = jax.tree.map(
            lambda ww, gg: (ww.astype(jnp.float32)
                            - eta * gg.astype(jnp.float32)).astype(ww.dtype),
            w, g)
        acc = jax.tree.map(lambda aa, gg: aa + gg.astype(aa.dtype), acc, g)
        return (w, acc), loss

    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    (w_k, acc), losses = jax.lax.scan(step, (params, zeros), client_batch)
    return acc, jnp.mean(losses)


def client_updates(loss_fn: Callable, params, batches, eta, K: int,
                   weight_decay: float = 0.0):
    """vmap device_update over clients.

    batches: pytree with leaves (N, K, ...). Returns (G (N, ...) f32, losses (N,)).
    """
    fn = partial(device_update, loss_fn, weight_decay=weight_decay)
    return jax.vmap(lambda b: fn(params, b, eta))(batches)
