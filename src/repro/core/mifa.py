"""MIFA — Memory-augmented Impatient Federated Averaging (paper Algorithm 1).

Server state: the update array {G^i}_{i=1..N}, stored as a pytree whose leaves
carry a leading client axis (N, *param_shape) sharded client→data. Each round:

    G^i_t = G^i_{t-1}                  if i ∉ A(t)
          = (w_t − w^i_{t,K}) / η_t    if i ∈ A(t)      (fresh K-step update)
    w_{t+1} = w_t − η_t · (1/N) Σ_i G^i_t

Three dense memory layouts (docs/architecture.md §3):
  * "array"  — paper-faithful float update array (fp32/bf16).
  * "delta"  — the paper's §4 memory-efficient variant: server keeps only the
    running mean Ḡ; per-client previous updates are separate state (on-device
    in a real deployment). Mathematically identical — property-tested.
  * "int8"   — beyond-paper: stochastically-rounded int8 array.

All three pay O(N·d) per round. For cohort-sized O(|A(t)|·d) rounds at large
N, use `repro.bank.BankedMIFA` — the same algorithm through a row-sparse
MemoryBank (dense / host-offloaded / int8-paged backends), property-tested
equivalent to memory="array".

`round_step` consumes precomputed per-client updates (from
core.local_update.client_updates), so the aggregation is a pure, kernel-
replaceable function — `repro.kernels.mifa_aggregate` fuses it on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import quantized_memory as qm


def _bcast(active: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """active (N,) -> broadcastable to leaf (N, ...)."""
    return active.reshape((active.shape[0],) + (1,) * (leaf.ndim - 1))


@dataclass(frozen=True)
class MIFA:
    """memory: 'array' | 'delta' | 'int8'; memory_dtype for 'array'."""

    memory: str = "array"
    memory_dtype: str = "float32"
    # needs no knowledge of the availability law (Assumption 4 only) —
    # see docs/scenarios.md "Algorithm taxonomy"
    assumes = "arbitrary"

    # ------------------------------------------------------------------ #
    def init_state(self, params, n_clients: int) -> dict:
        def zeros_n(p, dtype):
            return jnp.zeros((n_clients,) + p.shape, dtype)

        if self.memory == "array":
            dt = jnp.dtype(self.memory_dtype)
            return {"G": jax.tree.map(lambda p: zeros_n(p, dt), params),
                    "t": jnp.zeros((), jnp.int32)}
        if self.memory == "delta":
            dt = jnp.dtype(self.memory_dtype)
            return {"G_prev": jax.tree.map(lambda p: zeros_n(p, dt), params),
                    "G_bar": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params),
                    "t": jnp.zeros((), jnp.int32)}
        if self.memory == "int8":
            return {"G_q": jax.tree.map(lambda p: zeros_n(p, jnp.int8), params),
                    "G_scale": jax.tree.map(
                        lambda p: jnp.zeros((n_clients,), jnp.float32), params),
                    "t": jnp.zeros((), jnp.int32)}
        raise ValueError(self.memory)

    # ------------------------------------------------------------------ #
    def round_step(self, state: dict, params, updates, losses, active,
                   eta: jnp.ndarray, rng=None):
        """updates: pytree (N, ...) f32 — fresh K-step updates for ALL clients
        (the active mask selects which are used; inactive entries are ignored).
        """
        act = active.astype(jnp.float32)
        n = act.shape[0]

        if self.memory == "array":
            G = jax.tree.map(
                lambda g_old, u: jnp.where(_bcast(active, u), u, g_old
                                           ).astype(g_old.dtype),
                state["G"], updates)
            mean_G = jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), 0), G)
            new_state = {"G": G, "t": state["t"] + 1}

        elif self.memory == "delta":
            # Ḡ_t = Ḡ_{t-1} + (1/N) Σ_{i∈A} (G^i_t − G^i_{t'_i})
            deltas = jax.tree.map(
                lambda u, gp: (u - gp.astype(jnp.float32))
                * _bcast(act, u), updates, state["G_prev"])
            G_bar = jax.tree.map(lambda gb, d: gb + jnp.sum(d, 0) / n,
                                 state["G_bar"], deltas)
            G_prev = jax.tree.map(
                lambda gp, u: jnp.where(_bcast(active, u), u, gp
                                        ).astype(gp.dtype),
                state["G_prev"], updates)
            mean_G = G_bar
            new_state = {"G_prev": G_prev, "G_bar": G_bar,
                         "t": state["t"] + 1}

        elif self.memory == "int8":
            assert rng is not None, "int8 memory needs an rng for rounding"
            G_f = qm.dequantize_tree(state["G_q"], state["G_scale"])
            G_f = jax.tree.map(
                lambda g_old, u: jnp.where(_bcast(active, u), u, g_old),
                G_f, updates)
            G_q, G_scale = qm.quantize_tree(rng, G_f)
            # re-dequantize so inactive entries stay *exactly* what is stored
            G_f = qm.dequantize_tree(G_q, G_scale)
            mean_G = jax.tree.map(lambda g: jnp.mean(g, 0), G_f)
            new_state = {"G_q": G_q, "G_scale": G_scale, "t": state["t"] + 1}
        else:
            raise ValueError(self.memory)

        new_params = jax.tree.map(
            lambda w, g: (w - eta * g).astype(w.dtype), params, mean_G)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        return new_state, new_params, {"loss": loss,
                                       "n_active": jnp.sum(act)}
