"""FedAvg baselines under device unavailability (paper §3 / Algorithm 2).

  * BiasedFedAvg       — average the *active* devices' updates only. Fast but
                         biased when availability correlates with data.
  * FedAvgIS           — importance sampling: weight active updates by 1/p_i.
                         Unbiased but requires knowing the participation
                         probabilities (i.i.d. model only).
  * FedAvgSampling     — the original FedAvg protocol: sample S devices, then
                         *wait* across rounds until all S have responded; only
                         then apply a global update (the paper's straggler-prone
                         baseline, Eq. 3). The global model is frozen while
                         waiting, so updates from different rounds are computed
                         at the same w.
  * SCAFFOLDSampling   — SCAFFOLD control variates on top of the S-device
                         sampling protocol (paper compares against it in §5.1).
  * FedBuffAvg         — buffered-async aggregation (FedBuff-style): merges
                         staleness-weighted updates delivered by the
                         `repro.sim.BufferedKofN` server policy.

Competing memorisation / reweighting mechanisms from the related work
(PAPERS.md; docs/scenarios.md maps each to the paper's taxonomy):

  * FedAR              — local-update approximation + rectification (Jiang
                         et al., arXiv 2407.19103): the server keeps every
                         client's latest update as a surrogate (like MIFA's
                         memory) but *rectifies* the average with
                         staleness-decayed, re-normalised weights instead
                         of weighting surrogates uniformly.
  * CAFed              — correlated-availability weighting (Rodio et al.,
                         arXiv 2301.04632): aggregation weights adapt
                         online to availability estimates (EWMA activity +
                         chain-persistence) maintained in-state from the
                         observed `active` masks; clients whose
                         availability chain mixes too slowly are excluded.

All share MIFA's round API: init_state / round_step(state, params, updates,
losses, active, eta, rng) — pure round fns, so every algorithm inherits
fleet vmapping (`repro.fleet`) and whole-run scan compilation
(`core.scan_engine`) for free. The `assumes` tag names the availability
regime each mechanism needs (docs/scenarios.md "Algorithm taxonomy"):
'arbitrary' (Assumption 4 only), 'iid_known_probs' (Definition 5.2 with
oracle p_i), 'stationary_mixing' (estimable stationary chain), or 'none'
(no correction — biased under correlated availability).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mifa import _bcast


@dataclass(frozen=True)
class BiasedFedAvg:
    assumes: ClassVar[str] = "none"

    def init_state(self, params, n_clients: int) -> dict:
        return {"t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        act = active.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(act), 1.0)
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(act, u), 0) / denom, updates)
        new_params = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * act) / denom
        return ({"t": state["t"] + 1}, new_params,
                {"loss": loss, "n_active": jnp.sum(act)})


@dataclass(frozen=True)
class FedBuffAvg:
    """Buffered-async FedAvg (FedBuff-style): the server-side aggregator
    behind `repro.sim.BufferedKofN`.

    `active` arrives as a float32 weight vector (staleness discounts
    1/sqrt(1+s) from the buffered policy, 0 for non-contributors) instead
    of a bool mask — `weight_aware` tells the simulation engines to pass
    weights through. The update is Σ w_i·u_i / |contributors|: dividing by
    the contributor COUNT (not Σw) keeps the step size comparable to
    synchronous FedAvg while stale updates are attenuated, matching the
    FedBuff recipe. With a bool mask it degenerates to `BiasedFedAvg`.
    """

    weight_aware: ClassVar[bool] = True
    assumes: ClassVar[str] = "none"

    def init_state(self, params, n_clients: int) -> dict:
        """Stateless aggregation: only the round counter `t`."""
        return {"t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta,
                   rng=None):
        """One buffered merge: weighted mean over contributors (active > 0),
        server step w <- w - η·mean; loss averages the contributors."""
        w = active.astype(jnp.float32)
        contrib = (w > 0).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(contrib), 1.0)
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(w, u), 0) / denom, updates)
        new_params = jax.tree.map(lambda p, g: (p - eta * g).astype(p.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * contrib) / denom
        return ({"t": state["t"] + 1}, new_params,
                {"loss": loss, "n_active": jnp.sum(contrib)})


@dataclass(frozen=True)
class FedAvgIS:
    """Requires the true participation probabilities (N,).

    `probs` is a construction-time convenience only: `init_state` embeds it
    in the algorithm STATE pytree (the same pattern scenario parameters
    use), so the traced round function never reads it from `self` — two
    runs with distinct probability vectors share one jit trace, and
    mixed-probs trials can batch along the fleet's trial axis by stacking
    their states. (It used to be a jit-static tuple: every new vector
    retraced the whole program.)

    Zero-probability clients are excluded from the importance sum rather
    than divided by: a p_i = 0 device can never legitimately participate,
    and `act/p` would turn one stray activation into inf/nan params.
    """

    probs: tuple  # tuple only to keep the dataclass hashable for jit
    assumes: ClassVar[str] = "iid_known_probs"

    def __post_init__(self):
        # accept any array-like; normalise so equal vectors hash equal
        object.__setattr__(
            self, "probs",
            tuple(float(p) for p in np.atleast_1d(np.asarray(self.probs))))

    def init_state(self, params, n_clients: int) -> dict:
        assert len(self.probs) == n_clients, (len(self.probs), n_clients)
        return {"t": jnp.zeros((), jnp.int32),
                "probs": jnp.asarray(self.probs, jnp.float32)}

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        act = active.astype(jnp.float32)
        p = state["probs"]                   # (N,) — rides the state pytree
        w_is = jnp.where(p > 0, act / jnp.maximum(p, 1e-12), 0.0)
        n = act.shape[0]
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(w_is, u), 0) / n, updates)
        new_params = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        return ({"t": state["t"] + 1, "probs": p}, new_params,
                {"loss": loss, "n_active": jnp.sum(act)})


@dataclass(frozen=True)
class FedAR:
    """FedAR-style local-update approximation + rectification (Jiang et al.,
    "FedAR: Addressing Client Unavailability in Federated Learning with
    Local Update Approximation and Rectification", arXiv 2407.19103).

    Approximation: the server keeps each client's most recent update U^i as
    a surrogate for the one it cannot observe this round — the same
    memorisation MIFA performs. Rectification: instead of averaging the
    surrogates uniformly (MIFA), each surrogate is discounted by its
    staleness and the weights are re-normalised:

        U^i_t = u^i_t            if i ∈ A(t)     (fresh update)
              = U^i_{t-1}        otherwise       (surrogate)
        τ_i   = rounds since i last participated (0 when fresh)
        α_i   = decay^τ_i,     w_{t+1} = w_t − η · Σ_i α_i U^i_t / Σ_i α_i

    The decay knob interpolates between the two competing mechanisms:
    decay=1 is exactly MIFA's uniform memory average, decay=0 is
    BiasedFedAvg (stale surrogates vanish, 0^0 = 1 keeps fresh ones).
    Surrogates and staleness ride the state pytree exactly like MIFA's
    memory, so fleet vmapping and scan compilation apply unchanged. Like
    MIFA it needs no knowledge of the availability law — only Assumption 4
    for the theory — hence `assumes = 'arbitrary'`.
    """

    decay: float = 0.5
    assumes: ClassVar[str] = "arbitrary"

    def init_state(self, params, n_clients: int) -> dict:
        return {"U": jax.tree.map(
                    lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32),
                    params),
                "tau": jnp.zeros((n_clients,), jnp.int32),
                "t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta,
                   rng=None):
        act = active.astype(jnp.float32)
        U = jax.tree.map(
            lambda u_old, u: jnp.where(_bcast(active, u), u, u_old),
            state["U"], updates)
        tau = jnp.where(active, 0, state["tau"] + 1)
        alpha = jnp.power(jnp.float32(self.decay), tau.astype(jnp.float32))
        denom = jnp.maximum(jnp.sum(alpha), 1.0)
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(alpha, u), 0) / denom, U)
        new_params = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        return ({"U": U, "tau": tau, "t": state["t"] + 1}, new_params,
                {"loss": loss, "n_active": jnp.sum(act)})


@dataclass(frozen=True)
class CAFed:
    """Correlated-availability weighting, after Rodio et al., "Federated
    Learning under Heterogeneous and Correlated Client Availability"
    (arXiv 2301.04632) — CA-Fed.

    CA-Fed adapts each client's aggregation weight to ONLINE estimates of
    its availability dynamics and excludes clients whose availability
    chain mixes too slowly (their importance-weighted reappearances inject
    more variance/bias than their data is worth). No oracle probabilities:
    everything is estimated in-state from the observed `active` masks.

    Per-client state (all EWMA with rate `rho`):
      pi_hat   — stationary activity estimate π̂_i (EWMA of the mask).
      stay_up  — P(active_t | active_{t-1}) estimate (updated only on
                 rounds where the client WAS active).
      stay_dn  — P(inactive_t | inactive_{t-1}) estimate (updated only
                 after inactive rounds); 1/(1−stay_dn) is the expected
                 off-burst length, and stay_up + stay_dn − 1 estimates the
                 second eigenvalue λ_i of the 2-state availability chain —
                 Rodio et al.'s correlation measure.

    Round update: exclude clients with stay_dn > d_max (expected off-burst
    beyond 1/(1−d_max) rounds); the rest are importance-weighted by their
    estimated rate,

        w_{t+1} = w_t − η · Σ_{i incl} 1[i ∈ A(t)] u^i_t / π̂_i
                          / |{incl}| ,

    falling back to all-clients-included when the exclusion rule would
    empty the cohort. Under iid availability the estimates converge to the
    true p_i and CAFed approaches FedAvgIS without the oracle; under
    correlated availability it trades the excluded clients' bias for
    variance, which is exactly the regime split the scenario atlas probes.
    Estimation needs the chain to BE estimable, hence
    `assumes = 'stationary_mixing'`.
    """

    rho: float = 0.1
    pi_min: float = 0.05
    d_max: float = 0.85
    assumes: ClassVar[str] = "stationary_mixing"

    def init_state(self, params, n_clients: int) -> dict:
        # neutral priors: π̂ at 1/2, both persistences at their iid-0.5
        # values — a client never observed in a state keeps the prior
        return {"pi_hat": jnp.full((n_clients,), 0.5, jnp.float32),
                "stay_up": jnp.full((n_clients,), 0.5, jnp.float32),
                "stay_dn": jnp.full((n_clients,), 0.5, jnp.float32),
                "prev": jnp.ones((n_clients,), bool),
                "t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta,
                   rng=None):
        act = active.astype(jnp.float32)
        rho = jnp.float32(self.rho)
        pi_hat = state["pi_hat"] + rho * (act - state["pi_hat"])
        stay_up = jnp.where(state["prev"],
                            state["stay_up"]
                            + rho * (act - state["stay_up"]),
                            state["stay_up"])
        stay_dn = jnp.where(state["prev"], state["stay_dn"],
                            state["stay_dn"]
                            + rho * ((1.0 - act) - state["stay_dn"]))
        incl = (stay_dn <= self.d_max).astype(jnp.float32)
        # never let the exclusion rule empty the cohort entirely
        incl = jnp.where(jnp.sum(incl) > 0, incl, jnp.ones_like(incl))
        w = incl * act / jnp.clip(pi_hat, self.pi_min, 1.0)
        denom = jnp.maximum(jnp.sum(incl), 1.0)
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(w, u), 0) / denom, updates)
        new_params = jax.tree.map(lambda p, g: (p - eta * g).astype(p.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        new_state = {"pi_hat": pi_hat, "stay_up": stay_up,
                     "stay_dn": stay_dn, "prev": active,
                     "t": state["t"] + 1}
        return new_state, new_params, {"loss": loss,
                                       "n_active": jnp.sum(act)}


@dataclass(frozen=True)
class FedAvgSampling:
    """FedAvg with device sampling: wait for the S selected devices."""

    s: int
    assumes: ClassVar[str] = "none"

    def init_state(self, params, n_clients: int) -> dict:
        return {
            "selected": jnp.zeros((n_clients,), bool),
            "received": jnp.zeros((n_clients,), bool),
            "U": jax.tree.map(
                lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32),
                params),
            "t": jnp.zeros((), jnp.int32),        # communication rounds
            "t_updates": jnp.zeros((), jnp.int32),  # applied global updates
            "need_resample": jnp.ones((), bool),
        }

    def _resample(self, rng, n: int) -> jnp.ndarray:
        perm = jax.random.permutation(rng, n)
        mask = jnp.zeros((n,), bool).at[perm[: self.s]].set(True)
        return mask

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        assert rng is not None, "FedAvgSampling needs an rng to sample devices"
        n = active.shape[0]
        selected = jnp.where(state["need_resample"],
                             self._resample(rng, n), state["selected"])
        received = jnp.where(state["need_resample"],
                             jnp.zeros_like(state["received"]),
                             state["received"])

        newly = selected & active & ~received
        U = jax.tree.map(
            lambda u_old, u: jnp.where(_bcast(newly, u), u, u_old),
            state["U"], updates)
        received = received | newly
        complete = jnp.all(~selected | received)

        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(selected.astype(jnp.float32), u), 0)
            / self.s, U)
        new_params = jax.tree.map(
            lambda w, g: jnp.where(complete, (w - eta * g).astype(w.dtype), w),
            params, mean_G)

        act = active.astype(jnp.float32)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        new_state = {
            "selected": selected,
            "received": received,
            "U": U,
            "t": state["t"] + 1,
            "t_updates": state["t_updates"] + complete.astype(jnp.int32),
            "need_resample": complete,
        }
        return new_state, new_params, {
            "loss": loss, "n_active": jnp.sum(act),
            "global_updates": new_state["t_updates"].astype(jnp.float32)}


@dataclass(frozen=True)
class SCAFFOLDSampling:
    """SCAFFOLD (Karimireddy et al. 2020) on the S-device sampling protocol.

    Control variates c_i (per device) and c (server). Clients correct their
    local gradients with (c − c_i); here, with the update-level API, the
    corrected update for device i is  u_i − K·(c_i − c)  (option II of the
    paper, expressed on accumulated gradients), and on completion
       c_i ← c_i + (u_i/K − c_i)·1[i∈S],   c ← c + (S/N)·mean_{i∈S}(Δc_i).
    """

    s: int
    k_steps: int
    assumes: ClassVar[str] = "none"

    def init_state(self, params, n_clients: int) -> dict:
        zeros_n = lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32)
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = FedAvgSampling(self.s).init_state(params, n_clients)
        st["c_i"] = jax.tree.map(zeros_n, params)
        st["c"] = jax.tree.map(zeros, params)
        return st

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        assert rng is not None
        n = active.shape[0]
        K = float(self.k_steps)
        # variance-reduced updates
        vr_updates = jax.tree.map(
            lambda u, ci, c: u - K * (ci - c[None]), updates,
            state["c_i"], state["c"])

        base = FedAvgSampling(self.s)
        sub = {k: state[k] for k in
               ("selected", "received", "U", "t", "t_updates", "need_resample")}
        new_sub, new_params, metrics = base.round_step(
            sub, params, vr_updates, losses, active, eta, rng)

        # on completion, refresh control variates for the selected cohort
        complete = new_sub["need_resample"]
        sel = new_sub["selected"]
        self32 = sel.astype(jnp.float32)
        # device i's fresh avg gradient estimate = stored U_i / K  + correction
        c_i_new = jax.tree.map(
            lambda Ui, ci, c: jnp.where(
                _bcast(sel & complete, Ui),
                Ui / K,  # U holds vr update; invert correction below
                ci),
            new_sub["U"], state["c_i"], state["c"])
        # invert the (c - c_i) correction stored inside U
        c_i_new = jax.tree.map(
            lambda cin, ci, c: jnp.where(
                _bcast(sel & complete, cin),
                cin + (ci - c[None]), cin),
            c_i_new, state["c_i"], state["c"])
        dc = jax.tree.map(lambda cin, ci: (cin - ci) * _bcast(self32, cin),
                          c_i_new, state["c_i"])
        c_new = jax.tree.map(
            lambda c, d: jnp.where(complete, c + jnp.sum(d, 0) / n, c),
            state["c"], dc)

        new_state = dict(new_sub)
        new_state["c_i"] = jax.tree.map(
            lambda a, b: jnp.where(complete, a, b), c_i_new, state["c_i"])
        new_state["c"] = c_new
        return new_state, new_params, metrics
