"""FedAvg baselines under device unavailability (paper §3 / Algorithm 2).

  * BiasedFedAvg       — average the *active* devices' updates only. Fast but
                         biased when availability correlates with data.
  * FedAvgIS           — importance sampling: weight active updates by 1/p_i.
                         Unbiased but requires knowing the participation
                         probabilities (i.i.d. model only).
  * FedAvgSampling     — the original FedAvg protocol: sample S devices, then
                         *wait* across rounds until all S have responded; only
                         then apply a global update (the paper's straggler-prone
                         baseline, Eq. 3). The global model is frozen while
                         waiting, so updates from different rounds are computed
                         at the same w.
  * SCAFFOLDSampling   — SCAFFOLD control variates on top of the S-device
                         sampling protocol (paper compares against it in §5.1).
  * FedBuffAvg         — buffered-async aggregation (FedBuff-style): merges
                         staleness-weighted updates delivered by the
                         `repro.sim.BufferedKofN` server policy.

All share MIFA's round API: init_state / round_step(state, params, updates,
losses, active, eta, rng).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.mifa import _bcast


@dataclass(frozen=True)
class BiasedFedAvg:
    def init_state(self, params, n_clients: int) -> dict:
        return {"t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        act = active.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(act), 1.0)
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(act, u), 0) / denom, updates)
        new_params = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * act) / denom
        return ({"t": state["t"] + 1}, new_params,
                {"loss": loss, "n_active": jnp.sum(act)})


@dataclass(frozen=True)
class FedBuffAvg:
    """Buffered-async FedAvg (FedBuff-style): the server-side aggregator
    behind `repro.sim.BufferedKofN`.

    `active` arrives as a float32 weight vector (staleness discounts
    1/sqrt(1+s) from the buffered policy, 0 for non-contributors) instead
    of a bool mask — `weight_aware` tells the simulation engines to pass
    weights through. The update is Σ w_i·u_i / |contributors|: dividing by
    the contributor COUNT (not Σw) keeps the step size comparable to
    synchronous FedAvg while stale updates are attenuated, matching the
    FedBuff recipe. With a bool mask it degenerates to `BiasedFedAvg`.
    """

    weight_aware: ClassVar[bool] = True

    def init_state(self, params, n_clients: int) -> dict:
        """Stateless aggregation: only the round counter `t`."""
        return {"t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta,
                   rng=None):
        """One buffered merge: weighted mean over contributors (active > 0),
        server step w <- w - η·mean; loss averages the contributors."""
        w = active.astype(jnp.float32)
        contrib = (w > 0).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(contrib), 1.0)
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(w, u), 0) / denom, updates)
        new_params = jax.tree.map(lambda p, g: (p - eta * g).astype(p.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * contrib) / denom
        return ({"t": state["t"] + 1}, new_params,
                {"loss": loss, "n_active": jnp.sum(contrib)})


@dataclass(frozen=True)
class FedAvgIS:
    """Requires the true participation probabilities (N,)."""

    probs: tuple  # static tuple so the dataclass stays hashable for jit

    def init_state(self, params, n_clients: int) -> dict:
        return {"t": jnp.zeros((), jnp.int32)}

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        act = active.astype(jnp.float32)
        p = jnp.asarray(self.probs, jnp.float32)
        w_is = act / p                       # (N,)
        n = act.shape[0]
        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(w_is, u), 0) / n, updates)
        new_params = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype),
                                  params, mean_G)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        return ({"t": state["t"] + 1}, new_params,
                {"loss": loss, "n_active": jnp.sum(act)})


@dataclass(frozen=True)
class FedAvgSampling:
    """FedAvg with device sampling: wait for the S selected devices."""

    s: int

    def init_state(self, params, n_clients: int) -> dict:
        return {
            "selected": jnp.zeros((n_clients,), bool),
            "received": jnp.zeros((n_clients,), bool),
            "U": jax.tree.map(
                lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32),
                params),
            "t": jnp.zeros((), jnp.int32),        # communication rounds
            "t_updates": jnp.zeros((), jnp.int32),  # applied global updates
            "need_resample": jnp.ones((), bool),
        }

    def _resample(self, rng, n: int) -> jnp.ndarray:
        perm = jax.random.permutation(rng, n)
        mask = jnp.zeros((n,), bool).at[perm[: self.s]].set(True)
        return mask

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        assert rng is not None, "FedAvgSampling needs an rng to sample devices"
        n = active.shape[0]
        selected = jnp.where(state["need_resample"],
                             self._resample(rng, n), state["selected"])
        received = jnp.where(state["need_resample"],
                             jnp.zeros_like(state["received"]),
                             state["received"])

        newly = selected & active & ~received
        U = jax.tree.map(
            lambda u_old, u: jnp.where(_bcast(newly, u), u, u_old),
            state["U"], updates)
        received = received | newly
        complete = jnp.all(~selected | received)

        mean_G = jax.tree.map(
            lambda u: jnp.sum(u * _bcast(selected.astype(jnp.float32), u), 0)
            / self.s, U)
        new_params = jax.tree.map(
            lambda w, g: jnp.where(complete, (w - eta * g).astype(w.dtype), w),
            params, mean_G)

        act = active.astype(jnp.float32)
        loss = jnp.sum(losses * act) / jnp.maximum(jnp.sum(act), 1.0)
        new_state = {
            "selected": selected,
            "received": received,
            "U": U,
            "t": state["t"] + 1,
            "t_updates": state["t_updates"] + complete.astype(jnp.int32),
            "need_resample": complete,
        }
        return new_state, new_params, {
            "loss": loss, "n_active": jnp.sum(act),
            "global_updates": new_state["t_updates"].astype(jnp.float32)}


@dataclass(frozen=True)
class SCAFFOLDSampling:
    """SCAFFOLD (Karimireddy et al. 2020) on the S-device sampling protocol.

    Control variates c_i (per device) and c (server). Clients correct their
    local gradients with (c − c_i); here, with the update-level API, the
    corrected update for device i is  u_i − K·(c_i − c)  (option II of the
    paper, expressed on accumulated gradients), and on completion
       c_i ← c_i + (u_i/K − c_i)·1[i∈S],   c ← c + (S/N)·mean_{i∈S}(Δc_i).
    """

    s: int
    k_steps: int

    def init_state(self, params, n_clients: int) -> dict:
        zeros_n = lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32)
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = FedAvgSampling(self.s).init_state(params, n_clients)
        st["c_i"] = jax.tree.map(zeros_n, params)
        st["c"] = jax.tree.map(zeros, params)
        return st

    def round_step(self, state, params, updates, losses, active, eta, rng=None):
        assert rng is not None
        n = active.shape[0]
        K = float(self.k_steps)
        # variance-reduced updates
        vr_updates = jax.tree.map(
            lambda u, ci, c: u - K * (ci - c[None]), updates,
            state["c_i"], state["c"])

        base = FedAvgSampling(self.s)
        sub = {k: state[k] for k in
               ("selected", "received", "U", "t", "t_updates", "need_resample")}
        new_sub, new_params, metrics = base.round_step(
            sub, params, vr_updates, losses, active, eta, rng)

        # on completion, refresh control variates for the selected cohort
        complete = new_sub["need_resample"]
        sel = new_sub["selected"]
        self32 = sel.astype(jnp.float32)
        # device i's fresh avg gradient estimate = stored U_i / K  + correction
        c_i_new = jax.tree.map(
            lambda Ui, ci, c: jnp.where(
                _bcast(sel & complete, Ui),
                Ui / K,  # U holds vr update; invert correction below
                ci),
            new_sub["U"], state["c_i"], state["c"])
        # invert the (c - c_i) correction stored inside U
        c_i_new = jax.tree.map(
            lambda cin, ci, c: jnp.where(
                _bcast(sel & complete, cin),
                cin + (ci - c[None]), cin),
            c_i_new, state["c_i"], state["c"])
        dc = jax.tree.map(lambda cin, ci: (cin - ci) * _bcast(self32, cin),
                          c_i_new, state["c_i"])
        c_new = jax.tree.map(
            lambda c, d: jnp.where(complete, c + jnp.sum(d, 0) / n, c),
            state["c"], dc)

        new_state = dict(new_sub)
        new_state["c_i"] = jax.tree.map(
            lambda a, b: jnp.where(complete, a, b), c_i_new, state["c_i"])
        new_state["c"] = c_new
        return new_state, new_params, metrics
