"""Int8 update-array storage with per-(client, leaf) scales (beyond-paper).

MIFA's server memory is O(N·d) — the paper acknowledges this is the cost of the
method (§4). We store G^i in int8 with an absmax scale per client per tensor and
*stochastic rounding*, which keeps the stored update an unbiased estimator of
the true update — preserving the bias-correction property MIFA's analysis
relies on (Assumption 2 asks for unbiased gradients; stochastic rounding adds
zero-mean bounded noise, effectively enlarging σ² slightly).

Cuts the qwen1.5-110b update array from 13.75 -> 3.44 GB/chip (docs/architecture.md §3).
Also the quantizer behind `repro.bank.Int8PagedBank`, which adds lazy paging
on top of the same per-row int8 + absmax-scale layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_leaf(rng, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (N, ...) f32 -> (q int8 (N, ...), scale f32 (N,)) stochastic rounding."""
    n = x.shape[0]
    absmax = jnp.max(jnp.abs(x.reshape(n, -1)), axis=1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    sc = scale.reshape((n,) + (1,) * (x.ndim - 1))
    y = x / sc
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(rng, x.shape)
    q = lo + (u < frac).astype(y.dtype)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    sc = scale.reshape((scale.shape[0],) + (1,) * (q.ndim - 1))
    return q.astype(jnp.float32) * sc


def quantize_tree(rng, tree):
    leaves, treedef = jax.tree.flatten(tree)
    rngs = jax.random.split(rng, len(leaves))
    qs, scales = [], []
    for r, leaf in zip(rngs, leaves):
        q, s = quantize_leaf(r, leaf)
        qs.append(q)
        scales.append(s)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales))


def dequantize_tree(qtree, stree):
    return jax.tree.map(dequantize_leaf, qtree, stree)
