"""Host-side FL training loop: participation process + data + algorithm.

The per-round computation (local K-step SGD on every client + algorithm
aggregation) is a single jitted function; the availability mask and minibatch
indices stream in from the host (they are the *environment*, not the model).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_update import client_updates
from repro.core.participation import TauStats


@dataclass
class FLHistory:
    rounds: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    eval_loss: list = field(default_factory=list)
    eval_acc: list = field(default_factory=list)
    n_active: list = field(default_factory=list)
    global_updates: list = field(default_factory=list)
    wall_time: float = 0.0
    tau_bar: float = 0.0
    tau_max: int = 0

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in
                ("rounds", "train_loss", "eval_loss", "eval_acc", "n_active",
                 "global_updates", "wall_time", "tau_bar", "tau_max")}


def run_fl(*, model, algo, participation, batcher, schedule: Callable,
           n_rounds: int, eta_local: Callable | float | None = None,
           weight_decay: float = 0.0, seed: int = 0,
           eval_fn: Callable | None = None, eval_every: int = 10,
           params=None, uses_update_clock: bool = False,
           verbose: bool = False) -> tuple[Any, FLHistory]:
    """Run T rounds of federated training. Returns (params, history).

    batcher.sample_round(t) -> batch pytree with leaves (N, K, mb, ...).
    schedule(t) -> server/local learning rate η_t (paper uses the same for both).
    """
    rng = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init(rng)
    n = batcher.n_clients
    state = algo.init_state(params, n)
    stats = TauStats(n)
    hist = FLHistory()

    @jax.jit
    def round_fn(state, params, batch, active, eta_loc, eta_srv, rng):
        updates, losses = client_updates(model.loss_fn, params, batch,
                                         eta_loc, K=batcher.k_steps,
                                         weight_decay=weight_decay)
        return algo.round_step(state, params, updates, losses, active,
                               eta_srv, rng)

    t0 = time.time()
    for t in range(n_rounds):
        active = participation.sample(t)
        stats.update(active)
        batch = batcher.sample_round(t)
        if uses_update_clock and "t_updates" in state:
            clock = int(state["t_updates"]) + 1
        else:
            clock = t + 1
        eta_srv = float(schedule(clock))
        if eta_local is None:
            eta_loc = eta_srv
        elif callable(eta_local):
            eta_loc = float(eta_local(clock))
        else:
            eta_loc = float(eta_local)
        rng, sub = jax.random.split(rng)
        state, params, metrics = round_fn(
            state, params, batch, jnp.asarray(active),
            jnp.float32(eta_loc), jnp.float32(eta_srv), sub)

        hist.rounds.append(t)
        hist.train_loss.append(float(metrics["loss"]))
        hist.n_active.append(float(metrics["n_active"]))
        if "global_updates" in metrics:
            hist.global_updates.append(float(metrics["global_updates"]))
        if eval_fn is not None and (t % eval_every == 0 or t == n_rounds - 1):
            el, ea = eval_fn(params)
            hist.eval_loss.append((t, float(el)))
            hist.eval_acc.append((t, float(ea)))
            if verbose:
                print(f"  round {t:5d} train={hist.train_loss[-1]:.4f} "
                      f"eval={el:.4f} acc={ea:.4f} active={int(active.sum())}")
    hist.wall_time = time.time() - t0
    hist.tau_bar = stats.tau_bar
    hist.tau_max = stats.tau_max
    return params, hist
