"""Host-side FL training loop: participation process + data + algorithm.

The per-round computation (local K-step SGD on every client + algorithm
aggregation) is a single jitted function; the availability mask and minibatch
indices stream in from the host (they are the *environment*, not the model).

`RoundRunner` owns the jitted round step and all history bookkeeping so that
two drivers can share it unchanged:

  * `run_fl`            — the paper's round-synchronous loop (one availability
                          draw per round, no notion of time), and
  * `repro.sim.engine`  — the discrete-event runtime simulator, which decides
                          *when* each round closes and which updates arrived,
                          and stamps every round with simulated seconds.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_update import client_updates
from repro.core.participation import TauStats

_FALLBACK_WARNED: set[str] = set()


def warn_engine_fallback(msg: str, *, stacklevel: int = 3) -> None:
    """Emit an engine-fallback warning ONCE per distinct message.

    Sweeps (the scenario atlas, fleet grids, repeated run_fl calls) hit the
    same unsupported configuration hundreds of times; the first warning per
    config is signal, the rest is noise — and `simplefilter("always")`
    environments defeat the stdlib's own per-location dedup. The message
    embeds the config-specific reason, so distinct configs still warn.
    `stacklevel` defaults to 3: one frame for this helper plus the
    stacklevel=2 the inline warnings used, so the warning still points at
    the run_fl / run_fleet caller.
    """
    if msg in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(msg)
    warnings.warn(msg, stacklevel=stacklevel)


def _reset_fallback_warnings() -> None:
    """Forget which fallback warnings fired (test isolation hook)."""
    _FALLBACK_WARNED.clear()


def warn_legacy_threefry(mesh) -> None:
    """Warn once when a >1-device mesh runs under the legacy threefry RNG.

    JAX's default (non-partitionable) threefry lowering generates DIFFERENT
    random bits when its operands are sharded — a `jax.random.uniform`
    inside the round function draws different values on a 2x2 mesh than on
    one device, so jit-native scenario masks and any in-program randomness
    silently depend on the mesh shape. `jax_threefry_partitionable=True`
    makes the bits sharding-invariant (at the cost of differing from the
    legacy single-device stream). The mesh test/benchmark worlds set it
    (tests/conftest.py, docs/architecture.md §13).
    """
    n = getattr(mesh, "size", 1)
    if n <= 1 or getattr(jax.config, "jax_threefry_partitionable", True):
        return
    warn_engine_fallback(
        "mesh= with the legacy threefry RNG: in-program random draws "
        "(jit-native scenario masks, algorithm rng) depend on the mesh "
        "shape; set jax.config.update('jax_threefry_partitionable', True) "
        "for sharding-invariant trajectories")


@dataclass
class FLHistory:
    rounds: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    eval_loss: list = field(default_factory=list)
    eval_acc: list = field(default_factory=list)
    n_active: list = field(default_factory=list)
    global_updates: list = field(default_factory=list)
    sim_seconds: list = field(default_factory=list)   # per-round close time
    eval_seconds: list = field(default_factory=list)  # (round, sim_t) per eval
    wall_time: float = 0.0
    tau_bar: float = 0.0
    tau_max: int = 0

    def as_dict(self) -> dict:
        """Plain-dict view of every history field (JSON-serialisable)."""
        return {k: getattr(self, k) for k in
                ("rounds", "train_loss", "eval_loss", "eval_acc", "n_active",
                 "global_updates", "sim_seconds", "eval_seconds", "wall_time",
                 "tau_bar", "tau_max")}

    def record_round(self, t: int, metrics: dict,
                     sim_time: float | None = None) -> None:
        """Append round t's metrics dict (loss, n_active, optional
        global_updates); `sim_time` stamps it with simulated seconds."""
        self.rounds.append(t)
        self.train_loss.append(float(metrics["loss"]))
        self.n_active.append(float(metrics["n_active"]))
        if "global_updates" in metrics:
            self.global_updates.append(float(metrics["global_updates"]))
        if sim_time is not None:
            self.sim_seconds.append(float(sim_time))

    def record_eval(self, t: int, eval_loss: float, eval_acc: float,
                    sim_time: float | None = None) -> None:
        """Append an (round, value) eval point; `sim_time` additionally
        stamps it on the simulated-seconds axis (eval_seconds)."""
        self.eval_loss.append((t, float(eval_loss)))
        self.eval_acc.append((t, float(eval_acc)))
        if sim_time is not None:
            self.eval_seconds.append((t, float(sim_time)))

    def eval_curve(self) -> list[tuple[float, float, float]]:
        """Time-stamped view: (sim_seconds, eval_loss, eval_acc) triples.

        Only meaningful for simulator-driven runs (sim_seconds populated);
        round-synchronous runs fall back to the round index as the time axis.
        """
        times = dict(self.eval_seconds)
        out = []
        for (t, el), (_, ea) in zip(self.eval_loss, self.eval_acc):
            out.append((times.get(t, float(t)), el, ea))
        return out


def _pow2_bucket(c: int) -> int:
    """Smallest power of two >= c — pads cohorts into few jit traces."""
    return 1 << max(int(np.ceil(np.log2(max(c, 1)))), 0)


# --------------------------------------------------------------------------- #
# pure round functions, shared by RoundRunner (jit) and repro.fleet (jit∘vmap)
# --------------------------------------------------------------------------- #

def make_dense_round_fn(model, algo, k_steps: int, weight_decay: float):
    """One dense federated round as a pure function.

    (state, params, batch, active, eta_loc, eta_srv, rng) ->
    (state, params, metrics). RoundRunner jits it; the fleet executor vmaps
    it over a leading trial axis — the SAME function, so the two paths can
    never drift apart.
    """
    def round_fn(state, params, batch, active, eta_loc, eta_srv, rng):
        updates, losses = client_updates(model.loss_fn, params, batch,
                                         eta_loc, K=k_steps,
                                         weight_decay=weight_decay)
        return algo.round_step(state, params, updates, losses, active,
                               eta_srv, rng)
    return round_fn


def make_cohort_update_fn(model, k_steps: int, weight_decay: float):
    """Compact cohort local updates: (params, batch (C, ...), eta_loc) ->
    (updates (C, ...), losses (C,)). Pure; shared with the fleet executor."""
    def cohort_updates_fn(params, batch, eta_loc):
        return client_updates(model.loss_fn, params, batch, eta_loc,
                              K=k_steps, weight_decay=weight_decay)
    return cohort_updates_fn


def apply_mean(params, mean_g, eta_srv):
    """Server step w <- w - η·mean_G (pure; shared with the fleet executor)."""
    return jax.tree.map(
        lambda w, g: (w - eta_srv * g).astype(w.dtype), params, mean_g)


def make_scenario_round_fn(model, algo, k_steps: int, weight_decay: float,
                           scen_fn):
    """One dense round with availability sampled INSIDE the program.

    Wraps `make_dense_round_fn` so the (N,) mask comes from a scenario's
    jit-native surface (`scenarios.AvailabilityProcess.sample_fn`) instead
    of the host: (state, params, batch, scen_state, t, scen_key, eta_loc,
    eta_srv, rng) -> (state, params, metrics, scen_state, mask). `t` is a
    traced int32 scalar (no retrace per round); the returned mask feeds τ
    statistics on the host. The fleet executor vmaps the same composition
    over the trial axis — availability sweeps never materialise a (T, N)
    trace.
    """
    base = make_dense_round_fn(model, algo, k_steps, weight_decay)

    def round_fn(state, params, batch, scen_state, t, scen_key, eta_loc,
                 eta_srv, rng):
        mask, scen_state = scen_fn(scen_key, t, scen_state)
        state, params, metrics = base(state, params, batch, mask, eta_loc,
                                      eta_srv, rng)
        return state, params, metrics, scen_state, mask

    return round_fn


def make_scan_round_fn(model, algo, k_steps: int, weight_decay: float, *,
                       scen_fn=None, cohort: bool = False,
                       track_tau: bool = False):
    """Lift the pure round functions into a `lax.scan` body.

    The body computes ONE federated round and has the scan signature
    ``(carry, xs) -> (carry, ys)``; `repro.core.scan_engine` scans it over a
    chunk of rounds so T rounds compile into one XLA program, and the fleet
    executor vmaps the SAME body over a leading trial axis before scanning —
    per round it is exactly `make_dense_round_fn` / `make_scenario_round_fn`
    / `make_cohort_round_fn`, so scan trajectories are fp32 bit-exact
    against the per-round dispatch loop (tests/test_scan_engine.py).

    Three modes (exactly one):
      * dense mask (default)   — xs carries the host-drawn ``active`` (N,)
        mask per round (legacy participation processes).
      * scenario (`scen_fn`)   — availability is sampled INSIDE the body
        from the jit-native scenario surface; the scenario state threads
        through the carry and xs carries only the round index ``t``. With
        `track_tau`, τ statistics accumulate in the carry ((N,) int32
        current/max τ) and per-round int32 sums ride the ys — no (T, N)
        mask trace is ever materialised.
      * cohort (`cohort=True`) — xs carries the padded cohort (``ids``,
        ``valid``, compact batch); jittable banks only.

    Carry layout: ``{"state", "params", "rng"}`` plus ``{"scen_state",
    "scen_key"}`` in scenario mode and ``{"tau", "tau_max"}`` when
    `track_tau`. ys are the round's metrics dict (plus ``tau_sum`` /
    ``tau_sq_sum``, exact while Σ τ² per round < 2^31).
    """
    assert not (cohort and scen_fn is not None), \
        "cohort scan bodies take host-assembled cohorts, not a scen_fn"
    assert not (track_tau and scen_fn is None), \
        "track_tau is for scenario bodies (mask-mode τ runs on the host)"

    if cohort:
        cohort_round = make_cohort_round_fn(model, algo, k_steps,
                                            weight_decay)

        def body(carry, x):
            rng, sub = jax.random.split(carry["rng"])
            state, params, metrics = cohort_round(
                carry["state"], carry["params"], x["batch"], x["ids"],
                x["valid"], x["eta_loc"], x["eta_srv"], sub)
            return ({"state": state, "params": params, "rng": rng}, metrics)

        return body

    if scen_fn is not None:
        scen_round = make_scenario_round_fn(model, algo, k_steps,
                                            weight_decay, scen_fn)

        def body(carry, x):
            rng, sub = jax.random.split(carry["rng"])
            state, params, metrics, scen_state, mask = scen_round(
                carry["state"], carry["params"], x["batch"],
                carry["scen_state"], x["t"], carry["scen_key"],
                x["eta_loc"], x["eta_srv"], sub)
            out = {"state": state, "params": params, "rng": rng,
                   "scen_state": scen_state, "scen_key": carry["scen_key"]}
            if track_tau:
                tau = jnp.where(mask, 0, carry["tau"] + 1)
                out["tau"] = tau
                out["tau_max"] = jnp.maximum(carry["tau_max"], tau)
                metrics = dict(metrics, tau_sum=jnp.sum(tau),
                               tau_sq_sum=jnp.sum(tau * tau))
            return out, metrics

        return body

    base = make_dense_round_fn(model, algo, k_steps, weight_decay)

    def body(carry, x):
        rng, sub = jax.random.split(carry["rng"])
        state, params, metrics = base(
            carry["state"], carry["params"], x["batch"], x["active"],
            x["eta_loc"], x["eta_srv"], sub)
        return ({"state": state, "params": params, "rng": rng}, metrics)

    return body


def make_cohort_round_fn(model, algo, k_steps: int, weight_decay: float):
    """One whole cohort round (local updates + bank scatter + server step)
    as a pure function — jittable banks only.

    RoundRunner jits it; the fleet executor runs the structurally identical
    batched composition. Keeping BOTH paths single fused programs is what
    makes them bit-identical per trial: XLA's fp32 fusion decisions depend
    on jit boundaries, so the sequential path must not split the round into
    separate dispatches the vmapped path fuses.
    """
    updates_fn = make_cohort_update_fn(model, k_steps, weight_decay)

    def cohort_round(state, params, batch, padded, valid, eta_loc, eta_srv,
                     rng):
        updates, losses = updates_fn(params, batch, eta_loc)
        state, mean_g, metrics = algo.round_step_cohort(
            state, padded, valid, updates, losses, rng=rng)
        params = apply_mean(params, mean_g, eta_srv)
        return state, params, metrics

    return cohort_round


class RoundRunner:
    """One jitted federated round + bookkeeping, shared across drivers.

    The driver decides which mask of client updates is applied each round
    (availability in the synchronous loop; arrivals in the simulator) and may
    stamp each round with a simulated-seconds timestamp.

    Two round paths, selected by the algorithm:

      * dense (default)             — `client_updates` vmaps over ALL N
        clients and `algo.round_step` consumes the (N, ...) update array;
      * cohort (`algo.cohort_based`) — only the active cohort's batches are
        sampled and updated: compact (C, ...) leaves where C is |A(t)| padded
        to a power-of-two bucket (or `cohort_capacity`), then applied through
        the algorithm's memory bank. Pad slots carry valid=False and point at
        the bank's dummy row `n_clients`. O(|A|·d) per round instead of
        O(N·d); both `run_fl` and `sim.engine` drive it unchanged via
        `step(t, mask)`, and million-client drivers can call
        `step_cohort(t, ids)` directly to skip O(N) mask work entirely.
    """

    def __init__(self, *, model, algo, batcher, schedule: Callable,
                 eta_local: Callable | float | None = None,
                 weight_decay: float = 0.0, seed: int = 0,
                 params=None, uses_update_clock: bool = False,
                 cohort_capacity: int | None = None, scenario=None):
        self.model = model
        self.algo = algo
        self.batcher = batcher
        self.schedule = schedule
        self.eta_local = eta_local
        self.weight_decay = weight_decay
        self.uses_update_clock = uses_update_clock
        self.cohort_capacity = cohort_capacity
        self.rng = jax.random.PRNGKey(seed)
        self.params = model.init(self.rng) if params is None else params
        self.n_clients = batcher.n_clients
        self.state = algo.init_state(self.params, self.n_clients)
        # strict=False: simulator round policies (Deadline) legitimately
        # drop round-0 responders — the init convention applies there
        self.stats = TauStats(self.n_clients, strict=False)
        self.hist = FLHistory()
        self.cohort_mode = getattr(algo, "cohort_based", False)
        self._init_scenario(scenario, weight_decay)

        if self.cohort_mode:
            self.cohort_updates_fn = jax.jit(make_cohort_update_fn(
                model, batcher.k_steps, weight_decay))
            self.apply_mean_fn = jax.jit(apply_mean)
            self.round_fn = None
            # jittable banks get the whole round as ONE program (fewer
            # dispatches, and bit-identical to the vmapped fleet path);
            # host-offloaded banks keep the split updates/scatter/apply path
            if getattr(getattr(algo, "bank", None), "jittable", False):
                self.cohort_round_fn = jax.jit(
                    make_cohort_round_fn(model, algo, batcher.k_steps,
                                         weight_decay),
                    donate_argnums=(0,))
            else:
                self.cohort_round_fn = None
        else:
            self.round_fn = jax.jit(make_dense_round_fn(
                model, algo, batcher.k_steps, weight_decay))

    def _init_scenario(self, scenario, weight_decay: float) -> None:
        """Wire a `repro.scenarios` scenario (or bare process) in.

        Dense algorithms get the jit-native surface: availability is
        sampled inside the jitted round (`make_scenario_round_fn`), keyed
        by the scenario's own PRNG stream. Cohort algorithms need the mask
        on the host to assemble compact batches, so they fall back to the
        scenario's host surface — identical masks either way.
        """
        self.scenario_round_fn = None
        self._scen_sampler = None
        if scenario is None:
            self.scen_process = None
            return
        from repro.scenarios.base import as_process
        proc = as_process(scenario)
        assert proc.n == self.n_clients, (proc.n, self.n_clients)
        self.scen_process = proc
        if self.cohort_mode:
            self._scen_sampler = proc.host_sampler()
        else:
            self.scenario_round_fn = jax.jit(
                make_scenario_round_fn(self.model, self.algo,
                                       self.batcher.k_steps, weight_decay,
                                       proc.sample_fn()),
                donate_argnums=(0,))
            self.scen_state = proc.init_state()
            self.scen_key = proc.key
            # windowed processes (trace replay) carry only `window` rounds
            # of masks in scen_state; the loop engine re-pages between
            # rounds (the scan engine uses its pre_chunk hook instead).
            # None origin = unknown coverage, load before first use.
            self._scen_win_start = (
                0 if getattr(proc, "scan_window", None) is not None
                else None)

    def learning_rates(self, t: int) -> tuple[float, float]:
        """η_local, η_server for round t (update-clock aware)."""
        if self.uses_update_clock and "t_updates" in self.state:
            clock = int(self.state["t_updates"]) + 1
        else:
            clock = t + 1
        eta_srv = float(self.schedule(clock))
        if self.eta_local is None:
            eta_loc = eta_srv
        elif callable(self.eta_local):
            eta_loc = float(self.eta_local(clock))
        else:
            eta_loc = float(self.eta_local)
        return eta_loc, eta_srv

    def step(self, t: int, active: np.ndarray,
             sim_time: float | None = None) -> dict:
        """Apply one round with `active` (N,) bool as the applied-update
        mask; `sim_time` stamps it with simulated seconds. Returns the
        round's metrics dict."""
        self.stats.update(np.asarray(active, bool), sim_time=sim_time)
        if self.cohort_mode:
            ids = np.flatnonzero(np.asarray(active, bool))
            return self.step_cohort(t, ids, sim_time=sim_time)
        batch = self.batcher.sample_round(t)
        eta_loc, eta_srv = self.learning_rates(t)
        self.rng, sub = jax.random.split(self.rng)
        self.state, self.params, metrics = self.round_fn(
            self.state, self.params, batch, jnp.asarray(active),
            jnp.float32(eta_loc), jnp.float32(eta_srv), sub)
        self.hist.record_round(t, metrics, sim_time=sim_time)
        return metrics

    def step_scenario(self, t: int, sim_time: float | None = None) -> dict:
        """Apply one round with availability drawn BY the scenario.

        Dense path: the mask is sampled inside the jitted round function
        (device-side, no host trace) and returned only for τ statistics.
        Cohort path: the scenario's host surface draws the same mask and
        the round goes through `step` unchanged.
        """
        assert self.scen_process is not None, \
            "construct RoundRunner(scenario=...) to use step_scenario"
        if self.scenario_round_fn is None:        # cohort: host surface
            return self.step(t, self._scen_sampler.sample(t),
                             sim_time=sim_time)
        w = getattr(self.scen_process, "scan_window", None)
        if w is not None:
            ws = self._scen_win_start
            if ws is None or not ws <= t < ws + w:
                t0 = (t // w) * w
                self.scen_state = self.scen_process.load_window(
                    self.scen_state, t0)
                self._scen_win_start = t0
        batch = self.batcher.sample_round(t)
        eta_loc, eta_srv = self.learning_rates(t)
        self.rng, sub = jax.random.split(self.rng)
        (self.state, self.params, metrics, self.scen_state,
         mask) = self.scenario_round_fn(
            self.state, self.params, batch, self.scen_state, jnp.int32(t),
            self.scen_key, jnp.float32(eta_loc), jnp.float32(eta_srv), sub)
        self.stats.update(np.asarray(mask, bool), sim_time=sim_time)
        self.hist.record_round(t, metrics, sim_time=sim_time)
        return metrics

    def step_cohort(self, t: int, ids: np.ndarray,
                    sim_time: float | None = None) -> dict:
        """Apply one O(|A|·d) cohort round; `ids` are the active client
        rows, `sim_time` the optional simulated-seconds stamp.

        Called directly (million-client drivers), τ statistics are skipped —
        TauStats is itself O(N) per round. `step` keeps them.
        """
        assert self.cohort_mode, "step_cohort needs a cohort_based algorithm"
        from repro.bank.base import check_unique_ids
        ids = np.asarray(ids, np.int64)
        check_unique_ids(ids)    # duplicates would corrupt the bank's G_sum
        c = len(ids)
        cap = self.cohort_capacity or _pow2_bucket(c)
        if c > cap:          # stochastic overflow past the configured capacity
            cap = _pow2_bucket(c)
        padded = np.full(cap, self.n_clients, np.int64)   # pad -> dummy row
        padded[:c] = ids
        valid = np.zeros(cap, bool)
        valid[:c] = True
        # pad slots still need *some* real client's batch shape; row 0's
        # content is computed then discarded by the valid mask
        batch = self.batcher.sample_round(
            t, client_ids=np.where(valid, padded, 0))
        eta_loc, eta_srv = self.learning_rates(t)
        self.rng, sub = jax.random.split(self.rng)
        # paged banks fault this round's rows in before the jitted program
        # runs (identity for every other backend)
        prep = getattr(self.algo, "prepare_cohort", None)
        if prep is not None:
            self.state = prep(self.state, padded[valid])
        if self.cohort_round_fn is not None:
            self.state, self.params, metrics = self.cohort_round_fn(
                self.state, self.params, batch, jnp.asarray(padded),
                jnp.asarray(valid), jnp.float32(eta_loc),
                jnp.float32(eta_srv), sub)
        else:
            updates, losses = self.cohort_updates_fn(self.params, batch,
                                                     jnp.float32(eta_loc))
            self.state, mean_g, metrics = self.algo.round_step_cohort(
                self.state, padded, valid, updates, losses, rng=sub)
            self.params = self.apply_mean_fn(self.params, mean_g,
                                             jnp.float32(eta_srv))
        self.hist.record_round(t, metrics, sim_time=sim_time)
        return metrics

    def evaluate(self, t: int, eval_fn: Callable,
                 sim_time: float | None = None) -> tuple[float, float]:
        """Run `eval_fn(params) -> (loss, acc)` and record it at round t."""
        el, ea = eval_fn(self.params)
        self.hist.record_eval(t, el, ea, sim_time=sim_time)
        return float(el), float(ea)

    def finalize(self) -> tuple[Any, FLHistory]:
        """Seal τ statistics into the history; returns (params, history)."""
        self.hist.tau_bar = self.stats.tau_bar
        self.hist.tau_max = self.stats.tau_max
        return self.params, self.hist


def run_fl(*, model, algo, batcher, schedule: Callable, n_rounds: int,
           participation=None, scenario=None, sim=None,
           eta_local: Callable | float | None = None,
           weight_decay: float = 0.0, seed: int = 0,
           eval_fn: Callable | None = None, eval_every: int = 10,
           params=None, uses_update_clock: bool = False,
           cohort_capacity: int | None = None, engine: str = "loop",
           scan_chunk: int = 64, checkpoint=None, mesh=None, cfg=None,
           verbose: bool = False) -> tuple[Any, FLHistory]:
    """Run T round-synchronous rounds of federated training.

    Availability comes from exactly one of:
      * participation — legacy host process (``.sample(t) -> (N,) bool``);
        one draw per round on the host, mask streamed into the jitted round.
      * scenario — a `repro.scenarios` Scenario/process; dense algorithms
        sample the mask INSIDE the jitted round (jit-native surface),
        cohort algorithms use the scenario's host surface (same masks).

    `sim` switches the run onto the simulated wall clock: pass a
    `repro.sim.compiled.SimSpec` (server policy + latency model + temporal
    config) and rounds open/close in simulated seconds under that policy —
    the applied-update mask becomes the policy's arrival decision instead
    of the raw availability draw. Under ``engine="scan"`` the compiled
    simulator (`repro.sim.compiled.SimScanDriver`) runs the whole event
    flow in-program when `sim_scan_supported` says yes; otherwise (and
    always under ``engine="loop"``) the discrete-event heap engine
    (`repro.sim.engine.FedSimEngine`) drives it, with a warning naming the
    blocker under ``engine="scan"`` and a raise under ``"scan_strict"``.

    `model` supplies init/loss/accuracy; batcher.sample_round(t) -> batch
    pytree with leaves (N, K, mb, ...); schedule(t) -> server learning rate
    η_t for each of the `n_rounds` rounds (`eta_local` overrides the
    client-side rate; the paper uses the same for both). `seed` keys model
    init and the round RNG (or pass `params` to skip init);
    `weight_decay` applies to the K local SGD steps. `eval_fn(params) ->
    (loss, acc)` runs every `eval_every` rounds; `uses_update_clock` drives
    schedules off applied global updates instead of rounds
    (FedAvgSampling-style). cohort_capacity pins the cohort-path pad width
    (default: per-round pow-2 buckets). Pad slots are mathematically inert
    either way, but fp32 reduction *grouping* depends on the padded
    length — pin the capacity when comparing trajectories bit-for-bit
    across drivers (see tests/test_fleet).

    `engine` selects the execution strategy (docs/architecture.md §9):
      * "loop" — one jitted dispatch per round (the historical path).
      * "scan" — `repro.core.scan_engine`: rounds are compiled into
        `lax.scan` programs of up to `scan_chunk` rounds each, fp32
        bit-exact against the loop. Configurations the scan cannot express
        (update-clock schedules, host-offloaded banks) fall back to the
        loop with a warning.
      * "scan_strict" — like "scan" but unsupported configurations raise.

    `checkpoint` (a `repro.checkpoint.CheckpointSpec`) wires long-horizon
    durability: the scan engine snapshots the FULL run state (params,
    algorithm state incl. bank pages + host residency bookkeeping, round
    RNG, scenario/trace cursor, τ stats, history) through
    `checkpoint.run_state.save_run` after every `checkpoint.every`
    completed rounds, atomically. With ``checkpoint.resume=True`` the
    latest snapshot in ``checkpoint.dir`` is restored and the run
    continues from its round — fp32 bit-exact against the uninterrupted
    run (docs/operations.md runbook, pinned in tests/test_trace_replay).
    Scan engines only: snapshots ride chunk boundaries, so ``engine``
    must not be "loop", and a configuration the scan cannot express
    raises rather than silently dropping durability.

    `mesh` (scan engines only) places the scan carry under explicit
    shardings (`sharding.rules.scan_carry_specs`): params by the model
    rules when `cfg` (an `ArchConfig`) is given, MIFA's update array /
    bank rows / scenario chain state with the client axis over the mesh's
    data axes — one compiled program, data-parallel over clients and
    model-parallel over d (docs/architecture.md §13). A `DenseBank`
    constructed without its own mesh inherits `mesh`/`cfg` so its rows
    pad to divide the data extent (`sharding.rules.padded_bank_rows`).
    Sharded client-axis reductions group partial sums per device, so
    trajectories match single-device runs to fp32 reduction-order
    tolerance, not bitwise (tests/test_sharded_scan.py pins both).
    """
    if (participation is None) == (scenario is None):
        raise ValueError("pass exactly one of participation= or scenario=")
    if engine not in ("loop", "scan", "scan_strict"):
        raise ValueError(f"unknown engine {engine!r}: expected 'loop', "
                         "'scan', or 'scan_strict'")
    if checkpoint is not None:
        if sim is not None:
            raise ValueError("checkpoint= is not supported for simulated "
                             "runs (the compiled simulator carry holds "
                             "event-queue state with no snapshot schema)")
        if engine == "loop":
            raise ValueError("checkpoint= rides the scan engine's chunk "
                             "boundaries; pass engine='scan' (or "
                             "'scan_strict')")
    if mesh is not None:
        if engine == "loop":
            raise ValueError("mesh= places the scan carry; it has no effect "
                             "under engine='loop' — pass engine='scan'")
        if sim is not None:
            raise ValueError("mesh= is not supported for simulated runs "
                             "(the compiled simulator carry has no "
                             "sharding rules yet)")
        warn_legacy_threefry(mesh)
        # banks build their rows inside RoundRunner.__init__ (algo.init_state
        # -> bank.init), so a mesh-less bank inherits the run's mesh here
        bank = getattr(algo, "bank", None)
        if (bank is not None and hasattr(bank, "mesh")
                and bank.mesh is None):
            bank.mesh = mesh
            bank.cfg = cfg if getattr(bank, "cfg", None) is None else bank.cfg
    runner = RoundRunner(model=model, algo=algo, batcher=batcher,
                         schedule=schedule, eta_local=eta_local,
                         weight_decay=weight_decay, seed=seed, params=params,
                         uses_update_clock=uses_update_clock,
                         cohort_capacity=cohort_capacity, scenario=scenario)
    if sim is not None:
        from repro.sim.compiled import run_sim_scan, sim_scan_supported
        from repro.sim.engine import FedSimEngine
        if engine != "loop":
            ok, why = sim_scan_supported(runner, sim)
            if ok:
                return run_sim_scan(runner, sim, n_rounds,
                                    scan_chunk=scan_chunk, eval_fn=eval_fn,
                                    eval_every=eval_every, verbose=verbose)
            if engine == "scan_strict":
                raise ValueError(f"engine='scan_strict': {why}")
            warn_engine_fallback(
                f"engine='scan' unsupported for this simulated "
                f"configuration ({why}); falling back to the "
                "discrete-event heap engine")
        part = participation if participation is not None \
            else runner.scen_process.host_sampler()
        eng = FedSimEngine(runner, sim.policy, part, sim.latency, sim.config,
                           seed=seed)
        t0 = time.time()
        params, hist = eng.run(n_rounds, eval_fn=eval_fn,
                               eval_every=eval_every)
        hist.wall_time = time.time() - t0
        return params, hist
    start_round = 0
    if checkpoint is not None and checkpoint.resume:
        from repro.checkpoint.run_state import (fast_forward_sampler,
                                                restore_run)
        start_round = restore_run(runner, checkpoint)
        if start_round:
            # host availability streams are not in the snapshot; replay
            # them through the restored rounds so the remaining rounds
            # draw exactly the uninterrupted run's masks
            fast_forward_sampler(participation, start_round)
            fast_forward_sampler(runner._scen_sampler, start_round)
        if start_round >= n_rounds:
            return runner.finalize()
    if engine != "loop":
        from repro.core.scan_engine import ScanDriver, scan_supported
        ok, why = scan_supported(runner)
        if ok:
            t0 = time.time()
            ScanDriver(runner, scan_chunk=scan_chunk, mesh=mesh,
                       cfg=cfg).run(
                n_rounds, participation=participation, eval_fn=eval_fn,
                eval_every=eval_every, verbose=verbose,
                checkpoint=checkpoint, start_round=start_round)
            runner.hist.wall_time = time.time() - t0
            return runner.finalize()
        if engine == "scan_strict":
            raise ValueError(f"engine='scan_strict': {why}")
        if checkpoint is not None:
            raise ValueError(
                f"checkpoint= needs the scan engine, but this "
                f"configuration cannot scan ({why}); refusing to fall "
                "back and silently drop durability")
        if mesh is not None:
            raise ValueError(f"engine='scan' with mesh= cannot fall back "
                             f"to the per-round loop (the loop ignores "
                             f"mesh); blocker: {why}")
        warn_engine_fallback(
            f"engine='scan' unsupported for this configuration "
            f"({why}); falling back to the per-round loop")
    t0 = time.time()
    for t in range(n_rounds):
        if scenario is not None:
            metrics = runner.step_scenario(t)
            n_active = int(metrics["n_active"])
        else:
            active = participation.sample(t)
            runner.step(t, active)
            n_active = int(active.sum())
        if eval_fn is not None and (t % eval_every == 0 or t == n_rounds - 1):
            el, ea = runner.evaluate(t, eval_fn)
            if verbose:
                print(f"  round {t:5d} train={runner.hist.train_loss[-1]:.4f} "
                      f"eval={el:.4f} acc={ea:.4f} active={n_active}")
    runner.hist.wall_time = time.time() - t0
    return runner.finalize()
