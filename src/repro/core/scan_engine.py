"""Whole-run scan engine: compile T federated rounds into one XLA program.

The per-round loop (`run_fl`'s historical path) pays one jitted dispatch,
one host→device batch upload, and one Python iteration per round. On the
tiny models where availability studies actually run (the paper's Fig. 2,
correlated-availability grids), that dispatch overhead dominates compute by
an order of magnitude. This module fuses the run itself: the pure round
functions of `core.runner` become a `lax.scan` body
(`runner.make_scan_round_fn`) and T rounds execute as ⌈T/scan_chunk⌉
compiled programs.

Chunking (`scan_chunk`): the scan consumes stacked per-round inputs
(batches, masks, learning rates), so an unchunked T-round program would
hold T rounds of batches on device at once and could only report history
at the very end. Chunks bound that memory by the chunk length, flush
`FLHistory` every chunk boundary, and give eval/logging host points — and
the chunk carry is donated, so params/state buffers are reused in place
across chunks. Chunk boundaries additionally snap to eval rounds so
`eval_fn` runs at exactly the rounds the loop engine would evaluate.

Carry / ys layout (see `make_scan_round_fn`): the carry is
``{"state", "params", "rng"}`` plus the scenario's ``{"scen_state",
"scen_key"}`` and the τ accumulators ``{"tau", "tau_max"}``; the stacked
ys are the per-round metrics `FLHistory` records, plus per-round τ sums so
`TauStats` can be reconstructed without materialising a (T, N) mask trace.

What falls back to the loop (`scan_supported`): update-clock schedules
(the host schedule callable would need the device-side applied-update
counter every round) and host-offloaded banks (`HostBank`,
`Int8PagedBank` — their rows live outside jit by design). `run_fl`
warns and loops for these under ``engine="scan"`` and raises under
``engine="scan_strict"``. `PagedDeviceBank` is NOT excluded: its page
table is a jnp array in the scan carry, and its host↔device page
streaming runs at chunk boundaries through the ``pre_chunk`` hook of
`run_pipelined_chunks` — each chunk's cohort union is paged in while
the host still owns the carry, so N=10⁶ runs scan with bounded device
bytes.

Bit-exactness: per round the scan body IS the loop's jitted round function,
and `jax.random.split` / `fold_in` are deterministic bitwise, so scan
trajectories are fp32 bit-exact against the loop for dense algorithms and
for jittable banks with a pinned `cohort_capacity` (the loop's per-round
pow-2 cohort buckets vary with |A(t)|; a scan program has one shape, so the
engine pins unpinned cohort runs to the N-client bucket — pin the capacity
on both paths when comparing, per `run_fl`'s docstring).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runner import RoundRunner, _pow2_bucket, make_scan_round_fn


def scan_supported(runner: RoundRunner) -> tuple[bool, str]:
    """Can this runner's configuration execute as a scan? (ok, reason)."""
    if runner.uses_update_clock:
        return False, ("update-clock schedules read the device-side "
                       "applied-update counter between rounds; the host "
                       "cannot precompute a chunk of learning rates")
    bank = getattr(runner.algo, "bank", None)
    if runner.cohort_mode and not getattr(bank, "jittable", False):
        return False, (
            f"{type(bank).__name__} is host-offloaded: its rows live "
            "outside jit by design and cannot ride a scan carry; scan-"
            "capable banks are DenseBank ('dense') and PagedDeviceBank "
            "('paged_device', bounded device bytes via a jit-native page "
            "table)")
    return True, ""


def _eval_rounds(n_rounds: int, eval_every: int, has_eval: bool) -> set:
    """The rounds after which the loop engine would run eval_fn."""
    if not has_eval:
        return set()
    pts = {t for t in range(n_rounds) if t % eval_every == 0}
    pts.add(n_rounds - 1)
    return pts


def chunk_bounds(n_rounds: int, scan_chunk: int, eval_rounds: set,
                 start: int = 0) -> list[tuple[int, int]]:
    """[t0, t1) segments over rounds [start, n_rounds): cut every
    `scan_chunk` rounds AND after each eval/sync round, so evals land
    exactly where the loop engine runs them. `start` > 0 is the resume
    case (checkpoint restore): the chunk grid stays anchored at round 0,
    so a resumed run shares every boundary past `start` with the
    uninterrupted run — and by chunk-boundary invariance the extra cut at
    `start` itself does not perturb the trajectory."""
    if scan_chunk < 1:
        raise ValueError(f"scan_chunk must be >= 1, got {scan_chunk}")
    cuts = {start, n_rounds}
    cuts.update(range(0, n_rounds, scan_chunk))
    cuts.update(t + 1 for t in eval_rounds if t < n_rounds)
    edges = sorted(c for c in cuts if start <= c <= n_rounds)
    return list(zip(edges[:-1], edges[1:]))


def _stack(trees: list) -> dict:
    """Stack a list of per-round pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: np.stack(xs), *trees)


def pad_cohort(ids: np.ndarray, cap: int, n_clients: int,
               round_t: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad one cohort's ids to the scan capacity: (padded, valid).

    Pad slots point at the bank's dummy row `n_clients` with valid=False,
    exactly like `RoundRunner.step_cohort`. A scan program has ONE static
    shape, so a cohort overflowing `cap` raises instead of widening per
    round the way the loop engine's pow-2 buckets do.
    """
    if len(ids) > cap:
        raise ValueError(
            f"round {round_t}: cohort of {len(ids)} overflows the scan "
            f"capacity {cap}; raise cohort_capacity (a scan program cannot "
            "widen per round the way the loop engine's pow-2 buckets do)")
    padded = np.full(cap, n_clients, np.int64)
    padded[:len(ids)] = ids
    valid = np.zeros(cap, bool)
    valid[:len(ids)] = True
    return padded, valid


def run_pipelined_chunks(carry, segments, *, chunk_fn, build_xs, writeback,
                         flush, sync_rounds=frozenset(), on_sync=None,
                         pre_chunk=None):
    """Software-pipelined chunk execution, shared by `ScanDriver` and
    `fleet.FleetScanDriver`.

    Each chunk dispatches asynchronously and is flushed one iteration
    late, so the NEXT chunk's host-side xs assembly overlaps the device
    executing the current one; the pending flush always completes before
    the pending carry is donated back into `chunk_fn`. Rounds in
    `sync_rounds` (eval boundaries) force the flush and then call
    `on_sync(t)` with the chunk's results on the host.

    Callback contract: ``build_xs(t0, t1)`` assembles a chunk's stacked
    inputs; ``chunk_fn(carry, xs) -> (carry, ys)`` is the jitted scan;
    ``writeback(carry)`` publishes the (not-yet-materialised) carry to the
    runner; ``flush(t0, t1, ys, carry)`` blocks on the chunk's results and
    records history. ``pre_chunk(carry) -> carry``, when given, runs after
    ``build_xs`` (which knows the upcoming chunk's working set) and right
    before the chunk dispatches — the streaming hook paged banks use to
    fault the chunk union's pages in while the host still owns the carry;
    its device reads block on the previous chunk only when pages actually
    move. Returns the final carry.
    """
    pending = None
    for t0, t1 in segments:
        xs = build_xs(t0, t1)
        if pending is not None:
            flush(*pending)
        if pre_chunk is not None:
            carry = pre_chunk(carry)
        carry, ys = chunk_fn(carry, xs)
        writeback(carry)
        pending = (t0, t1, ys, carry)
        if (t1 - 1) in sync_rounds:
            flush(*pending)
            pending = None
            on_sync(t1 - 1)
    if pending is not None:
        flush(*pending)
    return carry


class ScanDriver:
    """Drives a `RoundRunner` through T rounds as chunked scan programs.

    Constructed by `run_fl(engine="scan")` after `scan_supported` says yes.
    Reuses the runner's init (params, algorithm state, scenario wiring,
    RNG stream) so the trajectory is the one the loop engine would produce;
    on `run` completion the runner's state/params/history/τ stats are
    written back, and `runner.finalize()` works unchanged.
    """

    def __init__(self, runner: RoundRunner, *, scan_chunk: int = 64,
                 mesh=None, cfg=None):
        if scan_chunk < 1:
            raise ValueError(f"scan_chunk must be >= 1, got {scan_chunk}")
        self.r = runner
        self.scan_chunk = scan_chunk
        self.mesh = mesh
        self.cfg = cfg
        # NamedSharding tree matching the carry, set by `_init_carry`
        # (which runs before the first `_chunk_fn` trace — the closure
        # below reads it at trace time, not at definition time)
        self._carry_shardings = None
        r = runner
        self.scenario_mode = (r.scen_process is not None
                              and not r.cohort_mode)
        scen_fn = r.scen_process.sample_fn() if self.scenario_mode else None
        body = make_scan_round_fn(
            r.model, r.algo, r.batcher.k_steps, r.weight_decay,
            scen_fn=scen_fn, cohort=r.cohort_mode,
            track_tau=self.scenario_mode)
        if mesh is not None:
            # re-pin the carry's placement after every round: without the
            # constraint XLA is free to resharded intermediates, and the
            # donated carry must keep one layout across chunk boundaries
            inner = body

            def body(carry, x):
                carry, ys = inner(carry, x)
                return (jax.lax.with_sharding_constraint(
                    carry, self._carry_shardings), ys)

        self._chunk_fn = jax.jit(
            lambda carry, xs: jax.lax.scan(body, carry, xs),
            donate_argnums=(0,))
        if r.cohort_mode:
            # one static shape for the whole program: unpinned runs pad to
            # the N-client bucket (the loop's per-round buckets vary)
            self.cap = r.cohort_capacity or _pow2_bucket(r.n_clients)
        # the union of the upcoming chunk's cohorts, stashed by _build_xs
        # for the paged-bank pre_chunk residency hook
        self._last_union = None
        # windowed scenarios (trace replay): the carried availability
        # window is re-paged by the same pre_chunk hook; _seg is the
        # upcoming chunk's [t0, t1), _win_start the host-tracked origin of
        # the window currently in the carry (None = force a load — also
        # the resume case, where the restored carry's window is opaque)
        self._scan_window = (getattr(r.scen_process, "scan_window", None)
                             if self.scenario_mode else None)
        if self._scan_window is not None and scan_chunk > self._scan_window:
            raise ValueError(
                f"scan_chunk={scan_chunk} exceeds the scenario's carried "
                f"availability window ({self._scan_window} rounds): a chunk "
                "must be coverable by one window. Raise the scenario's "
                "window= or lower scan_chunk")
        self._seg = None
        self._win_start = None

    # ------------------------------------------------------------------ #
    def _init_carry(self) -> dict:
        r = self.r
        # copy params: the chunk call donates the whole carry, and the
        # initial params may be a caller-passed array (run_fl(params=...))
        # that the loop engine would never invalidate — donation must only
        # ever consume engine-owned buffers. One O(d) copy per run; every
        # later chunk donates the previous chunk's own output.
        params = jax.tree.map(jnp.array, r.params)
        carry = {"state": r.state, "params": params, "rng": r.rng}
        if self.scenario_mode:
            carry["scen_state"] = r.scen_state
            carry["scen_key"] = r.scen_key
            carry["tau"] = jnp.asarray(r.stats.tau, jnp.int32)
            carry["tau_max"] = jnp.asarray(r.stats.tau_max_per_dev,
                                           jnp.int32)
        if self.mesh is not None:
            carry = self._shard_carry(carry)
        return carry

    def _shard_carry(self, carry: dict) -> dict:
        """Place the initial carry under `sharding.rules.scan_carry_specs`
        and remember the shardings — the scan body re-pins them every
        round via `with_sharding_constraint`."""
        from jax.sharding import NamedSharding
        from repro.sharding.rules import scan_carry_specs
        bank = getattr(self.r.algo, "bank", None)
        rows = getattr(bank, "n_rows", 0)
        specs = scan_carry_specs(carry, self.mesh, cfg=self.cfg,
                                 n_clients=self.r.n_clients,
                                 row_counts=(rows,) if rows else ())
        self._carry_shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        return jax.tree.map(jax.device_put, carry, self._carry_shardings)

    def _writeback(self, carry: dict) -> None:
        r = self.r
        r.state, r.params, r.rng = (carry["state"], carry["params"],
                                    carry["rng"])
        if self.scenario_mode:
            r.scen_state = carry["scen_state"]
            # the key is carried through unchanged, but the INPUT buffer
            # was donated — keep the runner pointing at the live output
            # (checkpointing reads runner.scen_key between chunks)
            r.scen_key = carry["scen_key"]

    def _etas(self, t0: int, t1: int) -> tuple[np.ndarray, np.ndarray]:
        pairs = [self.r.learning_rates(t) for t in range(t0, t1)]
        return (np.asarray([p[0] for p in pairs], np.float32),
                np.asarray([p[1] for p in pairs], np.float32))

    def _host_masks(self, t0: int, t1: int, participation) -> np.ndarray:
        """(L, N) masks from the host surface, τ stats updated per round
        exactly as the loop engine's `step` would."""
        sampler = participation if participation is not None \
            else self.r._scen_sampler
        if hasattr(sampler, "sample_block"):
            masks = sampler.sample_block(t0, t1 - t0)
        else:
            masks = np.stack([np.asarray(sampler.sample(t), bool)
                              for t in range(t0, t1)])
        for row in masks:
            self.r.stats.update(np.asarray(row, bool))
        return np.asarray(masks, bool)

    def _build_xs(self, t0: int, t1: int, participation) -> dict:
        r = self.r
        self._seg = (t0, t1)
        eta_loc, eta_srv = self._etas(t0, t1)
        xs = {"eta_loc": eta_loc, "eta_srv": eta_srv}
        if self.scenario_mode:
            xs["t"] = np.arange(t0, t1, dtype=np.int32)
            xs["batch"] = _stack([r.batcher.sample_round(t)
                                  for t in range(t0, t1)])
            return xs
        masks = self._host_masks(t0, t1, participation)
        if not r.cohort_mode:
            xs["active"] = masks
            xs["batch"] = _stack([r.batcher.sample_round(t)
                                  for t in range(t0, t1)])
            return xs
        # cohort: reduce each mask to a padded id list + compact batch,
        # exactly as RoundRunner.step_cohort assembles a single round
        ids_l, valid_l, batch_l = [], [], []
        for j, row in enumerate(masks):
            padded, valid = pad_cohort(np.flatnonzero(row), self.cap,
                                       r.n_clients, t0 + j)
            ids_l.append(padded)
            valid_l.append(valid)
            batch_l.append(r.batcher.sample_round(
                t0 + j, client_ids=np.where(valid, padded, 0)))
        xs["ids"] = np.stack(ids_l)
        xs["valid"] = np.stack(valid_l)
        xs["batch"] = _stack(batch_l)
        self._last_union = np.concatenate(
            [p[v] for p, v in zip(ids_l, valid_l)])
        return xs

    def _pre_chunk(self, carry: dict) -> dict:
        """Host-side streaming between chunks, while the device still owns
        the previous chunk: page the upcoming chunk union's bank rows in
        (cohort mode, paged banks) or re-point a windowed scenario's
        carried availability window at the chunk (trace replay). Both only
        *replace* carry leaves with host-built arrays — no traced reads —
        so the pipeline never stalls here."""
        if self.r.cohort_mode:
            prep = getattr(self.r.algo, "prepare_cohort", None)
            if prep is None or self._last_union is None:
                return carry
            return {**carry, "state": prep(carry["state"], self._last_union)}
        w, (t0, t1) = self._scan_window, self._seg
        if (self._win_start is not None and self._win_start <= t0
                and t1 <= self._win_start + w):
            return carry                       # chunk already covered
        carry = {**carry, "scen_state": self.r.scen_process.load_window(
            carry["scen_state"], t0)}
        self._win_start = t0
        return carry

    def _flush(self, t0: int, t1: int, ys: dict, carry: dict) -> None:
        """Reconstruct per-round history (and τ stats) from the stacked ys.

        Blocks on the chunk's results — `run` calls it one chunk late so
        the next chunk's host-side xs assembly overlaps device compute.
        """
        if self.scenario_mode:
            self.r.stats.absorb_scan(carry["tau"], carry["tau_max"],
                                     ys["tau_sum"], ys["tau_sq_sum"])
        ys = {k: np.asarray(v) for k, v in ys.items()}
        tau_keys = ("tau_sum", "tau_sq_sum")
        for j, t in enumerate(range(t0, t1)):
            self.r.hist.record_round(
                t, {k: v[j] for k, v in ys.items() if k not in tau_keys})

    # ------------------------------------------------------------------ #
    def run(self, n_rounds: int, *, participation=None,
            eval_fn: Callable | None = None, eval_every: int = 10,
            verbose: bool = False, checkpoint=None,
            start_round: int = 0) -> None:
        """Execute rounds [start_round, n_rounds), mutating the runner in
        place. `checkpoint` (a `repro.checkpoint.CheckpointSpec`) snapshots
        the full run state at every `checkpoint.every`-round boundary —
        the boundaries become chunk cuts like eval rounds, and the save
        happens after the chunk flushed, so stats/history are current;
        `start_round` > 0 continues a restored run (`run_fl` handles the
        restore itself)."""
        r = self.r
        if (participation is None and r.scen_process is None):
            raise ValueError("ScanDriver.run needs participation= or a "
                             "runner constructed with scenario=")
        evals = _eval_rounds(n_rounds, eval_every, eval_fn is not None)
        ckpts = set()
        if checkpoint is not None:
            ckpts = {t for t in range(start_round, n_rounds)
                     if (t + 1) % checkpoint.every == 0}

        def on_sync(t):
            if t in evals:
                el, ea = r.evaluate(t, eval_fn)
                if verbose:
                    print(f"  round {t:5d} "
                          f"train={r.hist.train_loss[-1]:.4f} "
                          f"eval={el:.4f} acc={ea:.4f} "
                          f"active={int(r.hist.n_active[-1])}")
            if t in ckpts:
                from repro.checkpoint.run_state import save_run
                save_run(r, checkpoint, t + 1)

        use_pre = self.r.cohort_mode or self._scan_window is not None
        run_pipelined_chunks(
            self._init_carry(),
            chunk_bounds(n_rounds, self.scan_chunk, evals | ckpts,
                         start=start_round),
            chunk_fn=self._chunk_fn,
            build_xs=lambda t0, t1: self._build_xs(t0, t1, participation),
            writeback=self._writeback, flush=self._flush,
            sync_rounds=evals | ckpts, on_sync=on_sync,
            pre_chunk=self._pre_chunk if use_pre else None)
