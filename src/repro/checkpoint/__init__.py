"""Checkpoint subsystem: atomic pytree snapshots (`io`) and whole-run
checkpoint/resume for `run_fl` (`run_state`); docs/operations.md is the
runbook."""
from repro.checkpoint.io import save_pytree, load_pytree  # noqa: F401
from repro.checkpoint.run_state import (CheckpointSpec,  # noqa: F401
                                        checkpoint_path, fast_forward_sampler,
                                        latest_checkpoint, list_checkpoints,
                                        prune_checkpoints, restore_run,
                                        save_run)
