"""Pytree checkpointing on npz (no orbax in the container).

Nested dicts/lists of arrays <-> flat npz keys joined with '/'. List indices
are stored as '#i' components (so dict keys that *look* numeric — e.g. the
transformer's segment indices — round-trip as dicts, not lists).

Durability contract: `save_pytree` writes to a temporary file in the SAME
directory and atomically renames it over the destination, so a crash (or
kill) mid-write can never leave a torn checkpoint — the previous snapshot
at that path survives intact (pinned by tests/test_checkpoint.py). This is
what `checkpoint.run_state` builds long-horizon resume on.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_BF16_KEY = "__bf16_keys__"


def _flatten(tree, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save_pytree(path: str, tree) -> str:
    """Persist a pytree of arrays to `path` (npz), atomically.

    The tree is device_get-ed, flattened to '/'-joined keys, and written
    via a same-directory temp file + `os.replace` — the destination is
    either the complete new snapshot or untouched, never a torn file.
    bfloat16 leaves are stored as uint16 views plus a key manifest (npz
    cannot hold bf16 natively). A ``.npz`` suffix is appended if missing
    (matching `np.savez`); returns the actual path written.
    """
    flat = _flatten(jax.device_get(tree))
    # npz cannot store bfloat16: persist as uint16 views + a key manifest
    bf16_keys = [k for k, v in flat.items() if v.dtype == ml_dtypes.bfloat16]
    for k in bf16_keys:
        flat[k] = flat[k].view(np.uint16)
    flat[_BF16_KEY] = np.asarray(bf16_keys)
    if not path.endswith(".npz"):
        path += ".npz"
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    # write to a sibling temp file and rename: np.savez straight into the
    # final path truncates before writing, so a crash mid-write tears the
    # PREVIOUS snapshot. Passing the open file object (not a path) keeps
    # np.savez from appending its own suffix to the temp name.
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _insert(root: dict, parts: list[str], value):
    head = parts[0]
    if len(parts) == 1:
        root[head] = value
        return
    root.setdefault(head, {})
    _insert(root[head], parts[1:], value)


def _listify(node):
    """Convert dicts whose keys are exactly '#0'..'#n-1' into lists."""
    if not isinstance(node, dict):
        return node
    node = {k: _listify(v) for k, v in node.items()}
    keys = list(node.keys())
    if keys and all(k.startswith("#") and k[1:].isdigit() for k in keys):
        idx = sorted(int(k[1:]) for k in keys)
        if idx == list(range(len(idx))):
            return [node[f"#{i}"] for i in idx]
    return node


def load_pytree(path: str, as_jax: bool = True):
    """Load a `save_pytree` snapshot back into a nested pytree.

    Inverts the flattening ('/'-joined keys -> nested dicts, '#i'
    components -> lists) and restores bf16 leaves from their uint16
    views. `as_jax=False` keeps the leaves as NumPy arrays (host-side
    consumers like `checkpoint.run_state.restore_run`).
    """
    with np.load(path) as z:
        bf16 = set(z[_BF16_KEY].tolist()) if _BF16_KEY in z.files else set()
        root: dict = {}
        for key in z.files:
            if key == _BF16_KEY:
                continue
            val = z[key]
            if key in bf16:
                val = val.view(ml_dtypes.bfloat16)
            if as_jax:
                val = jnp.asarray(val)
            _insert(root, key.split("/"), val)
    return _listify(root)
