"""Pytree checkpointing on npz (no orbax in the container).

Nested dicts/lists of arrays <-> flat npz keys joined with '/'. List indices
are stored as '#i' components (so dict keys that *look* numeric — e.g. the
transformer's segment indices — round-trip as dicts, not lists).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_BF16_KEY = "__bf16_keys__"


def _flatten(tree, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save_pytree(path: str, tree) -> None:
    flat = _flatten(jax.device_get(tree))
    # npz cannot store bfloat16: persist as uint16 views + a key manifest
    bf16_keys = [k for k, v in flat.items() if v.dtype == ml_dtypes.bfloat16]
    for k in bf16_keys:
        flat[k] = flat[k].view(np.uint16)
    flat[_BF16_KEY] = np.asarray(bf16_keys)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    np.savez(path, **flat)


def _insert(root: dict, parts: list[str], value):
    head = parts[0]
    if len(parts) == 1:
        root[head] = value
        return
    root.setdefault(head, {})
    _insert(root[head], parts[1:], value)


def _listify(node):
    """Convert dicts whose keys are exactly '#0'..'#n-1' into lists."""
    if not isinstance(node, dict):
        return node
    node = {k: _listify(v) for k, v in node.items()}
    keys = list(node.keys())
    if keys and all(k.startswith("#") and k[1:].isdigit() for k in keys):
        idx = sorted(int(k[1:]) for k in keys)
        if idx == list(range(len(idx))):
            return [node[f"#{i}"] for i in idx]
    return node


def load_pytree(path: str, as_jax: bool = True):
    with np.load(path) as z:
        bf16 = set(z[_BF16_KEY].tolist()) if _BF16_KEY in z.files else set()
        root: dict = {}
        for key in z.files:
            if key == _BF16_KEY:
                continue
            val = z[key]
            if key in bf16:
                val = val.view(ml_dtypes.bfloat16)
            if as_jax:
                val = jnp.asarray(val)
            _insert(root, key.split("/"), val)
    return _listify(root)
