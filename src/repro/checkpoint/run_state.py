"""Whole-run checkpoint/resume for `run_fl` (long-horizon durability).

A snapshot is ONE atomic npz (`checkpoint.io.save_pytree`) holding
everything the scan engine's trajectory depends on at a chunk boundary:

  * the scan carry — params, algorithm state (bank pages + page table
    included, since they live in `runner.state`), the round RNG, and the
    scenario chain state + key (which for trace replay contains the
    carried availability window, i.e. the trace cursor);
  * host-side bank residency bookkeeping (`MemoryBank.host_state` — page
    table mirror, LRU clocks, spilled pages) for paged banks;
  * τ statistics (`TauStats`) and the recorded `FLHistory` so far;
  * the next round to run, the client count, and a format tag.

Resume invariants (docs/operations.md has the runbook): a run restored
from the snapshot at round k and continued to T produces the fp32
bit-exact params and history of the uninterrupted T-round run — this
reduces to the scan engine's chunk-boundary invariance (the resumed run's
chunk cuts differ only where cuts already don't matter) plus the fact
that every source of randomness (round RNG, scenario key, host sampler
streams) is either in the snapshot or deterministically fast-forwarded
(`fast_forward_sampler`). Pinned by tests/test_trace_replay.py.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree

_FORMAT = "repro-run-v1"
_NAME_RE = re.compile(r"^ckpt_r(\d{8})\.npz$")


@dataclass(frozen=True)
class CheckpointSpec:
    """Checkpoint request for `run_fl(checkpoint=...)`.

    Attributes:
      every: snapshot after every `every` completed rounds (the scan
        engine snaps its chunk boundaries to these rounds, like evals).
      dir: snapshot directory; files are ``ckpt_r<round:08d>.npz``.
      keep: retain only the newest `keep` snapshots (None: keep all).
      resume: when True, `run_fl` restores the latest snapshot in `dir`
        (if any) and continues from its round instead of round 0.
    """

    every: int
    dir: str
    keep: int | None = None
    resume: bool = False

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"checkpoint every must be >= 1, "
                             f"got {self.every}")
        if self.keep is not None and self.keep < 1:
            raise ValueError(f"checkpoint keep must be >= 1, "
                             f"got {self.keep}")


def checkpoint_path(dir: str, round: int) -> str:
    """Snapshot filename for the state AFTER `round` completed rounds."""
    return os.path.join(dir, f"ckpt_r{round:08d}.npz")


def list_checkpoints(dir: str) -> list[tuple[int, str]]:
    """(round, path) for every snapshot in `dir`, oldest first."""
    if not os.path.isdir(dir):
        return []
    out = []
    for name in os.listdir(dir):
        m = _NAME_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(dir, name)))
    return sorted(out)


def latest_checkpoint(dir: str) -> str | None:
    """Path of the newest snapshot in `dir`, or None when there is none."""
    found = list_checkpoints(dir)
    return found[-1][1] if found else None


def prune_checkpoints(dir: str, keep: int) -> None:
    """Delete all but the newest `keep` snapshots in `dir`."""
    for _, path in list_checkpoints(dir)[:-keep]:
        os.unlink(path)


def _hist_to_tree(hist) -> dict:
    """FLHistory -> arrays (float64/int64, exact round-trip)."""
    return {
        "rounds": np.asarray(hist.rounds, np.int64),
        "train_loss": np.asarray(hist.train_loss, np.float64),
        "n_active": np.asarray(hist.n_active, np.float64),
        "global_updates": np.asarray(hist.global_updates, np.float64),
        "eval_rounds": np.asarray([t for t, _ in hist.eval_loss], np.int64),
        "eval_loss": np.asarray([v for _, v in hist.eval_loss], np.float64),
        "eval_acc": np.asarray([v for _, v in hist.eval_acc], np.float64),
    }


def _hist_from_tree(hist, tree: dict) -> None:
    """Restore the list fields of an FLHistory from `_hist_to_tree`."""
    hist.rounds = [int(t) for t in tree["rounds"]]
    hist.train_loss = list(map(float, tree["train_loss"]))
    hist.n_active = list(map(float, tree["n_active"]))
    hist.global_updates = list(map(float, tree["global_updates"]))
    ev_t = [int(t) for t in tree["eval_rounds"]]
    hist.eval_loss = list(zip(ev_t, map(float, tree["eval_loss"])))
    hist.eval_acc = list(zip(ev_t, map(float, tree["eval_acc"])))


def save_run(runner, spec: CheckpointSpec, round_next: int) -> str:
    """Snapshot `runner`'s full state after `round_next` completed rounds.

    Called by the scan engine at a flushed chunk boundary (stats and
    history are current through round ``round_next - 1``). Atomic via
    `save_pytree`; prunes to `spec.keep` afterwards. Returns the path.
    """
    s = runner.stats
    tree = {
        "format": _FORMAT,
        "round": np.int64(round_next),
        "n_clients": np.int64(runner.n_clients),
        "carry": {"state": runner.state, "params": runner.params,
                  "rng": runner.rng},
        "stats": {"tau": s.tau, "tau_max_per_dev": s.tau_max_per_dev,
                  "sum_tau": np.float64(s.sum_tau),
                  "sum_tau_sq": np.float64(s.sum_tau_sq),
                  "rounds": np.int64(s.rounds)},
        "hist": _hist_to_tree(runner.hist),
    }
    if hasattr(runner, "scen_state") and runner.scen_state is not None:
        tree["carry"]["scen_state"] = runner.scen_state
        tree["carry"]["scen_key"] = runner.scen_key
    bank = getattr(runner.algo, "bank", None)
    if bank is not None and hasattr(bank, "host_state"):
        tree["bank"] = bank.host_state()       # {} flattens to nothing
    path = save_pytree(checkpoint_path(spec.dir, round_next), tree)
    if spec.keep is not None:
        prune_checkpoints(spec.dir, spec.keep)
    return path


def restore_run(runner, spec: CheckpointSpec) -> int:
    """Restore `runner` from the latest snapshot in `spec.dir`.

    Returns the round to resume from (0 when no snapshot exists — a
    fresh run). Raises when the snapshot's client count does not match
    the runner (resuming under a different problem is always a bug).
    """
    path = latest_checkpoint(spec.dir)
    if path is None:
        return 0
    tree = load_pytree(path, as_jax=False)
    fmt = str(np.asarray(tree["format"]))
    if fmt != _FORMAT:
        raise ValueError(f"{path}: unknown snapshot format {fmt!r} "
                         f"(expected {_FORMAT!r})")
    n = int(tree["n_clients"])
    if n != runner.n_clients:
        raise ValueError(f"{path}: snapshot has {n} clients, runner has "
                         f"{runner.n_clients} — refusing to resume")
    carry = tree["carry"]
    runner.state = jax.tree.map(jnp.asarray, carry["state"])
    runner.params = jax.tree.map(jnp.asarray, carry["params"])
    runner.rng = jnp.asarray(carry["rng"])
    if "scen_state" in carry:
        runner.scen_state = jax.tree.map(jnp.asarray, carry["scen_state"])
        runner.scen_key = jnp.asarray(carry["scen_key"])
    st = tree["stats"]
    runner.stats.tau = np.asarray(st["tau"], np.int64)
    runner.stats.tau_max_per_dev = np.asarray(st["tau_max_per_dev"],
                                              np.int64)
    runner.stats.sum_tau = float(st["sum_tau"])
    runner.stats.sum_tau_sq = float(st["sum_tau_sq"])
    runner.stats.rounds = int(st["rounds"])
    _hist_from_tree(runner.hist, tree["hist"])
    bank = getattr(runner.algo, "bank", None)
    if bank is not None and hasattr(bank, "load_host_state"):
        bank.load_host_state(tree.get("bank", {}))
    return int(tree["round"])


def fast_forward_sampler(sampler, start_round: int) -> None:
    """Replay a host availability sampler through rounds [0, start_round).

    Snapshots do not serialise host sampler state (NumPy generators,
    Markov chains); on resume the stream is re-derived by sampling the
    skipped rounds — deterministic, so the resumed rounds see exactly the
    masks the uninterrupted run drew. Skipped entirely for stateless
    scenario samplers (random-access by construction).
    """
    from repro.scenarios.base import HostSampler
    if sampler is None or start_round <= 0:
        return
    if isinstance(sampler, HostSampler) and sampler.process.stateless:
        return
    for t in range(start_round):
        sampler.sample(t)
