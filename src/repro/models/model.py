"""Public model API: init / loss_fn / prefill / decode_step for every family.

`Model` wraps an ArchConfig. Batch formats by modality:
  text:    {'tokens': (B,S) int32}
  vision_text: {'tokens': (B,S_text) int32, 'patches': (B,P,d)}  (stub frontend)
  audio:   {'frames': (B,S,d), 'labels': (B,S) int32}            (stub frontend)
  tabular: {'x': (B,d) float32, 'y': (B,) int32}                 (paper models)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import transformer
from repro.models.layers import (_dense_init, chunked_lm_loss, embed_init,
                                 head_init, rmsnorm, rmsnorm_init,
                                 softmax_cross_entropy)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.param_dtype = _dtype(cfg.param_dtype)
        self.compute_dtype = _dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ #
    # init
    # ------------------------------------------------------------------ #
    def init(self, rng) -> dict:
        cfg = self.cfg
        if cfg.family == "tabular":
            return self._init_tabular(rng)
        ks = jax.random.split(rng, 5)
        params = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                self.param_dtype),
            "final_norm": rmsnorm_init(cfg.d_model, self.param_dtype),
            "lm_head": head_init(ks[1], cfg.d_model, cfg.vocab_size,
                                 self.param_dtype),
        }
        params.update(transformer.init_segments(ks[2], cfg, self.param_dtype))
        if cfg.modality == "audio":
            params["frontend_proj"] = _dense_init(
                ks[3], (cfg.d_model, cfg.d_model), self.param_dtype)
        return params

    def _init_tabular(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, max(cfg.n_layers + 1, 2))
        if cfg.n_layers == 0:  # logistic regression
            return {"w": jnp.zeros((cfg.d_model, cfg.vocab_size), jnp.float32),
                    "b": jnp.zeros((cfg.vocab_size,), jnp.float32)}
        layers = []
        d_in = cfg.d_model
        for i in range(cfg.n_layers):
            layers.append({"w": _dense_init(ks[i], (d_in, cfg.d_ff), jnp.float32),
                           "b": jnp.zeros((cfg.d_ff,), jnp.float32)})
            d_in = cfg.d_ff
        return {"layers": layers,
                "out": {"w": _dense_init(ks[-1], (d_in, cfg.vocab_size),
                                         jnp.float32),
                        "b": jnp.zeros((cfg.vocab_size,), jnp.float32)}}

    # ------------------------------------------------------------------ #
    # embedding / input assembly
    # ------------------------------------------------------------------ #
    def _embed_inputs(self, params: dict, batch: dict):
        """Returns (x (B,S,d), labels (B,S') or None, logits_slice)."""
        cfg = self.cfg
        if cfg.modality == "vision_text":
            patches = batch["patches"].astype(self.compute_dtype)
            tok_emb = params["embed"][batch["tokens"]].astype(self.compute_dtype)
            x = jnp.concatenate([patches, tok_emb], axis=1)
            return x
        if cfg.modality == "audio":
            x = batch["frames"].astype(self.compute_dtype)
            return x @ params["frontend_proj"].astype(self.compute_dtype)
        return params["embed"][batch["tokens"]].astype(self.compute_dtype)

    # ------------------------------------------------------------------ #
    # training loss
    # ------------------------------------------------------------------ #
    def loss_fn(self, params: dict, batch: dict):
        cfg = self.cfg
        if cfg.family == "tabular":
            return self._loss_tabular(params, batch)
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)
        h, aux = transformer.forward(params, x, positions, cfg)
        h = rmsnorm(params["final_norm"], h)

        if cfg.ce_chunk:
            labels, mask = self._labels_mask(batch, S)
            ce = chunked_lm_loss(h, params["lm_head"], labels, mask,
                                 chunk=cfg.ce_chunk)
        else:
            logits = h @ params["lm_head"].astype(h.dtype)
            if cfg.modality == "audio":
                ce = softmax_cross_entropy(logits, batch["labels"])
            elif cfg.modality == "vision_text":
                P = cfg.n_patches
                ce = softmax_cross_entropy(logits[:, P:-1],
                                           batch["tokens"][:, 1:])
            else:
                ce = softmax_cross_entropy(logits[:, :-1],
                                           batch["tokens"][:, 1:])
        loss = ce + aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    def _labels_mask(self, batch: dict, S: int):
        """Full-length (B,S) labels + validity mask for the chunked CE."""
        cfg = self.cfg
        if cfg.modality == "audio":
            return batch["labels"], jnp.ones_like(batch["labels"],
                                                  jnp.float32)
        tokens = batch["tokens"]
        B, St = tokens.shape
        P = cfg.n_patches if cfg.modality == "vision_text" else 0
        pad = jnp.zeros((B, 1), tokens.dtype)
        shifted = jnp.concatenate([tokens[:, 1:], pad], axis=1)   # (B,St)
        if P:
            labels = jnp.concatenate(
                [jnp.zeros((B, P), tokens.dtype), shifted], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((B, P)), jnp.ones((B, St - 1)),
                 jnp.zeros((B, 1))], axis=1)
        else:
            labels = shifted
            mask = jnp.concatenate(
                [jnp.ones((B, St - 1)), jnp.zeros((B, 1))], axis=1)
        return labels, mask

    def _tabular_logits(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        if self.cfg.n_layers == 0:
            return x @ params["w"] + params["b"]
        h = x
        for lp in params["layers"]:
            h = jax.nn.relu(h @ lp["w"] + lp["b"])
        return h @ params["out"]["w"] + params["out"]["b"]

    def _loss_tabular(self, params: dict, batch: dict):
        logits = self._tabular_logits(params, batch["x"])
        ce = softmax_cross_entropy(logits, batch["y"])
        return ce, {"loss": ce, "ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def accuracy(self, params: dict, batch: dict) -> jnp.ndarray:
        logits = self._tabular_logits(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

    # ------------------------------------------------------------------ #
    # serving
    # ------------------------------------------------------------------ #
    def init_cache(self, batch: int, cache_len: int) -> dict:
        return transformer.init_cache(self.cfg, batch, cache_len,
                                      self.compute_dtype)

    def prefill(self, params: dict, batch: dict, cache: dict):
        """Returns (last-position logits (B,V), cache)."""
        cfg = self.cfg
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        x = self._embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)
        h, _, cache = transformer.prefill(params, x, positions, cache, cfg)
        h = rmsnorm(params["final_norm"], h[:, -1:])
        logits = (h @ params["lm_head"].astype(h.dtype))[:, 0]
        return logits, cache

    def decode_step(self, params: dict, tokens: jnp.ndarray, pos: jnp.ndarray,
                    cache: dict):
        """tokens (B,1) int32; pos scalar int32. Returns (logits (B,V), cache)."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.compute_dtype)
        h, _, cache = transformer.decode(params, x, pos, cache, cfg)
        h = rmsnorm(params["final_norm"], h)
        logits = (h @ params["lm_head"].astype(h.dtype))[:, 0]
        return logits, cache

    # ------------------------------------------------------------------ #
    def param_count(self, params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
