"""Mamba2 (SSD — state-space duality) block: chunked prefill scan + O(1) decode.

TPU adaptation: the chunked SSD algorithm (arXiv:2405.21060 §6) is implemented
with MXU-friendly einsums — intra-chunk quadratic attention-like contractions of
size (chunk x chunk) plus an inter-chunk `lax.scan` over the running state.
The Pallas `ssd_scan` kernel tiles the same computation for VMEM; this jnp path
is its oracle and the CPU default. Single SSM group (G=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init


def mamba2_dims(d_model: int, expand: int, headdim: int, d_state: int,
                conv_width: int):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state          # conv over [x, B, C]
    proj_dim = 2 * d_inner + 2 * d_state + n_heads  # z, x, B, C, dt
    return d_inner, n_heads, conv_ch, proj_dim


def mamba2_init(rng, d_model: int, expand: int, headdim: int, d_state: int,
                conv_width: int, dtype) -> dict:
    d_inner, n_heads, conv_ch, proj_dim = mamba2_dims(
        d_model, expand, headdim, d_state, conv_width)
    ks = jax.random.split(rng, 4)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[2], (n_heads,), jnp.float32)
                 * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": _dense_init(ks[0], (d_model, proj_dim), dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": _dense_init(ks[3], (d_inner, d_model), dtype),
    }


def _split_proj(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * d_state]
    dt = proj[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time. xbc (B,S,C); w (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _segsum_exp(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., q) -> L (..., q, q) with L[i,j] = exp(sum_{j<k<=i} a_k), lower-tri."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x: jnp.ndarray, dA: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
                chunk: int, h0: jnp.ndarray | None = None):
    """Chunked SSD: lax.scan over chunks, one chunk's intermediates live at a
    time (the (b,h,Q,Q) decay matrix L would otherwise materialize for every
    chunk simultaneously — 1.1 TB/chip for zamba2 at train_4k). The scan body
    is rematerialized on the backward pass; only the (b,h,p,n) carried state
    is saved per chunk. All state math in float32.

    x (b,S,h,p); dA (b,S,h) [= dt*A, negative]; B,C (b,S,n). Returns
    (y (b,S,h,p), h_final (b,h,p,n)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    # chunk-major stacks: (nc, b, Q, ...)
    xc = jnp.moveaxis(x.reshape(b, nc, Q, H, P), 1, 0)
    dAc = jnp.moveaxis(dA.reshape(b, nc, Q, H).astype(jnp.float32), 1, 0)
    Bc = jnp.moveaxis(B.reshape(b, nc, Q, N).astype(jnp.float32), 1, 0)
    Cc = jnp.moveaxis(C.reshape(b, nc, Q, N).astype(jnp.float32), 1, 0)

    h_init = (jnp.zeros((b, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def chunk_body(h, inp):
        xq, daq, bq, cq = inp            # (b,Q,h,p) (b,Q,h) (b,Q,n) (b,Q,n)
        xq = xq.astype(jnp.float32)
        cum = jnp.cumsum(daq, axis=1)                    # (b,Q,h)
        L = _segsum_exp(jnp.moveaxis(daq, -1, -2))       # (b,h,Q,Q)
        att = jnp.einsum("bqn,bkn->bqk", cq, bq)         # (b,Q,Q)
        y = jnp.einsum("bqk,bhqk,bkhp->bqhp", att, L, xq)
        # contribution of carried state
        y = y + jnp.einsum("bqn,bhpn,bqh->bqhp", cq, h, jnp.exp(cum))
        # state update
        decay = jnp.exp(cum[:, -1:, :] - cum)            # (b,Q,h)
        h_new = (h * jnp.exp(cum[:, -1, :])[..., None, None]
                 + jnp.einsum("bqn,bqh,bqhp->bhpn", bq, decay, xq))
        return h_new, y

    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_body), h_init,
                               (xc, dAc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, S, H, P).astype(x.dtype)
    return y, h_final


def mamba2_prefill(params: dict, x: jnp.ndarray, *, expand: int, headdim: int,
                   d_state: int, chunk: int, conv_width: int):
    """x (B,S,d) -> (y (B,S,d), (ssm_state (B,H,P,N), conv_state (B,W-1,C)))."""
    Bsz, S, d_model = x.shape
    d_inner, n_heads, conv_ch, _ = mamba2_dims(d_model, expand, headdim,
                                               d_state, conv_width)
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(proj, d_inner, d_state, n_heads)
    conv_state = xbc[:, -(conv_width - 1):, :] if S >= conv_width - 1 else \
        jnp.pad(xbc, ((0, 0), (conv_width - 1 - S, 0), (0, 0)))
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_inner].reshape(Bsz, S, n_heads, headdim)
    Bmat = xbc[..., d_inner:d_inner + d_state]
    Cmat = xbc[..., d_inner + d_state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])                                     # (H,)
    y, h_final = ssd_chunked(xs * dt[..., None].astype(xs.dtype),
                             dt * A, Bmat, Cmat, chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(Bsz, S, d_inner)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    return y @ params["out_proj"], (h_final, conv_state)


def mamba2_decode(params: dict, x: jnp.ndarray, ssm_state: jnp.ndarray,
                  conv_state: jnp.ndarray, *, expand: int, headdim: int,
                  d_state: int, conv_width: int):
    """Single-token recurrent step.

    x (B,1,d); ssm_state (B,H,P,N) f32; conv_state (B,W-1,conv_ch).
    Returns (y (B,1,d), (ssm_state, conv_state)).
    """
    Bsz, _, d_model = x.shape
    d_inner, n_heads, conv_ch, _ = mamba2_dims(d_model, expand, headdim,
                                               d_state, conv_width)
    proj = (x @ params["in_proj"])[:, 0]                  # (B, proj)
    z, xbc, dt = _split_proj(proj, d_inner, d_state, n_heads)

    # conv: append new channel vector, take causal window
    win = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,W,C)
    conv_state = win[:, 1:, :]
    conv_out = jnp.einsum("bwc,wc->bc", win.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)).astype(x.dtype)

    xs = xbc[:, :d_inner].reshape(Bsz, n_heads, headdim)
    Bv = xbc[:, d_inner:d_inner + d_state].astype(jnp.float32)
    Cv = xbc[:, d_inner + d_state:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                              # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv, xs.astype(jnp.float32))
    ssm_state = ssm_state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, Cv)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z[:, None, :], params["norm_scale"])
    return y @ params["out_proj"], (ssm_state, conv_state)
