"""Mixture-of-Experts block: top-k router + sort-based capacity dispatch.

TPU adaptation (docs/architecture.md §3): instead of a dense one-hot dispatch tensor
(T x E x C — infeasible at 1M tokens) we sort token assignments by expert id
and gather into an (E, C, d) buffer, run the per-expert SwiGLU as a single
batched einsum over the expert axis (expert-parallel: E is sharded over the
`model` mesh axis, so the gather/scatter lower to all-to-all-style collectives),
then scatter-add the gated outputs back. Tokens beyond an expert's capacity
C = ceil(T*k/E * capacity_factor) are dropped (standard TPU MoE practice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init


def moe_init(rng, d: int, f: int, n_experts: int, n_shared: int, dtype) -> dict:
    ks = jax.random.split(rng, 5)
    p = {
        "router": _dense_init(ks[0], (d, n_experts), jnp.float32, scale=0.02),
        "w1": _dense_init(ks[1], (n_experts, d, f), dtype),
        "w3": _dense_init(ks[2], (n_experts, d, f), dtype),
        "w2": _dense_init(ks[3], (n_experts, f, d), dtype),
    }
    if n_shared:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": _dense_init(kk[0], (d, f * n_shared), dtype),
            "w3": _dense_init(kk[1], (d, f * n_shared), dtype),
            "w2": _dense_init(kk[2], (f * n_shared, d), dtype),
        }
    return p


def moe_apply(params: dict, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25,
              aux_coef: float = 0.01) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E = params["router"].shape[-1]
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])        # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)          # (T,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                                 # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = aux_coef * E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    A = T * top_k
    flat_expert = expert_ids.reshape(A)                          # assignment -> expert
    flat_token = jnp.repeat(jnp.arange(T), top_k)
    flat_gate = gate_vals.reshape(A)

    order = jnp.argsort(flat_expert)                             # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # position of each assignment within its expert's run
    counts = jnp.bincount(flat_expert, length=E)                 # (E,)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(A) - offsets[sorted_expert]

    C = int(np.ceil(A / E * capacity_factor))
    keep = pos_in_expert < C
    # scatter token ids into the (E, C) routing table; dropped slots -> T (pad row)
    table = jnp.full((E, C), T, dtype=jnp.int32)
    table = table.at[sorted_expert, jnp.minimum(pos_in_expert, C - 1)].set(
        jnp.where(keep, sorted_token, T), mode="drop")
    gates = jnp.zeros((E, C), dtype=jnp.float32)
    gates = gates.at[sorted_expert, jnp.minimum(pos_in_expert, C - 1)].set(
        jnp.where(keep, sorted_gate, 0.0), mode="drop")

    # gather tokens (pad row of zeros at index T)
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xpad[table]                                             # (E,C,d)

    # per-expert SwiGLU
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w1"])) \
        * jnp.einsum("ecd,edf->ecf", xe, params["w3"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w2"])             # (E,C,d)

    # combine: scatter-add gated outputs back to tokens
    y = jnp.zeros((T + 1, d), ye.dtype)
    y = y.at[table.reshape(-1)].add(
        (ye * gates[..., None].astype(ye.dtype)).reshape(E * C, d))
    y = y[:T]

    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(xt @ sh["w1"]) * (xt @ sh["w3"])
        y = y + hs @ sh["w2"]

    return y.reshape(B, S, d), aux
