"""Layer-stack assembly: segments of homogeneous blocks scanned with lax.scan.

A model is a sequence of *segments*; each segment is a maximal run of layers
with identical (block kind, ffn kind). Segment parameters are stacked along a
leading layer axis and executed with ``lax.scan`` so the HLO stays compact for
80-layer models (critical for CPU-side dry-run compile times). Mixed patterns
(gemma3's 5 local : 1 global, zamba2's shared-attention insertions) become
short segment lists. Zamba2's shared attention block is stored once at the top
level and referenced by every `shared_attn` segment.

Block kinds: 'attn' (GQA full), 'local_attn' (GQA sliding window),
'mla' (DeepSeek compressed-KV), 'ssm' (Mamba2), 'shared_attn'.
FFN kinds: 'mlp', 'moe', None.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import mlp_apply, mlp_init, rmsnorm, rmsnorm_init


def _radd(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Residual add that preserves the activation dtype."""
    return x + y.astype(x.dtype)


def _attn_out(ctx: jnp.ndarray, wo: jnp.ndarray) -> jnp.ndarray:
    B, S, H, hd = ctx.shape
    return ctx.reshape(B, S, H * hd) @ wo


def _gqa(lp, h, positions, cfg, pad: bool = True):
    q, k, v = attn_lib.gqa_project(lp["attn"], h, positions, cfg.rope_theta,
                                   cfg.n_heads, cfg.n_kv_heads,
                                   cfg.resolved_head_dim)
    if not pad:
        return q, k, v
    if cfg.pad_q_heads and cfg.pad_q_heads > cfg.n_heads:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, cfg.pad_q_heads - cfg.n_heads),
                        (0, 0)))
    if cfg.pad_kv_heads and cfg.pad_kv_heads > cfg.n_kv_heads:
        pad = cfg.pad_kv_heads - cfg.n_kv_heads
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return q, k, v


def _unpad_ctx(ctx, cfg):
    if cfg.pad_q_heads and cfg.pad_q_heads > cfg.n_heads:
        return ctx[:, :, :cfg.n_heads, :]
    return ctx


def _unpad_kv(k, v, cfg):
    """Caches store the real (unpadded) kv heads."""
    if cfg.pad_kv_heads and cfg.pad_kv_heads > cfg.n_kv_heads:
        return k[:, :, :cfg.n_kv_heads, :], v[:, :, :cfg.n_kv_heads, :]
    return k, v


@dataclass(frozen=True)
class SegmentSpec:
    index: int
    kind: str        # attn | local_attn | mla | ssm | shared_attn
    ffn: str | None  # mlp | moe | None
    n_layers: int
    window: int = 0  # >0 for local_attn


def build_segments(cfg: ArchConfig) -> list[SegmentSpec]:
    specs: list[tuple[str, str | None, int]] = []
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        if kind == "attn" and cfg.kv_lora_rank:
            kind = "mla"
        if kind in ("ssm", "shared_attn"):
            ffn = None
        elif cfg.is_moe and i >= cfg.first_dense_layers:
            ffn = "moe"
        else:
            ffn = "mlp"
        if kind == "local_attn":
            window = cfg.swa_window
        elif kind == "shared_attn":
            window = cfg.shared_attn_window
        else:
            window = 0
        specs.append((kind, ffn, window))

    segments: list[SegmentSpec] = []
    run_start = 0
    for i in range(1, len(specs) + 1):
        if i == len(specs) or specs[i] != specs[run_start]:
            kind, ffn, window = specs[run_start]
            segments.append(SegmentSpec(len(segments), kind, ffn,
                                        i - run_start, window))
            run_start = i
    return segments


# --------------------------------------------------------------------------- #
# Parameter init
# --------------------------------------------------------------------------- #

def _layer_init(rng, spec: SegmentSpec, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict = {}
    if spec.kind in ("attn", "local_attn", "shared_attn"):
        p["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        p["attn"] = attn_lib.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.resolved_head_dim,
                                      cfg.qkv_bias, dtype)
    elif spec.kind == "mla":
        p["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        p["attn"] = attn_lib.mla_init(ks[0], cfg.d_model, cfg.n_heads,
                                      cfg.kv_lora_rank, cfg.rope_head_dim,
                                      cfg.nope_head_dim, cfg.v_head_dim, dtype)
    elif spec.kind == "ssm":
        p["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = ssm_lib.mamba2_init(ks[0], cfg.d_model, cfg.ssm_expand,
                                         cfg.ssm_headdim, cfg.ssm_state,
                                         cfg.ssm_conv_width, dtype)
    ffn = "mlp" if spec.kind == "shared_attn" else spec.ffn
    if ffn == "mlp":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["moe"] = moe_lib.moe_init(ks[1], cfg.d_model, cfg.expert_d_ff,
                                    cfg.n_experts, cfg.n_shared_experts, dtype)
    return p


def init_segments(rng, cfg: ArchConfig, dtype) -> dict:
    """Returns {'segments': {str(i): stacked params}, 'shared_attn': ...?}."""
    out: dict = {"segments": {}}
    segments = build_segments(cfg)
    rngs = jax.random.split(rng, len(segments) + 1)
    need_shared = any(s.kind == "shared_attn" for s in segments)
    if need_shared:
        shared_spec = next(s for s in segments if s.kind == "shared_attn")
        out["shared_attn"] = _layer_init(rngs[-1], shared_spec, cfg, dtype)
    for seg, r in zip(segments, rngs[:-1]):
        if seg.kind == "shared_attn":
            out["segments"][str(seg.index)] = {}  # parameters live at top level
            continue
        layer_rngs = jax.random.split(r, seg.n_layers)
        out["segments"][str(seg.index)] = jax.vmap(
            lambda k: _layer_init(k, seg, cfg, dtype))(layer_rngs)
    return out


# --------------------------------------------------------------------------- #
# Single-layer forward (no cache: training / scoring)
# --------------------------------------------------------------------------- #

def _layer_fwd(lp: dict, x: jnp.ndarray, positions: jnp.ndarray, aux,
               spec: SegmentSpec, cfg: ArchConfig):
    if spec.kind in ("attn", "local_attn", "shared_attn"):
        h = rmsnorm(lp["ln1"], x)
        q, k, v = _gqa(lp, h, positions, cfg)
        ctx = attn_lib.blockwise_attention(q, k, v, causal=cfg.causal,
                                           window=spec.window)
        x = _radd(x, _attn_out(_unpad_ctx(ctx, cfg), lp["attn"]["wo"]))
    elif spec.kind == "mla":
        h = rmsnorm(lp["ln1"], x)
        out, _ = attn_lib.mla_prefill(lp["attn"], h, positions,
                                      rope_theta=cfg.rope_theta,
                                      nope_hd=cfg.nope_head_dim,
                                      causal=cfg.causal)
        x = _radd(x, out)
    elif spec.kind == "ssm":
        h = rmsnorm(lp["ln1"], x)
        out, _ = ssm_lib.mamba2_prefill(lp["mixer"], h, expand=cfg.ssm_expand,
                                        headdim=cfg.ssm_headdim,
                                        d_state=cfg.ssm_state,
                                        chunk=cfg.ssm_chunk,
                                        conv_width=cfg.ssm_conv_width)
        x = _radd(x, out)

    ffn = "mlp" if spec.kind == "shared_attn" else spec.ffn
    if ffn == "mlp":
        x = _radd(x, mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x)))
    elif ffn == "moe":
        y, a = moe_lib.moe_apply(lp["moe"], rmsnorm(lp["ln2"], x),
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 aux_coef=cfg.router_aux_coef)
        x = _radd(x, y)
        aux = aux + a
    return x, aux


def forward(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
            cfg: ArchConfig):
    """Run all segments. x (B,S,d) -> (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    for seg in build_segments(cfg):
        if seg.kind == "shared_attn":
            body = lambda xa, lp=params["shared_attn"]: _layer_fwd(
                lp, xa[0], positions, xa[1], seg, cfg)
            if cfg.remat:
                body = jax.checkpoint(body)
            x, aux = body((x, aux))
            continue

        seg_params = params["segments"][str(seg.index)]

        def scan_body(carry, lp, seg=seg):
            xx, aa = carry
            xx, aa = _layer_fwd(lp, xx, positions, aa, seg, cfg)
            return (xx, aa), None

        if cfg.remat:
            scan_body = jax.checkpoint(scan_body)
        (x, aux), _ = jax.lax.scan(scan_body, (x, aux), seg_params)
    return x, aux


# --------------------------------------------------------------------------- #
# Prefill (emit caches) and decode (consume caches)
# --------------------------------------------------------------------------- #

def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype) -> dict:
    """Zero caches for every segment, stacked along the segment's layer axis."""
    cache: dict = {}
    hd = cfg.resolved_head_dim
    for seg in build_segments(cfg):
        n = seg.n_layers
        if seg.kind in ("attn", "shared_attn"):
            c = min(seg.window, cache_len) if seg.window else cache_len
            shp = (n, batch, c, cfg.n_kv_heads, hd) if seg.kind == "attn" else \
                  (batch, c, cfg.n_kv_heads, hd)
            cache[str(seg.index)] = {"k": jnp.zeros(shp, dtype),
                                     "v": jnp.zeros(shp, dtype)}
        elif seg.kind == "local_attn":
            c = min(cfg.swa_window, cache_len)
            cache[str(seg.index)] = {
                "k": jnp.zeros((n, batch, c, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((n, batch, c, cfg.n_kv_heads, hd), dtype)}
        elif seg.kind == "mla":
            cache[str(seg.index)] = {
                "c": jnp.zeros((n, batch, cache_len, cfg.kv_lora_rank), dtype),
                "pe": jnp.zeros((n, batch, cache_len, cfg.rope_head_dim), dtype)}
        elif seg.kind == "ssm":
            d_inner, n_heads, conv_ch, _ = ssm_lib.mamba2_dims(
                cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state,
                cfg.ssm_conv_width)
            cache[str(seg.index)] = {
                "state": jnp.zeros((n, batch, n_heads, cfg.ssm_headdim,
                                    cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.ssm_conv_width - 1, conv_ch),
                                  dtype)}
    return cache


def _ring_fill(buf: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
    """Place the last C positions of `new` (B,S,...) into ring buffer (B,C,...)."""
    C = buf.shape[1]
    S = new.shape[1]
    if S >= C:
        tail = new[:, S - C:]
        idx = jnp.mod(jnp.arange(S - C, S), C)
    else:
        tail = new
        idx = jnp.arange(S)
    return buf.at[:, idx].set(tail.astype(buf.dtype))


def _layer_prefill(lp: dict, x, positions, aux, cache_entry, spec: SegmentSpec,
                   cfg: ArchConfig):
    """Like _layer_fwd but fills this layer's cache entry."""
    new_cache = dict(cache_entry)
    if spec.kind in ("attn", "local_attn", "shared_attn"):
        h = rmsnorm(lp["ln1"], x)
        q, k, v = _gqa(lp, h, positions, cfg)
        ctx = attn_lib.blockwise_attention(q, k, v, causal=cfg.causal,
                                           window=spec.window)
        x = _radd(x, _attn_out(_unpad_ctx(ctx, cfg), lp["attn"]["wo"]))
        k, v = _unpad_kv(k, v, cfg)
        if spec.window:
            new_cache = {"k": _ring_fill(cache_entry["k"], k),
                         "v": _ring_fill(cache_entry["v"], v)}
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache_entry["k"], k.astype(cache_entry["k"].dtype), 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache_entry["v"], v.astype(cache_entry["v"].dtype), 0, axis=1)}
    elif spec.kind == "mla":
        h = rmsnorm(lp["ln1"], x)
        out, (c_kv, k_pe) = attn_lib.mla_prefill(
            lp["attn"], h, positions, rope_theta=cfg.rope_theta,
            nope_hd=cfg.nope_head_dim, causal=cfg.causal)
        x = _radd(x, out)
        new_cache = {
            "c": jax.lax.dynamic_update_slice_in_dim(
                cache_entry["c"], c_kv.astype(cache_entry["c"].dtype), 0, axis=1),
            "pe": jax.lax.dynamic_update_slice_in_dim(
                cache_entry["pe"], k_pe.astype(cache_entry["pe"].dtype), 0, axis=1)}
    elif spec.kind == "ssm":
        h = rmsnorm(lp["ln1"], x)
        out, (state, conv) = ssm_lib.mamba2_prefill(
            lp["mixer"], h, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            d_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
            conv_width=cfg.ssm_conv_width)
        x = _radd(x, out)
        new_cache = {"state": state,
                     "conv": conv.astype(cache_entry["conv"].dtype)}

    ffn = "mlp" if spec.kind == "shared_attn" else spec.ffn
    if ffn == "mlp":
        x = _radd(x, mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x)))
    elif ffn == "moe":
        y, a = moe_lib.moe_apply(lp["moe"], rmsnorm(lp["ln2"], x),
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 aux_coef=cfg.router_aux_coef)
        x = _radd(x, y)
        aux = aux + a
    return x, aux, new_cache


def _layer_decode(lp: dict, x, pos, aux, cache_entry, spec: SegmentSpec,
                  cfg: ArchConfig):
    """Single-token step through one layer; updates cache entry (no layer axis)."""
    positions = pos[None]
    if spec.kind in ("attn", "local_attn", "shared_attn"):
        h = rmsnorm(lp["ln1"], x)
        # decode is single-token: no score-AR pathology, so no head padding
        q, k, v = _gqa(lp, h, positions, cfg, pad=False)
        kc, vc = attn_lib.cache_write(cache_entry["k"], cache_entry["v"], k, v,
                                      pos, window=spec.window)
        ctx = attn_lib.decode_attend(q, kc, vc, pos, window=spec.window)
        x = _radd(x, _attn_out(ctx, lp["attn"]["wo"]))
        new_cache = {"k": kc, "v": vc}
    elif spec.kind == "mla":
        h = rmsnorm(lp["ln1"], x)
        out, (cc, pc) = attn_lib.mla_decode(lp["attn"], h, pos,
                                            cache_entry["c"], cache_entry["pe"],
                                            rope_theta=cfg.rope_theta,
                                            nope_hd=cfg.nope_head_dim)
        x = _radd(x, out)
        new_cache = {"c": cc, "pe": pc}
    elif spec.kind == "ssm":
        h = rmsnorm(lp["ln1"], x)
        out, (state, conv) = ssm_lib.mamba2_decode(
            lp["mixer"], h, cache_entry["state"], cache_entry["conv"],
            expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            d_state=cfg.ssm_state, conv_width=cfg.ssm_conv_width)
        x = _radd(x, out)
        new_cache = {"state": state, "conv": conv}

    ffn = "mlp" if spec.kind == "shared_attn" else spec.ffn
    if ffn == "mlp":
        x = _radd(x, mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x)))
    elif ffn == "moe":
        y, a = moe_lib.moe_apply(lp["moe"], rmsnorm(lp["ln2"], x),
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 aux_coef=cfg.router_aux_coef)
        x = _radd(x, y)
        aux = aux + a
    return x, aux, new_cache


def prefill(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
            cache: dict, cfg: ArchConfig):
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    for seg in build_segments(cfg):
        entry = cache[str(seg.index)]
        if seg.kind == "shared_attn":
            x, aux, new_entry = _layer_prefill(params["shared_attn"], x,
                                               positions, aux, entry, seg, cfg)
            new_cache[str(seg.index)] = new_entry
            continue
        seg_params = params["segments"][str(seg.index)]

        def scan_body(carry, inp, seg=seg):
            xx, aa = carry
            lp, ce = inp
            xx, aa, ne = _layer_prefill(lp, xx, positions, aa, ce, seg, cfg)
            return (xx, aa), ne

        if cfg.remat:
            scan_body = jax.checkpoint(scan_body)
        (x, aux), seg_cache = jax.lax.scan(scan_body, (x, aux),
                                           (seg_params, entry))
        new_cache[str(seg.index)] = seg_cache
    return x, aux, new_cache


def decode(params: dict, x: jnp.ndarray, pos: jnp.ndarray, cache: dict,
           cfg: ArchConfig):
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    for seg in build_segments(cfg):
        entry = cache[str(seg.index)]
        if seg.kind == "shared_attn":
            x, aux, new_entry = _layer_decode(params["shared_attn"], x, pos,
                                              aux, entry, seg, cfg)
            new_cache[str(seg.index)] = new_entry
            continue
        seg_params = params["segments"][str(seg.index)]

        def scan_body(carry, inp, seg=seg):
            xx, aa = carry
            lp, ce = inp
            xx, aa, ne = _layer_decode(lp, xx, pos, aa, ce, seg, cfg)
            return (xx, aa), ne

        (x, aux), seg_cache = jax.lax.scan(scan_body, (x, aux),
                                           (seg_params, entry))
        new_cache[str(seg.index)] = seg_cache
    return x, aux, new_cache
