"""Shared neural-net layers: norms, rope, mlp, embeddings, losses.

Pure-functional: params are nested dicts of jnp arrays; every function takes
(params, inputs) and returns outputs. Initializers take an explicit rng.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(rng, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------------- #

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]              # (..., S, 1, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# SwiGLU MLP
# --------------------------------------------------------------------------- #

def mlp_init(rng, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w1": _dense_init(k1, (d, f), dtype),
        "w3": _dense_init(k2, (d, f), dtype),
        "w2": _dense_init(k3, (f, d), dtype),
    }


def mlp_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #

def embed_init(rng, vocab: int, d: int, dtype) -> jnp.ndarray:
    return _dense_init(rng, (vocab, d), dtype, scale=0.02)


def head_init(rng, d: int, vocab: int, dtype) -> jnp.ndarray:
    return _dense_init(rng, (d, vocab), dtype)


# --------------------------------------------------------------------------- #
# Losses
# --------------------------------------------------------------------------- #

def chunked_lm_loss(h: jnp.ndarray, lm_head: jnp.ndarray,
                    labels: jnp.ndarray, mask: jnp.ndarray | None = None,
                    chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy without materializing the full (B,S,V) logits.

    Scans over sequence chunks; each chunk's logits live only inside the
    (rematerialized) scan body, so peak memory is O(B·chunk·V) instead of
    O(B·S·V) — the dominant training-memory term for 50k-262k vocabularies.
    lm_head gradients accumulate across chunks via the scan's reverse pass.
    """
    B, S, d = h.shape
    cs = min(chunk, S)
    while S % cs:
        cs //= 2
    nc = S // cs
    hc = jnp.moveaxis(h.reshape(B, nc, cs, d), 1, 0)          # (nc,B,cs,d)
    lc = jnp.moveaxis(labels.reshape(B, nc, cs), 1, 0)
    if mask is None:
        mc = jnp.ones((nc, B, cs), jnp.float32)
    else:
        mc = jnp.moveaxis(mask.reshape(B, nc, cs), 1, 0).astype(jnp.float32)

    def body(carry, inp):
        nll_sum, cnt = carry
        hh, ll, mm = inp
        logits = (hh @ lm_head.astype(hh.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum((lse - gold) * mm)
        cnt = cnt + jnp.sum(mm)
        return (nll_sum, cnt), None

    (nll, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)),
                                 (hc, lc, mc))
    return nll / jnp.maximum(cnt, 1.0)


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean CE over masked positions. logits (..., V) any float dtype; f32 math."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
