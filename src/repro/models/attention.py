"""Attention: GQA (+RoPE, QKV-bias, sliding window), MLA, decode paths.

Prefill uses a *blockwise* formulation (scan over query blocks) so the (S x S)
score matrix is never materialized — required for 32k-token prefill. The Pallas
flash-attention kernel in ``repro.kernels`` is the TPU-tiled version of the same
contraction; this jnp path is its reference and the default on CPU.

Decode attends a single query over a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init, apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# GQA parameters
# --------------------------------------------------------------------------- #

def gqa_init(rng, d: int, n_heads: int, n_kv: int, head_dim: int,
             qkv_bias: bool, dtype) -> dict:
    """Weights kept FLAT (d, H*hd): the fused head dim shards over `model`
    even when H (or KV) is smaller than the mesh axis (gemma3: 8 heads on a
    16-way axis; qwen/granite/llava: 8 kv heads). Activations are reshaped to
    (B,S,H,hd) after the projection matmul."""
    ks = jax.random.split(rng, 4)
    p = {
        "wq": _dense_init(ks[0], (d, n_heads * head_dim), dtype),
        "wk": _dense_init(ks[1], (d, n_kv * head_dim), dtype),
        "wv": _dense_init(ks[2], (d, n_kv * head_dim), dtype),
        "wo": _dense_init(ks[3], (n_heads * head_dim, d), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def gqa_project(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                rope_theta: float, n_heads: int, n_kv: int, head_dim: int):
    """x (B,S,d) -> q (B,S,H,hd), k,v (B,S,KV,hd) with rope applied to q,k."""
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


# --------------------------------------------------------------------------- #
# Blockwise exact attention (prefill)
# --------------------------------------------------------------------------- #

def _pick_block(s: int, target: int = 512) -> int:
    if s <= target:
        return s
    b = target
    while s % b:
        b //= 2
    return max(b, 1)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        q_block: int = 0) -> jnp.ndarray:
    """Exact attention, O(S*block) score memory.

    q (B,S,H,hd); k,v (B,T,KV,hd) with H % KV == 0. ``window``>0 restricts each
    query to the last `window` keys (inclusive of self); FLOPs are then
    O(S * (window + block)) instead of O(S*T).
    Assumes queries and keys share the same absolute positions 0..S-1 (prefill).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    v_hd = v.shape[-1]  # may differ from hd (MLA decompressed values)
    g = H // KV
    scale = 1.0 / np.sqrt(hd)
    bq = q_block or _pick_block(S)
    n_blocks = S // bq
    assert n_blocks * bq == S, (S, bq)

    q_scaled = (q * scale).astype(q.dtype)
    # reshape q to blocks: (nb, B, bq, H, hd)
    qb = jnp.moveaxis(q_scaled.reshape(B, n_blocks, bq, H, hd), 1, 0)

    use_window = window > 0
    if use_window:
        # keys needed by q block starting at qs: [qs - window + 1, qs + bq)
        span = window + bq  # static slice width
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    def q_block_body(carry, inp):
        qi, idx = inp
        qs = idx * bq  # dynamic scalar
        if use_window:
            kk = jax.lax.dynamic_slice_in_dim(kp, qs + pad - window, span, axis=1)
            vv = jax.lax.dynamic_slice_in_dim(vp, qs + pad - window, span, axis=1)
            # absolute key positions for the slice
            kpos = qs - window + jnp.arange(span)
        else:
            kk, vv = k, v
            kpos = jnp.arange(T)
        qpos = qs + jnp.arange(bq)
        scores = jnp.einsum("bqhk,bthk->bhqt",
                            qi,
                            jnp.repeat(kk, g, axis=2) if g > 1 else kk,
                            preferred_element_type=jnp.float32)
        mask = jnp.ones((bq, kpos.shape[0]), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if use_window:
            mask &= (kpos[None, :] > qpos[:, None] - window) & (kpos[None, :] >= 0)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        out = jnp.einsum("bhqt,bthk->bqhk", p,
                         jnp.repeat(vv, g, axis=2) if g > 1 else vv)
        return carry, out

    # Remat each q-block: without it, reverse-mode scan saves every block's
    # (B,H,bq,T) f32 softmax — 34 GB/layer at zamba2 train scale. Recomputing
    # the block forward during backward is exactly flash-attention's bwd.
    body = jax.checkpoint(q_block_body) if n_blocks > 1 else q_block_body
    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(n_blocks)))
    # outs (nb, B, bq, H, v_hd) -> (B, S, H, v_hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, v_hd)


# --------------------------------------------------------------------------- #
# Decode attention over a (ring) cache
# --------------------------------------------------------------------------- #

def decode_attend(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                  pos: jnp.ndarray, *, window: int = 0) -> jnp.ndarray:
    """q (B,1,H,hd); caches (B,C,KV,hd); pos scalar int32 = current position.

    For window>0 the cache is a ring buffer of size C==window: slot j holds
    absolute position  pos - ((pos - j) mod C)  (<= pos). Otherwise slot j
    holds absolute position j, valid iff j <= pos.
    """
    B, _, H, hd = q.shape
    C, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = 1.0 / np.sqrt(hd)
    slots = jnp.arange(C)
    if window > 0:
        abs_pos = pos - jnp.mod(pos - slots, C)
        valid = abs_pos >= 0
    else:
        valid = slots <= pos
    kk = jnp.repeat(k_cache, g, axis=2) if g > 1 else k_cache
    vv = jnp.repeat(v_cache, g, axis=2) if g > 1 else v_cache
    scores = jnp.einsum("bqhk,bthk->bhqt", q * scale, kk,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthk->bqhk", p, vv)


def cache_write(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                k_new: jnp.ndarray, v_new: jnp.ndarray, pos: jnp.ndarray,
                *, window: int = 0):
    """Write one token's k,v (B,1,KV,hd) at `pos` (ring-buffered if window>0)."""
    C = k_cache.shape[1]
    slot = jnp.mod(pos, C) if window > 0 else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache


# --------------------------------------------------------------------------- #
# MLA (DeepSeek-V2): compressed-KV attention
# --------------------------------------------------------------------------- #

def mla_init(rng, d: int, n_heads: int, kv_lora: int, rope_hd: int,
             nope_hd: int, v_hd: int, dtype) -> dict:
    """Flat weight layout (see gqa_init) — fused head dims shard over model."""
    ks = jax.random.split(rng, 6)
    return {
        "wq": _dense_init(ks[0], (d, n_heads * (nope_hd + rope_hd)), dtype),
        "w_dkv": _dense_init(ks[1], (d, kv_lora), dtype),
        "w_kpe": _dense_init(ks[2], (d, rope_hd), dtype),
        "w_uk": _dense_init(ks[3], (kv_lora, n_heads * nope_hd), dtype),
        "w_uv": _dense_init(ks[4], (kv_lora, n_heads * v_hd), dtype),
        "wo": _dense_init(ks[5], (n_heads * v_hd, d), dtype),
    }


def mla_compress(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                 rope_theta: float):
    """x (B,S,d) -> c_kv (B,S,r), k_pe (B,S,rope_hd) [rope applied]."""
    c_kv = x @ params["w_dkv"]
    k_pe = (x @ params["w_kpe"])[:, :, None, :]       # (B,S,1,rope_hd)
    k_pe = apply_rope(k_pe, positions, rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def _mla_dims(params: dict, nope_hd: int):
    rope_hd = params["w_kpe"].shape[1]
    H = params["wq"].shape[1] // (nope_hd + rope_hd)
    v_hd = params["w_uv"].shape[1] // H
    return H, rope_hd, v_hd


def mla_queries(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                rope_theta: float, nope_hd: int):
    B, S, _ = x.shape
    H, rope_hd, _ = _mla_dims(params, nope_hd)
    q = (x @ params["wq"]).reshape(B, S, H, nope_hd + rope_hd)
    q_nope, q_pe = q[..., :nope_hd], q[..., nope_hd:]
    q_pe = apply_rope(q_pe, positions, rope_theta)
    return q_nope, q_pe


def mla_prefill(params: dict, x: jnp.ndarray, positions: jnp.ndarray, *,
                rope_theta: float, nope_hd: int, causal: bool = True) -> tuple:
    """Returns (out (B,S,d), (c_kv, k_pe) for caching)."""
    B, S, _ = x.shape
    H, rope_hd, v_hd = _mla_dims(params, nope_hd)
    c_kv, k_pe = mla_compress(params, x, positions, rope_theta)
    q_nope, q_pe = mla_queries(params, x, positions, rope_theta, nope_hd)
    # decompress keys/values (prefill only; decode uses the absorbed form)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, nope_hd)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, v_hd)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, rope_hd))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    ctx = blockwise_attention(q_full, k_full, v, causal=causal)
    out = ctx.reshape(B, S, H * v_hd) @ params["wo"]
    return out, (c_kv, k_pe)


def mla_decode(params: dict, x: jnp.ndarray, pos: jnp.ndarray,
               c_cache: jnp.ndarray, pe_cache: jnp.ndarray, *,
               rope_theta: float, nope_hd: int):
    """Absorbed single-token MLA decode.

    x (B,1,d); c_cache (B,C,r), pe_cache (B,C,rope_hd). Returns (out (B,1,d),
    updated caches). Scores are computed in the compressed space:
      score = (W_uk^T q_nope) . c  +  q_pe . k_pe
    and the context is re-expanded once per step: o = W_uv (sum_t p_t c_t).
    """
    positions = pos[None]  # (1,) broadcast over batch
    c_new, pe_new = mla_compress(params, x, positions, rope_theta)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        c_cache, c_new.astype(c_cache.dtype), pos, axis=1)
    pe_cache = jax.lax.dynamic_update_slice_in_dim(
        pe_cache, pe_new.astype(pe_cache.dtype), pos, axis=1)

    q_nope, q_pe = mla_queries(params, x, positions, rope_theta, nope_hd)
    B = x.shape[0]
    H, rope_hd, v_hd = _mla_dims(params, nope_hd)
    r = c_cache.shape[-1]
    w_uk = params["w_uk"].reshape(r, H, nope_hd)
    w_uv = params["w_uv"].reshape(r, H, v_hd)
    scale = 1.0 / np.sqrt(nope_hd + rope_hd)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)            # (B,1,H,r)
    scores = (jnp.einsum("bshr,btr->bhst", q_c, c_cache)
              + jnp.einsum("bshk,btk->bhst", q_pe, pe_cache)) * scale
    valid = jnp.arange(c_cache.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bhst,btr->bshr", p, c_cache)            # (B,1,H,r)
    ctx = jnp.einsum("bshr,rhk->bshk", ctx_c, w_uv)             # (B,1,H,v_hd)
    out = ctx.reshape(B, 1, H * v_hd) @ params["wo"]
    return out, (c_cache, pe_cache)
