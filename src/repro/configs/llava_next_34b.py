"""llava-next-34b — VLM language decoder; vision frontend stubbed.

Assigned: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000, anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

Per the brief, the ViT/SigLIP encoder + projector is a STUB: ``input_specs`` provides
pre-computed patch embeddings (B, n_patches, d_model) which the decoder consumes as a
prefix (anyres => 2880 patch tokens: 5 tiles x 576).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llava_next_34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    modality="vision_text",
    n_patches=2880,   # anyres: 4 tiles + base, 576 patches each
    fl_clients=16,
    fl_local_steps=1,
    fsdp=True,
    sequential_clients=True,
    param_dtype="bfloat16",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384,
        vocab_size=512, n_patches=16, fl_clients=4, fsdp=False, remat=False,
    )
