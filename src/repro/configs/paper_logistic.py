"""Paper-scale strongly convex model: multinomial logistic regression.

Matches the paper's MNIST experiment structure (Section 7): 10 classes,
l2 regularization via weight decay 1e-3, N=100 clients, 2 classes/client.
Input: 64-d synthetic features (offline stand-in for 784-d MNIST).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="paper_logistic",
    family="tabular",
    n_layers=0,
    d_model=64,       # feature dim
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=10,    # n classes
    encoder_only=True,
    modality="tabular",
    fl_clients=100,
    fl_local_steps=5,
    param_dtype="float32",
    compute_dtype="float32",
    source="paper §7 (MNIST/logistic), synthetic stand-in",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(fl_clients=8)
