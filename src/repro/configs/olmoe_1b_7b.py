"""olmoe-1b-7b — fully-MoE decoder (64 experts, top-8).

Assigned: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8.
[arXiv:2409.02060]
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    fl_clients=16,
    fl_local_steps=2,
    param_dtype="bfloat16",
    source="arXiv:2409.02060",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512, n_experts=4, top_k=2, moe_capacity_factor=2.0, moe_d_ff=96,
        fl_clients=4, remat=False,
    )
