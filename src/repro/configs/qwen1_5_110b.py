"""qwen1.5-110b — large dense decoder with QKV bias.

Assigned: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
[hf:Qwen/Qwen1.5-0.5B]

At 110B parameters this is the memory-limit case for MIFA's update array:
K=1 local steps (no transient diverged client params), 2-D FSDP x TP param
sharding, and the int8 update-memory option (docs/architecture.md §3).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen1_5_110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49_152,
    vocab_size=152_064,
    qkv_bias=True,
    fl_clients=16,
    fl_local_steps=1,
    fsdp=True,
    sequential_clients=True,
    inner_update_constraint=True,
    param_dtype="bfloat16",   # HBM budget at 110B (docs/architecture.md §3)
    memory_dtype="bfloat16",  # paper-faithful; int8 variant benchmarked separately
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384,
        vocab_size=512, fl_clients=4, fsdp=False, remat=False,
    )
