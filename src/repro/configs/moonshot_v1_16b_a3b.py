"""moonshot-v1-16b-a3b — Moonlight-16B-A3B-style MoE decoder.

Assigned: 48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B]

Note: the assignment tags this [dense] but carries MoE fields; Moonlight-16B-A3B is a
DeepSeek-V3-style MoE (16B total / 3B active), so we implement it as an MoE with
64 routed experts, top-6, per-expert hidden 1408 (see docs/architecture.md §4).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="moonshot_v1_16b_a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
    fl_clients=16,
    fl_local_steps=1,
    param_dtype="bfloat16",
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512, n_experts=4, top_k=2, moe_capacity_factor=2.0, moe_d_ff=96,
        fl_clients=4, remat=False,
    )
