"""zamba2-7b — hybrid Mamba2 backbone with shared attention blocks.

Assigned: 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64,
Mamba2 + shared attn blocks. [arXiv:2411.15242]

Zamba2 interleaves a *single shared* attention(+MLP) block into the Mamba2 backbone
(same parameters re-used at each insertion). We insert it every 6th layer.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    hybrid_attn_every=6,
    fl_clients=16,
    fl_local_steps=1,
    param_dtype="bfloat16",
    source="arXiv:2411.15242",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, ssm_state=16, ssm_headdim=32, ssm_chunk=32,
        hybrid_attn_every=2, fl_clients=4, remat=False,
    )
