"""gemma3-4b — dense decoder with 5:1 local:global sliding-window attention.

Assigned: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, 5:1 local:global,
128k context. [hf:google/gemma-3-1b-pt]

head_dim=256 per the Gemma-3 model card (not d_model/n_heads); local window 1024.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10_240,
    vocab_size=262_144,
    swa_window=1024,
    swa_pattern=6,          # 5 local : 1 global
    rope_theta=1_000_000.0, # long-context rope base for global layers
    fl_clients=16,
    fl_local_steps=2,
    param_dtype="bfloat16",
    source="hf:google/gemma-3-1b-pt",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, swa_window=16, swa_pattern=2,
        fl_clients=4, remat=False,
    )
