"""Architecture & input-shape configuration registry.

Every assigned architecture has one ``<id>.py`` in this package defining
``CONFIG: ArchConfig`` with the exact assigned numbers (source cited in the
docstring) and ``smoke() -> ArchConfig`` returning a reduced variant of the
same family (<=2 layers, d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional


# --------------------------------------------------------------------------- #
# Input shapes (assigned)
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------- #
# Architecture config
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ArchConfig:
    """A complete architecture description consumed by models.registry.build_model."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int            # 0 for attention-free (pure ssm)
    n_kv_heads: int
    d_ff: int               # dense-MLP hidden size (0 => no dense MLP, e.g. pure ssm)
    vocab_size: int
    head_dim: int = 0       # 0 => d_model // n_heads

    # --- attention flavour ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True     # False for encoder-only (hubert)
    # sliding-window attention: every `swa_pattern`-th layer is global, rest local
    swa_window: int = 0     # 0 => full attention everywhere
    swa_pattern: int = 0    # e.g. 6 for gemma3's 5 local : 1 global

    # --- MLA (DeepSeek) ---
    kv_lora_rank: int = 0   # 0 => standard GQA
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0      # 0 => dense MLP
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0       # per-expert hidden (defaults to d_ff)
    router_aux_coef: float = 0.01
    first_dense_layers: int = 0  # leading dense layers before MoE layers (DS-V2 style)
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2) ---
    ssm_state: int = 0      # d_state; 0 => no ssm blocks
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (Zamba2) ---
    hybrid_attn_every: int = 0  # insert a *shared* attention block every k-th layer
    shared_attn_window: int = 0  # window the shared attn (long-context serving)

    # --- modality ---
    modality: str = "text"      # text | vision_text | audio
    n_patches: int = 0          # vlm: patch embeddings prepended (stub frontend)
    encoder_only: bool = False

    # --- FL / training defaults ---
    fl_clients: int = 16        # silo clients = data-axis extent for large archs
    fl_local_steps: int = 1
    remat: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    fsdp: bool = False          # 2-D (data x model) parameter sharding
    sequential_clients: bool = False  # scan clients (memory) vs vmap (speed)
    # constrain per-client updates to the 2-D G sharding inside the client
    # scan: helps param-heavy archs (qwen), hurts activation-heavy ones
    # (llava) — see EXPERIMENTS.md §Perf H1/H2
    inner_update_constraint: bool = False
    memory_dtype: str = "bfloat16"  # MIFA update-array storage dtype
    ce_chunk: int = 0           # >0: chunked cross-entropy (seq chunk size)
    # pad attention heads (compute-layout only, params untouched) so the head
    # count divides the TP axis — avoids XLA splitting head_dim (which turns
    # the score contraction into partial sums all-reduced at score size)
    pad_q_heads: int = 0
    pad_kv_heads: int = 0

    # --- citation ---
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_layer_arch(self) -> bool:
        return self.ssm_state > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def supports_long_decode(self) -> bool:
        """long_500k needs sub-quadratic attention (or no attention)."""
        if self.encoder_only:
            return False
        if self.ssm_state > 0:  # ssm & hybrid
            return True
        return self.swa_window > 0  # SWA-dense (gemma3)

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'attn' | 'local_attn' | 'ssm' | 'shared_attn'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm",):
                kinds.append("ssm")
            elif self.family == "hybrid":
                # zamba2: mamba2 backbone, shared attention block every k layers
                if self.hybrid_attn_every and (i % self.hybrid_attn_every
                                               == self.hybrid_attn_every - 1):
                    kinds.append("shared_attn")
                else:
                    kinds.append("ssm")
            elif self.swa_pattern:
                # gemma3: (pattern-1) local layers then 1 global, repeating
                kinds.append("attn" if (i % self.swa_pattern
                                        == self.swa_pattern - 1) else "local_attn")
            else:
                kinds.append("attn")
        return kinds

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

ARCH_IDS = [
    "moonshot_v1_16b_a3b",
    "deepseek_v2_lite_16b",
    "mamba2_1_3b",
    "gemma3_4b",
    "olmoe_1b_7b",
    "zamba2_7b",
    "qwen1_5_110b",
    "granite_3_8b",
    "llava_next_34b",
    "hubert_xlarge",
]

# map the assignment's dashed ids to module names
_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIAS.update({
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mamba2-1.3b": "mamba2_1_3b",
    "gemma3-4b": "gemma3_4b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "zamba2-7b": "zamba2_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "granite-3-8b": "granite_3_8b",
    "llava-next-34b": "llava_next_34b",
    "hubert-xlarge": "hubert_xlarge",
})

# paper-scale configs also live here
PAPER_IDS = ["paper_logistic", "paper_mlp"]


def canonical_id(arch: str) -> str:
    key = arch.strip()
    if key in ARCH_IDS or key in PAPER_IDS:
        return key
    if key in _ALIAS:
        return _ALIAS[key]
    norm = key.replace("-", "_").replace(".", "_")
    if norm in ARCH_IDS or norm in PAPER_IDS:
        return norm
    raise KeyError(f"unknown architecture {arch!r}; known: {ARCH_IDS + PAPER_IDS}")


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.smoke()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
