"""granite-3-8b — dense GQA decoder.

Assigned: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="granite_3_8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12_800,
    vocab_size=49_155,
    fl_clients=16,
    fl_local_steps=2,
    param_dtype="bfloat16",
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384,
        vocab_size=512, fl_clients=4, remat=False,
    )
