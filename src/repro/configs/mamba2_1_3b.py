"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).

Assigned: 48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
[arXiv:2405.21060]
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_1_3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    fl_clients=16,
    fl_local_steps=2,
    param_dtype="bfloat16",
    source="arXiv:2405.21060",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, vocab_size=512, ssm_state=16,
        ssm_headdim=32, ssm_chunk=32, fl_clients=4, remat=False,
    )
