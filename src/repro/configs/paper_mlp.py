"""Paper-scale non-convex model: 2-hidden-layer ReLU MLP.

Offline stand-in for the paper's LeNet-5/CIFAR-10 experiment (Section 7):
non-convex, 10 classes, N=100 clients, 2 classes/client, weight decay 1e-3.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="paper_mlp",
    family="tabular",
    n_layers=2,       # hidden layers
    d_model=256,      # feature dim
    n_heads=0,
    n_kv_heads=0,
    d_ff=128,         # hidden width
    vocab_size=10,
    encoder_only=True,
    modality="tabular",
    fl_clients=100,
    fl_local_steps=5,
    param_dtype="float32",
    compute_dtype="float32",
    source="paper §7 (CIFAR-10/LeNet-5), synthetic MLP stand-in",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(fl_clients=8, d_model=32, d_ff=16)
