"""deepseek-v2-lite-16b — DeepSeek-V2-Lite MoE with MLA attention.

Assigned: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6,
MLA kv_lora=512, 2 shared + routed experts top-6. [arXiv:2405.04434]

The bracket note mentions "160 routed" (the non-lite V2); the assigned fields say
64 experts top-6, so we follow the fields and add the 2 shared experts.
The first layer is dense (DeepSeek-V2 convention).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite_16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # unused under MLA, kept for bookkeeping
    d_ff=1408,              # shared-expert / dense-layer hidden
    vocab_size=102_400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    kv_lora_rank=512,
    q_lora_rank=0,          # v2-lite has no q compression
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    fl_clients=16,
    fl_local_steps=1,
    param_dtype="bfloat16",
    source="arXiv:2405.04434",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, d_ff=96, vocab_size=512,
        n_experts=4, top_k=2, moe_capacity_factor=2.0, n_shared_experts=1, moe_d_ff=96,
        first_dense_layers=1, kv_lora_rank=64, rope_head_dim=16,
        nope_head_dim=32, v_head_dim=32, fl_clients=4, remat=False,
    )
