"""hubert-xlarge — encoder-only audio transformer; conv frontend stubbed.

Assigned: 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504, encoder-only,
same backbone as wav2vec2. [arXiv:2106.07447]

Per the brief, the mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` provides frame embeddings (B, n_frames, d_model). Training is
masked-frame cluster prediction over the 504-unit codebook. Encoder-only =>
no decode shapes (docs/architecture.md §4).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="hubert_xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    encoder_only=True,
    modality="audio",
    fl_clients=16,
    fl_local_steps=2,
    param_dtype="bfloat16",
    source="arXiv:2106.07447",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=64, fl_clients=4, remat=False,
    )
