"""Vmapped fleet executor: K independent FL trials as one jitted program."""
from repro.fleet.executor import (FleetHistory, FleetRunner,  # noqa: F401
                                  FleetScanDriver, fleet_scan_supported,
                                  make_fleet_eval, run_fleet)
from repro.fleet.spec import FleetSpec, Trial, expand_grid  # noqa: F401
from repro.fleet.sim import SimTrial, run_sim_fleet  # noqa: F401
