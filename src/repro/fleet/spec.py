"""FleetSpec — declarative sweep grids expanded into batched trial lists.

A *trial* is one independent FL run: (init seed, participation process,
trial label). A *FleetSpec* is a group of trials that can execute as ONE
vmapped program — which requires the algorithm's *static* configuration
(class, memory layout, cohort capacity, FedAvgSampling's S, FedAvgIS's
probability table) to be shared across the group; everything that is traced
(init params, RNG streams, availability masks, learning rates) batches
freely along the trial axis.

`expand_grid` builds the cross product seeds × availability-parameter points
per algorithm:

    specs = expand_grid(
        algos={"mifa": MIFA(memory="array"), "fedavg": BiasedFedAvg()},
        seeds=(0, 1, 2),
        avail_grid=({"p_min": 0.1}, {"p_min": 0.2}),
        make_participation=lambda seed, p_min: BernoulliParticipation(
            label_correlated_probs(labels, p_min), seed=seed + 100),
    )
    for spec in specs:
        params, hist = run_fleet(spec=spec, model=model, batcher=batcher, ...)

One FleetSpec per algorithm (static config can't batch); K = |seeds| ×
|avail_grid| trials inside each. Algorithms whose static config depends on
the availability point (e.g. FedAvgIS's probs) need one spec per point —
`expand_grid` accepts `algos` values as callables `(avail_kwargs) -> algo`
for that case and then emits one spec per (algo, avail point).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


@dataclass(frozen=True)
class Trial:
    """One independent FL run inside a fleet group.

    Availability comes from exactly one of:
      * participation — legacy host-side process (``.sample(t) -> (N,)``);
        the driver draws each round's mask on the host.
      * scenario — a `repro.scenarios` process/Scenario; dense fleet groups
        sample the mask INSIDE the vmapped jitted round (no host trace),
        cohort groups use the scenario's host surface. All trials in one
        group must share the scenario *type* (one pure sample function);
        per-trial parameters and chain state batch along the trial axis.
    """

    seed: int
    participation: Any = None     # host-side process with .sample(t) -> (N,)
    scenario: Any = None          # repro.scenarios process or Scenario
    label: str = ""

    def __post_init__(self):
        if (self.participation is None) == (self.scenario is None):
            raise ValueError(
                "Trial needs exactly one of participation= or scenario=")


@dataclass
class FleetSpec:
    """A group of trials sharing one vmapped executable.

    `scan_chunk` tunes the scan-native path (`run_fleet(engine="scan")`):
    the K×T sweep compiles into `lax.scan` programs of up to `scan_chunk`
    rounds each (docs/architecture.md §9). None defers to the driver's
    default; the loop engine ignores it.
    """

    algo: Any
    trials: list[Trial] = field(default_factory=list)
    uses_update_clock: bool = False
    cohort_capacity: int | None = None
    scan_chunk: int | None = None
    name: str = ""

    @property
    def n_trials(self) -> int:
        """K — the number of trials batched into this group."""
        return len(self.trials)

    @property
    def seeds(self) -> tuple:
        """Per-trial init/RNG seeds, in trial order."""
        return tuple(t.seed for t in self.trials)

    @property
    def participations(self) -> tuple:
        """Per-trial participation processes (None for scenario trials)."""
        return tuple(t.participation for t in self.trials)

    @property
    def labels(self) -> list[str]:
        """Per-trial display labels, in trial order."""
        return [t.label for t in self.trials]


def _avail_tag(kwargs: dict) -> str:
    return ",".join(f"{k}{v}" for k, v in sorted(kwargs.items()))


def expand_grid(*, algos: dict[str, Any], seeds: Sequence[int],
                make_participation: Callable | None = None,
                make_scenario: Callable | None = None,
                avail_grid: Sequence[dict] = ({},),
                clock: Sequence[str] = (),
                cohort_capacity: int | None = None) -> list[FleetSpec]:
    """Expand (algorithm × seed × availability point) into FleetSpecs.

    Args:
      algos: name -> algorithm instance, or name -> callable taking the
        availability kwargs and returning an instance (for algorithms whose
        static config depends on the point, e.g. FedAvgIS). Instances get
        one spec with seeds × avail_grid trials; callables get one spec PER
        grid point (seeds only batch).
      seeds: model-init/RNG seeds; each becomes one trial per grid point.
      make_participation: ``(seed=..., **avail_kwargs) -> host process``
        (legacy surface). Exactly one of this and `make_scenario`.
      make_scenario: ``(seed=..., **avail_kwargs) -> scenario process`` —
        trials carry `Trial.scenario` and dense groups sample availability
        inside the vmapped round (jit-native surface). Scenario *types*
        must not vary across one spec's grid points (one pure function per
        vmapped program); sweep types via separate expand_grid calls.
      avail_grid: availability parameter points (dicts of kwargs).
      clock: algo names that use the update clock (FedAvgSampling-style).
      cohort_capacity: pinned cohort pad width for cohort algorithms.

    Returns:
      One `FleetSpec` per algorithm (or per (algorithm, point) for
      callable algos), each runnable as ONE vmapped program.
    """
    if (make_participation is None) == (make_scenario is None):
        raise ValueError(
            "pass exactly one of make_participation= or make_scenario=")

    def _trial(s: int, av: dict, name: str) -> Trial:
        label = f"{name}/{_avail_tag(av)}/seed{s}"
        if make_scenario is not None:
            return Trial(seed=s, scenario=make_scenario(seed=s, **av),
                         label=label)
        return Trial(seed=s, participation=make_participation(seed=s, **av),
                     label=label)

    specs: list[FleetSpec] = []
    for name, algo in algos.items():
        common = dict(uses_update_clock=name in clock,
                      cohort_capacity=cohort_capacity)
        if callable(algo) and not hasattr(algo, "init_state"):
            for av in avail_grid:
                trials = [_trial(s, av, name) for s in seeds]
                specs.append(FleetSpec(algo=algo(**av), trials=trials,
                                       name=f"{name}/{_avail_tag(av)}",
                                       **common))
        else:
            trials = [_trial(s, av, name)
                      for av in avail_grid for s in seeds]
            specs.append(FleetSpec(algo=algo, trials=trials, name=name,
                                   **common))
    return specs
