"""Vmapped fleet executor — K independent FL trials as ONE jitted program.

The paper's headline results are statistical claims over seeds ×
participation scenarios × algorithms, but a Python loop over `run_fl` pays
per-trial dispatch, per-trial retracing, and per-trial host→device traffic.
The fleet executor stacks K trials along a leading *trial axis* and runs
each round as a single `jit(vmap(...))` call:

    params : (K, *shape)      state : per-algo leaves with a (K,) prefix
    rngs   : (K, 2)           masks : (K, N) from K host-side processes

Reuse, not reimplementation: the vmapped round is `jax.vmap` of the SAME
pure functions `RoundRunner` jits (`core.runner.make_dense_round_fn`,
`make_cohort_update_fn`, `apply_mean`), and the banked cohort path goes
through the same `DenseBank` scatter body (vmapped jnp, or the grid-axis
batched Pallas kernel `kernels.bank_scatter_batched`). Per trial the fleet
is therefore bit-exactly the trajectory `run_fl` produces — property-tested
in tests/test_fleet.py.

What is and is not vmappable (docs/architecture.md §7):
  * dense algorithms (MIFA array/delta/int8, FedAvg baselines)   — yes
  * BankedMIFA over DenseBank (jittable)                         — yes
  * BankedMIFA over PagedDeviceBank (jittable; one residency map
    shared across trials, paged in per round / chunk union)      — yes
  * BankedMIFA over HostBank / Int8PagedBank (host-offloaded)    — no; these
    live outside jit by design, run those trials sequentially.

The availability environment comes in two flavours. Legacy participation
processes stay per-trial and un-vmapped: each trial's (N,) mask is drawn on
the host exactly as `run_fl` would draw it. `repro.scenarios` trials
instead carry a jit-native process whose state (Markov chains, drifting
rates — parameters included) stacks along the trial axis, and the mask is
sampled INSIDE the vmapped round function (`step_scenario`): sweeping
`seed × scenario × algorithm` never materialises a (T, N) trace or loops
over trials on the host. Cohort batches are assembled per trial then
stacked. The trial axis can be sharded over the mesh's data axes
(`sharding.rules.fleet_trial_specs`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runner import (FLHistory, _pow2_bucket, apply_mean,
                               make_cohort_update_fn, make_dense_round_fn,
                               make_scenario_round_fn, warn_engine_fallback)
from repro.fleet.spec import FleetSpec, Trial


@dataclass
class FleetHistory:
    """Per-round metrics with a leading (K,) trial axis.

    `trial(k)` materialises one trial's view as a plain `FLHistory`, so
    downstream plotting/analysis written for `run_fl` works unchanged.
    """

    n_trials: int
    labels: list[str] = field(default_factory=list)
    rounds: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)     # (K,) per round
    n_active: list = field(default_factory=list)       # (K,) per round
    global_updates: list = field(default_factory=list)
    eval_loss: list = field(default_factory=list)      # (t, (K,)) per eval
    eval_acc: list = field(default_factory=list)
    sim_seconds: list = field(default_factory=list)    # (K,) close per round
    eval_seconds: list = field(default_factory=list)   # (t, (K,)) per eval
    wall_time: float = 0.0

    def record_round(self, t: int, metrics: dict, sim_time=None) -> None:
        """Append round t's (K,) metric vectors (loss, n_active, ...);
        `sim_time` stamps the round with per-trial simulated seconds
        (simulated-fleet runs, `repro.fleet.sim`)."""
        self.rounds.append(t)
        self.train_loss.append(np.asarray(metrics["loss"], np.float64))
        self.n_active.append(np.asarray(metrics["n_active"], np.float64))
        if "global_updates" in metrics:
            self.global_updates.append(
                np.asarray(metrics["global_updates"], np.float64))
        if sim_time is not None:
            self.sim_seconds.append(np.asarray(sim_time, np.float64))

    def record_eval(self, t: int, eval_loss, eval_acc,
                    sim_time=None) -> None:
        """Append an eval point: (round, (K,) losses) and (round, (K,) accs);
        `sim_time` additionally stamps it on the per-trial simulated-seconds
        axis (eval_seconds)."""
        self.eval_loss.append((t, np.asarray(eval_loss, np.float64)))
        self.eval_acc.append((t, np.asarray(eval_acc, np.float64)))
        if sim_time is not None:
            self.eval_seconds.append((t, np.asarray(sim_time, np.float64)))

    def stacked(self) -> dict:
        """{'train_loss': (K, T), 'n_active': (K, T), ...} arrays."""
        out = {"rounds": np.asarray(self.rounds),
               "train_loss": np.stack(self.train_loss, axis=1)
               if self.train_loss else np.zeros((self.n_trials, 0)),
               "n_active": np.stack(self.n_active, axis=1)
               if self.n_active else np.zeros((self.n_trials, 0))}
        if self.global_updates:
            out["global_updates"] = np.stack(self.global_updates, axis=1)
        if self.eval_loss:
            out["eval_rounds"] = np.asarray([t for t, _ in self.eval_loss])
            out["eval_loss"] = np.stack([v for _, v in self.eval_loss], 1)
            out["eval_acc"] = np.stack([v for _, v in self.eval_acc], 1)
        if self.sim_seconds:
            out["sim_seconds"] = np.stack(self.sim_seconds, axis=1)
        if self.eval_seconds:
            out["eval_seconds"] = np.stack(
                [v for _, v in self.eval_seconds], 1)
        return out

    def trial(self, k: int) -> FLHistory:
        """Trial k's view as a plain `FLHistory` (scalars, not (K,) rows)."""
        h = FLHistory()
        h.rounds = list(self.rounds)
        h.train_loss = [float(v[k]) for v in self.train_loss]
        h.n_active = [float(v[k]) for v in self.n_active]
        h.global_updates = [float(v[k]) for v in self.global_updates]
        h.eval_loss = [(t, float(v[k])) for t, v in self.eval_loss]
        h.eval_acc = [(t, float(v[k])) for t, v in self.eval_acc]
        h.sim_seconds = [float(v[k]) for v in self.sim_seconds]
        h.eval_seconds = [(t, float(v[k])) for t, v in self.eval_seconds]
        h.wall_time = self.wall_time
        return h


class FleetRunner:
    """K-trial counterpart of `core.runner.RoundRunner`.

    The driver feeds `step(t, masks)` a (K, N) availability matrix — one
    row per trial, drawn by that trial's own participation process — and
    every round executes as one jitted, vmapped program. τ statistics are
    not tracked (they are host-side O(K·N) bookkeeping; run the trial
    sequentially if you need them).
    """

    def __init__(self, *, model, algo, batcher, schedule: Callable,
                 seeds: Sequence[int], eta_local: Callable | float | None = None,
                 weight_decay: float = 0.0, uses_update_clock: bool = False,
                 cohort_capacity: int | None = None,
                 labels: Sequence[str] | None = None, mesh=None, cfg=None,
                 scenarios: Sequence | None = None):
        self.model = model
        self.algo = algo
        self.batcher = batcher
        self.schedule = schedule
        self.eta_local = eta_local
        self.weight_decay = weight_decay
        self.uses_update_clock = uses_update_clock
        self.cohort_capacity = cohort_capacity
        self.n_trials = len(seeds)
        self.n_clients = batcher.n_clients
        # one PRNG stream per trial, identical to RoundRunner(seed=s):
        # the key inits the params, then splits once per round
        self.rngs = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
        self.params = jax.vmap(model.init)(self.rngs)
        self.state = jax.vmap(
            lambda p: algo.init_state(p, self.n_clients))(self.params)
        self.hist = FleetHistory(self.n_trials,
                                 labels=list(labels or
                                             [f"seed{s}" for s in seeds]))
        self.cohort_mode = getattr(algo, "cohort_based", False)

        if self.cohort_mode:
            if not getattr(algo.bank, "jittable", False):
                raise NotImplementedError(
                    f"{type(algo.bank).__name__} is host-offloaded "
                    "(jittable=False); the vmapped fleet path needs a "
                    "jittable bank — DenseBank ('dense') or PagedDeviceBank "
                    "('paged_device') — otherwise run trials sequentially")
            updates_fn = make_cohort_update_fn(model, batcher.k_steps,
                                               weight_decay)

            def cohort_round(state, params, ubatch, idx, ids, valid,
                             eta_loc, eta_srv, rngs):
                # each distinct client's batch crosses host->device ONCE;
                # trials gather their (cap, ...) slices on device
                batch = jax.tree.map(lambda l: l[idx], ubatch)
                updates, losses = jax.vmap(updates_fn)(params, batch,
                                                       eta_loc)
                state, mean_g, metrics = algo.round_step_cohort_fleet(
                    state, ids, valid, updates, losses, rng=rngs)
                params = jax.vmap(apply_mean)(params, mean_g, eta_srv)
                return state, params, metrics

            self.cohort_round_fn = jax.jit(cohort_round,
                                           donate_argnums=(0,))
            self.round_fn = None
        else:
            base = make_dense_round_fn(model, algo, batcher.k_steps,
                                       weight_decay)
            # batch is shared across trials (the data is the environment):
            # in_axes=None broadcasts it, everything else carries the K axis
            self.round_fn = jax.jit(
                jax.vmap(base, in_axes=(0, 0, None, 0, 0, 0, 0)),
                donate_argnums=(0,))
            self.cohort_round_fn = None

        self.mesh = mesh
        self.cfg = cfg
        self._init_scenarios(scenarios, weight_decay)
        if mesh is not None:
            self._shard_trial_axis(mesh, cfg)

    def _init_scenarios(self, scenarios, weight_decay: float) -> None:
        """Wire per-trial `repro.scenarios` processes into the fleet.

        Dense groups sample availability INSIDE the vmapped round: each
        trial's scenario state (chain state + parameters) stacks along the
        trial axis and the shared pure sample function runs under the same
        jit as the round — no (T, N) trace, no per-trial host loop. Cohort
        groups (compact batches need the mask on the host) fall back to the
        scenarios' host surfaces, which draw identical masks.
        """
        self.scen_round_fn = None
        self._scen_fn = None
        self._scen_samplers = None
        self._scen_procs = None
        self._scen_win_start = None
        if scenarios is None:
            return
        from repro.scenarios.base import as_process
        procs = [as_process(s) for s in scenarios]
        self._scen_procs = procs
        assert len(procs) == self.n_trials, (len(procs), self.n_trials)
        if any(type(p) is not type(procs[0]) for p in procs):
            raise ValueError(
                "all trials in one fleet group must share a scenario type "
                "(one pure sample function per vmapped program); got "
                f"{sorted({type(p).__name__ for p in procs})} — split the "
                "sweep into one FleetSpec per type")
        for p in procs:
            assert p.n == self.n_clients, (p.n, self.n_clients)
        if self.cohort_mode:
            self._scen_samplers = [p.host_sampler() for p in procs]
            return
        self._scen_fn = procs[0].sample_fn()
        scen_round = make_scenario_round_fn(
            self.model, self.algo, self.batcher.k_steps, weight_decay,
            self._scen_fn)
        self.scen_round_fn = jax.jit(
            jax.vmap(scen_round,
                     in_axes=(0, 0, None, 0, None, 0, 0, 0, 0)),
            donate_argnums=(0,))
        self.scen_state = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *[p.init_state() for p in procs])
        self.scen_keys = jnp.stack([p.key for p in procs])
        # windowed processes (trace replay): every trial's window must be
        # the same length so the stacked (K, W, N) leaf is rectangular
        ws = {getattr(p, "scan_window", None) for p in procs}
        if len(ws) > 1:
            raise ValueError(
                "all trials in one fleet group must share the scenario "
                f"window length, got {sorted(map(str, ws))}")
        self._scen_win_start = 0 if ws != {None} else None

    def _shard_trial_axis(self, mesh, cfg) -> None:
        """Place every (K, ...)-leading trial structure — params, algorithm
        state, per-trial RNG streams, and (scenario fleets) the stacked
        chain state and scenario keys — with the trial axis over the mesh's
        data axes, so the vmapped/scanned programs run K-way data parallel."""
        from jax.sharding import NamedSharding
        from repro.core.runner import warn_legacy_threefry
        from repro.sharding.rules import fleet_axis_specs, fleet_trial_specs
        warn_legacy_threefry(mesh)
        put = lambda tree, specs: jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, specs)
        if cfg is not None:
            self.params = put(self.params,
                              fleet_trial_specs(self.params, cfg, mesh))
        else:
            self.params = put(self.params,
                              fleet_axis_specs(self.params, mesh))
        self.state = put(self.state, fleet_axis_specs(self.state, mesh))
        self.rngs = put(self.rngs, fleet_axis_specs(self.rngs, mesh))
        if getattr(self, "scen_round_fn", None) is not None:
            self.scen_state = put(self.scen_state,
                                  fleet_axis_specs(self.scen_state, mesh))
            self.scen_keys = put(self.scen_keys,
                                 fleet_axis_specs(self.scen_keys, mesh))

    # ------------------------------------------------------------------ #
    def _split(self):
        out = jax.vmap(jax.random.split)(self.rngs)      # (K, 2, 2)
        return out[:, 0], out[:, 1]

    def learning_rates(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """(η_local (K,), η_server (K,)) f32 — per-trial update clocks."""
        if self.uses_update_clock and "t_updates" in self.state:
            clocks = np.asarray(self.state["t_updates"], np.int64) + 1
        else:
            clocks = np.full(self.n_trials, t + 1, np.int64)
        eta_srv = np.array([float(self.schedule(int(c))) for c in clocks],
                           np.float32)
        if self.eta_local is None:
            eta_loc = eta_srv
        elif callable(self.eta_local):
            eta_loc = np.array(
                [float(self.eta_local(int(c))) for c in clocks], np.float32)
        else:
            eta_loc = np.full(self.n_trials, float(self.eta_local),
                              np.float32)
        return eta_loc, eta_srv

    # ------------------------------------------------------------------ #
    def step(self, t: int, masks: np.ndarray) -> dict:
        """Apply round t to all trials; masks (K, N) bool applied-updates."""
        masks = np.asarray(masks, bool)
        assert masks.shape == (self.n_trials, self.n_clients), masks.shape
        if self.cohort_mode:
            return self.step_cohort(
                t, [np.flatnonzero(m) for m in masks])
        batch = self.batcher.sample_round(t)
        eta_loc, eta_srv = self.learning_rates(t)
        self.rngs, subs = self._split()
        self.state, self.params, metrics = self.round_fn(
            self.state, self.params, batch, jnp.asarray(masks),
            jnp.asarray(eta_loc), jnp.asarray(eta_srv), subs)
        self.hist.record_round(t, metrics)
        return metrics

    def step_scenario(self, t: int) -> dict:
        """Apply round t with availability drawn BY each trial's scenario.

        Dense groups: masks are sampled inside the jitted, vmapped round —
        one program computes K masks, K cohorts of local updates, and K
        server steps. Cohort groups: the scenarios' host surfaces draw the
        same (K, N) masks and the round goes through `step` unchanged.
        """
        if self._scen_samplers is not None:        # cohort: host surface
            masks = np.stack([s.sample(t) for s in self._scen_samplers])
            return self.step(t, masks)
        assert self.scen_round_fn is not None, \
            "construct FleetRunner(scenarios=...) to use step_scenario"
        procs = self._scen_procs
        w = getattr(procs[0], "scan_window", None)
        if w is not None:
            ws = self._scen_win_start
            if ws is None or not ws <= t < ws + w:
                t0 = (t // w) * w
                self.scen_state = procs[0].load_window_fleet(
                    self.scen_state, procs, t0)
                self._scen_win_start = t0
        batch = self.batcher.sample_round(t)
        eta_loc, eta_srv = self.learning_rates(t)
        self.rngs, subs = self._split()
        (self.state, self.params, metrics, self.scen_state,
         _masks) = self.scen_round_fn(
            self.state, self.params, batch, self.scen_state, jnp.int32(t),
            self.scen_keys, jnp.asarray(eta_loc), jnp.asarray(eta_srv),
            subs)
        self.hist.record_round(t, metrics)
        return metrics

    def step_cohort(self, t: int, ids_per_trial: Sequence[np.ndarray]) -> dict:
        """Cohort round for all trials; ids_per_trial[k] are trial k's
        active rows. All trials pad to one shared capacity (the pow-2
        bucket of the largest cohort, or `cohort_capacity`) — pad slots are
        inert, so per-trial results are unchanged by the shared padding."""
        assert self.cohort_mode
        from repro.bank.base import check_unique_ids
        K = self.n_trials
        ids_per_trial = [np.asarray(i, np.int64) for i in ids_per_trial]
        for ids in ids_per_trial:
            check_unique_ids(ids)
        cmax = max((len(i) for i in ids_per_trial), default=0)
        cap = self.cohort_capacity or _pow2_bucket(cmax)
        if cmax > cap:
            # widening is shared by ALL trials (vmap needs one shape), so a
            # pinned capacity no longer matches what per-trial run_fl pads
            # non-overflowing trials to — warn instead of silently breaking
            # the bit-exact cross-path comparison the pin exists for
            import warnings
            warnings.warn(
                f"cohort of {cmax} overflows pinned cohort_capacity="
                f"{self.cohort_capacity}; widening ALL trials to "
                f"{_pow2_bucket(cmax)} — fleet trajectories may no longer "
                "be bit-exact vs sequential runs pinned to the original "
                "capacity", stacklevel=2)
            cap = _pow2_bucket(cmax)
        padded = np.full((K, cap), self.n_clients, np.int64)
        valid = np.zeros((K, cap), bool)
        for k, ids in enumerate(ids_per_trial):
            padded[k, :len(ids)] = ids
            valid[k, :len(ids)] = True
        # pad slots sample client 0's batch (computed then masked), exactly
        # like RoundRunner.step_cohort. Trials share the batcher and the
        # round index, so each distinct client is sampled ONCE for the whole
        # fleet (same (seed, t, i) streams as per-trial sampling), uploaded
        # once, and every trial gathers its (cap, ...) slice on device. The
        # union is padded to a pow-2 bucket so jit traces are reused.
        wanted = np.where(valid, padded, 0)                # (K, cap)
        uniq, inv = np.unique(wanted, return_inverse=True)
        u_pad = _pow2_bucket(len(uniq))
        uniq = np.concatenate([uniq, np.full(u_pad - len(uniq), uniq[0])])
        ubatch = self.batcher.sample_round(t, client_ids=uniq)
        idx = inv.reshape(K, cap).astype(np.int32)
        eta_loc, eta_srv = self.learning_rates(t)
        self.rngs, subs = self._split()
        # paged banks fault the cross-trial union in before the program
        # runs (one residency map shared by all trials); identity otherwise
        prep = getattr(self.algo, "prepare_cohort", None)
        if prep is not None:
            self.state = prep(self.state, padded[valid])
        self.state, self.params, metrics = self.cohort_round_fn(
            self.state, self.params, ubatch, jnp.asarray(idx),
            jnp.asarray(padded), jnp.asarray(valid), jnp.asarray(eta_loc),
            jnp.asarray(eta_srv), subs)
        self.hist.record_round(t, metrics)
        return metrics

    def evaluate(self, t: int, eval_fn: Callable) -> tuple[Any, Any]:
        """eval_fn consumes stacked params -> ((K,) losses, (K,) accs)."""
        el, ea = eval_fn(self.params)
        self.hist.record_eval(t, el, ea)
        return el, ea

    def finalize(self) -> tuple[Any, FleetHistory]:
        """Returns (stacked (K, ...) params, fleet history)."""
        return self.params, self.hist


def fleet_scan_supported(runner: FleetRunner) -> tuple[bool, str]:
    """Can this fleet group execute on the scan-native path? (ok, reason)."""
    if runner.uses_update_clock:
        return False, ("update-clock schedules read per-trial device-side "
                       "counters between rounds; the host cannot precompute "
                       "a chunk of learning rates")
    return True, ""


class FleetScanDriver:
    """Scan-native fleet execution: K trials × T rounds as one program.

    The per-trial scan body (`core.runner.make_scan_round_fn`) is vmapped
    over the trial axis and the result scanned over a chunk of rounds, so
    one `jit(scan(vmap(round)))` launch advances the whole sweep by
    `scan_chunk` rounds — per trial bit-exact against both the per-round
    fleet path and sequential `run_fl` (the body IS the same pure round
    function; tests/test_scan_engine.py). Chunk boundaries snap to eval
    rounds exactly like the sequential scan driver
    (`core.scan_engine.ScanDriver`); τ statistics are not tracked, matching
    the per-round fleet path.
    """

    def __init__(self, runner: FleetRunner, *, scan_chunk: int = 64):
        from repro.core.runner import make_scan_round_fn
        if scan_chunk < 1:
            raise ValueError(f"scan_chunk must be >= 1, got {scan_chunk}")
        self.r = r = runner
        self.scan_chunk = scan_chunk
        self.scenario_mode = r._scen_fn is not None
        # windowed scenarios (trace replay): the stacked (K, W, N) window
        # is re-paged at chunk boundaries via the pre_chunk hook, exactly
        # like the sequential ScanDriver
        self._scan_window = (getattr(r._scen_procs[0], "scan_window", None)
                             if self.scenario_mode else None)
        if self._scan_window is not None and scan_chunk > self._scan_window:
            raise ValueError(
                f"scan_chunk={scan_chunk} exceeds the scenario's carried "
                f"availability window ({self._scan_window} rounds): a chunk "
                "must be coverable by one window. Raise the scenario's "
                "window= or lower scan_chunk")
        self._seg = None
        self._win_start = None
        body = make_scan_round_fn(
            r.model, r.algo, r.batcher.k_steps, r.weight_decay,
            scen_fn=r._scen_fn, cohort=r.cohort_mode)
        if r.cohort_mode:
            self.cap = r.cohort_capacity or _pow2_bucket(r.n_clients)
            # each distinct client's batch crosses host->device ONCE per
            # round (ubatch, shared across trials); trials gather their
            # (cap, ...) slices inside the program — the same dedup the
            # per-round fleet path performs in `cohort_round`
            base = body

            def body(carry, x):
                batch = jax.tree.map(lambda l: l[x["idx"]], x["ubatch"])
                return base(carry, {"batch": batch, "ids": x["ids"],
                                    "valid": x["valid"],
                                    "eta_loc": x["eta_loc"],
                                    "eta_srv": x["eta_srv"]})

            xs_axes = {"ubatch": None, "idx": 0, "ids": 0, "valid": 0,
                       "eta_loc": 0, "eta_srv": 0}
        elif self.scenario_mode:
            xs_axes = {"batch": None, "t": None, "eta_loc": 0, "eta_srv": 0}
        else:
            xs_axes = {"batch": None, "active": 0, "eta_loc": 0,
                       "eta_srv": 0}
        vbody = jax.vmap(body, in_axes=(0, xs_axes))
        # NamedSharding tree matching the carry, set by `_init_carry`
        # before the first `_chunk_fn` trace reads it
        self._carry_shardings = None
        if getattr(r, "mesh", None) is not None:
            # pin the trial-axis placement after every vmapped round, so
            # the donated carry keeps one layout across chunk boundaries
            inner = vbody

            def vbody(carry, x):
                carry, ys = inner(carry, x)
                return (jax.lax.with_sharding_constraint(
                    carry, self._carry_shardings), ys)

        self._chunk_fn = jax.jit(
            lambda carry, xs: jax.lax.scan(vbody, carry, xs),
            donate_argnums=(0,))
        # the upcoming chunk's cross-trial cohort union, stashed by
        # _build_xs for the paged-bank pre_chunk residency hook
        self._last_union = None

    # ------------------------------------------------------------------ #
    def _init_carry(self) -> dict:
        r = self.r
        carry = {"state": r.state, "params": r.params, "rng": r.rngs}
        if self.scenario_mode:
            carry["scen_state"] = r.scen_state
            carry["scen_key"] = r.scen_keys
        if getattr(r, "mesh", None) is not None:
            from jax.sharding import NamedSharding
            from repro.sharding.rules import fleet_carry_specs
            specs = fleet_carry_specs(carry, r.mesh, cfg=r.cfg)
            self._carry_shardings = jax.tree.map(
                lambda s: NamedSharding(r.mesh, s), specs,
                is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
            carry = jax.tree.map(jax.device_put, carry,
                                 self._carry_shardings)
        return carry

    def _writeback(self, carry: dict) -> None:
        r = self.r
        r.state, r.params, r.rngs = (carry["state"], carry["params"],
                                     carry["rng"])
        if self.scenario_mode:
            r.scen_state = carry["scen_state"]

    def _etas(self, t0: int, t1: int) -> tuple[np.ndarray, np.ndarray]:
        pairs = [self.r.learning_rates(t) for t in range(t0, t1)]
        return (np.stack([p[0] for p in pairs]),
                np.stack([p[1] for p in pairs]))     # (L, K) f32

    def _build_xs(self, t0: int, t1: int, parts) -> dict:
        r = self.r
        self._seg = (t0, t1)
        eta_loc, eta_srv = self._etas(t0, t1)
        xs = {"eta_loc": eta_loc, "eta_srv": eta_srv}
        if self.scenario_mode:
            xs["t"] = np.arange(t0, t1, dtype=np.int32)
            xs["batch"] = jax.tree.map(
                lambda *ls: np.stack(ls),
                *[r.batcher.sample_round(t) for t in range(t0, t1)])
            return xs
        samplers = parts if parts is not None else r._scen_samplers
        masks = np.stack([
            np.stack([np.asarray(p.sample(t), bool) for p in samplers])
            for t in range(t0, t1)])                 # (L, K, N)
        if not r.cohort_mode:
            xs["active"] = masks
            xs["batch"] = jax.tree.map(
                lambda *ls: np.stack(ls),
                *[r.batcher.sample_round(t) for t in range(t0, t1)])
            return xs
        from repro.core.scan_engine import pad_cohort
        K, cap = r.n_trials, self.cap
        ids_l, valid_l, uniq_l, idx_l = [], [], [], []
        for j in range(t1 - t0):
            padded = np.empty((K, cap), np.int64)
            valid = np.empty((K, cap), bool)
            for k in range(K):
                padded[k], valid[k] = pad_cohort(
                    np.flatnonzero(masks[j, k]), cap, r.n_clients, t0 + j)
            # pad slots sample client 0's batch, exactly like the per-round
            # paths. Each distinct client is sampled once per round; every
            # trial's (cap, ...) slice is gathered on device inside the
            # scan body (same (seed, t, i) streams as per-trial sampling).
            wanted = np.where(valid, padded, 0)
            uniq, inv = np.unique(wanted, return_inverse=True)
            ids_l.append(padded)
            valid_l.append(valid)
            uniq_l.append(uniq)
            idx_l.append(inv.reshape(K, cap).astype(np.int32))
        # one shared pow-2 width per chunk so the stacked ubatch leaves are
        # rectangular and jit traces are reused across chunks
        u_pad = _pow2_bucket(max(len(u) for u in uniq_l))
        batch_l = []
        for j, uniq in enumerate(uniq_l):
            uniq = np.concatenate(
                [uniq, np.full(u_pad - len(uniq), uniq[0])])
            batch_l.append(r.batcher.sample_round(t0 + j, client_ids=uniq))
        xs["ids"] = np.stack(ids_l)
        xs["valid"] = np.stack(valid_l)
        xs["idx"] = np.stack(idx_l)
        xs["ubatch"] = jax.tree.map(lambda *ls: np.stack(ls), *batch_l)
        self._last_union = np.concatenate(
            [p[v] for p, v in zip(ids_l, valid_l)])
        return xs

    def _pre_chunk(self, carry: dict) -> dict:
        """Host-side streaming between chunks: page the chunk's cross-trial
        union in (cohort mode, paged banks) or re-point the trials' stacked
        availability window at the upcoming chunk (windowed scenarios)."""
        if self.r.cohort_mode:
            prep = getattr(self.r.algo, "prepare_cohort", None)
            if prep is None or self._last_union is None:
                return carry
            return {**carry, "state": prep(carry["state"], self._last_union)}
        w, (t0, t1) = self._scan_window, self._seg
        if (self._win_start is not None and self._win_start <= t0
                and t1 <= self._win_start + w):
            return carry
        procs = self.r._scen_procs
        carry = {**carry, "scen_state": procs[0].load_window_fleet(
            carry["scen_state"], procs, t0)}
        self._win_start = t0
        return carry

    # ------------------------------------------------------------------ #
    def run(self, n_rounds: int, *, parts=None,
            eval_fn: Callable | None = None, eval_every: int = 10,
            verbose: bool = False) -> None:
        """Execute `n_rounds` rounds for all trials, mutating the runner."""
        from repro.core.scan_engine import (_eval_rounds, chunk_bounds,
                                            run_pipelined_chunks)
        r = self.r
        evals = _eval_rounds(n_rounds, eval_every, eval_fn is not None)

        def flush(t0, t1, ys, _carry):
            ys = {k: np.asarray(v) for k, v in ys.items()}
            for j, t in enumerate(range(t0, t1)):
                r.hist.record_round(t, {k: v[j] for k, v in ys.items()})

        def on_sync(t):
            el, ea = r.evaluate(t, eval_fn)
            if verbose:
                print(f"  round {t:5d} loss={np.asarray(el).mean():.4f} "
                      f"acc={np.asarray(ea).mean():.4f}")

        run_pipelined_chunks(
            self._init_carry(),
            chunk_bounds(n_rounds, self.scan_chunk, evals),
            chunk_fn=self._chunk_fn,
            build_xs=lambda t0, t1: self._build_xs(t0, t1, parts),
            writeback=self._writeback, flush=flush,
            sync_rounds=evals, on_sync=on_sync,
            pre_chunk=self._pre_chunk
            if (r.cohort_mode or self._scan_window is not None) else None)


def make_fleet_eval(model, eval_batch: dict) -> Callable:
    """Vmapped eval: stacked params (K, ...) -> (losses (K,), accs (K,))."""
    batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}

    @jax.jit
    def ev(params_stack):
        def one(p):
            loss, _ = model.loss_fn(p, batch)
            return loss, model.accuracy(p, batch)
        return jax.vmap(one)(params_stack)

    return ev


def run_fleet(*, model, batcher, schedule: Callable, n_rounds: int,
              spec: FleetSpec | None = None, algo=None,
              trials: Sequence[Trial] | None = None,
              eta_local: Callable | float | None = None,
              weight_decay: float = 0.0, eval_fn: Callable | None = None,
              eval_every: int = 10, uses_update_clock: bool = False,
              cohort_capacity: int | None = None, mesh=None, cfg=None,
              engine: str = "loop", scan_chunk: int | None = None,
              verbose: bool = False) -> tuple[Any, FleetHistory]:
    """Run T rounds of K independent trials as one vmapped program.

    The K-trial counterpart of `core.runner.run_fl`: pass a `FleetSpec`
    (algo + trials + clock flag), or `algo` + `trials` explicitly.

    Args:
      model, batcher, schedule: shared problem — batcher.sample_round(t)
        yields the round's batch pytree; schedule(t) the server LR for
        each of the `n_rounds` rounds (`eta_local` overrides the client
        rate; `weight_decay` applies to the local steps;
        `uses_update_clock` drives schedules off applied global updates;
        `cohort_capacity` pins the cohort pad width).
      spec: FleetSpec carrying algo/trials/clock/capacity (or pass `algo`
        and `trials` explicitly).
      trials: `Trial` list. Trials with `participation` draw each round's
        (N,) mask on the host exactly as `run_fl` would; trials with
        `scenario` sample availability INSIDE the jitted round for dense
        algorithms (cohort algorithms use the scenario's host surface) —
        no (T, N) trace is ever materialised. One group must be all-
        participation or all-scenario.
      eval_fn: consumes stacked (K, ...) params, returns ((K,) losses,
        (K,) accs) — see `make_fleet_eval`. Runs every `eval_every` rounds.
      mesh, cfg: optional mesh to shard the trial axis over
        (`sharding.rules.fleet_trial_specs`).
      engine: "loop" (default) dispatches one vmapped program per round;
        "scan" compiles `scan_chunk`-round blocks of the whole sweep into
        single `lax.scan` programs (`FleetScanDriver`,
        docs/architecture.md §9) — bit-exact per trial, falling back to
        the loop (with a warning) for update-clock schedules;
        "scan_strict" raises instead of falling back.
      scan_chunk: rounds per compiled scan block (None: the spec's
        `scan_chunk`, else 64).

    Returns:
      (stacked params with leading (K,) axis, `FleetHistory`).
    """
    if spec is not None:
        algo = spec.algo
        trials = spec.trials
        uses_update_clock = spec.uses_update_clock
        cohort_capacity = spec.cohort_capacity or cohort_capacity
        if scan_chunk is None:
            scan_chunk = spec.scan_chunk
    assert algo is not None and trials, "need a FleetSpec or algo + trials"
    if engine not in ("loop", "scan", "scan_strict"):
        raise ValueError(f"unknown engine {engine!r}: expected 'loop', "
                         "'scan', or 'scan_strict'")
    n_scen = sum(tr.scenario is not None for tr in trials)
    if n_scen not in (0, len(trials)):
        raise ValueError("mixing scenario and participation trials in one "
                         "fleet group is not supported")
    runner = FleetRunner(
        model=model, algo=algo, batcher=batcher, schedule=schedule,
        seeds=[tr.seed for tr in trials], eta_local=eta_local,
        weight_decay=weight_decay, uses_update_clock=uses_update_clock,
        cohort_capacity=cohort_capacity,
        labels=[tr.label or f"seed{tr.seed}" for tr in trials],
        mesh=mesh, cfg=cfg,
        scenarios=[tr.scenario for tr in trials] if n_scen else None)
    parts = [tr.participation for tr in trials]
    if engine != "loop":
        ok, why = fleet_scan_supported(runner)
        if ok:
            t0 = time.time()
            FleetScanDriver(
                runner,
                scan_chunk=64 if scan_chunk is None else scan_chunk).run(
                n_rounds, parts=None if n_scen else parts, eval_fn=eval_fn,
                eval_every=eval_every, verbose=verbose)
            runner.hist.wall_time = time.time() - t0
            return runner.finalize()
        if engine == "scan_strict":
            raise ValueError(f"engine='scan_strict': {why}")
        warn_engine_fallback(
            f"engine='scan' unsupported for this fleet ({why}); "
            "falling back to the per-round loop")
    t0 = time.time()
    for t in range(n_rounds):
        if n_scen:
            runner.step_scenario(t)
        else:
            masks = np.stack([np.asarray(p.sample(t), bool) for p in parts])
            runner.step(t, masks)
        if eval_fn is not None and (t % eval_every == 0 or t == n_rounds - 1):
            el, ea = runner.evaluate(t, eval_fn)
            if verbose:
                print(f"  round {t:5d} "
                      f"loss={np.asarray(el).mean():.4f} "
                      f"acc={np.asarray(ea).mean():.4f}")
    runner.hist.wall_time = time.time() - t0
    return runner.finalize()
