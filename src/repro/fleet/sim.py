"""Simulated-fleet executor: K wall-clock trials as one scan(vmap) program.

Time-to-accuracy studies are statistical claims over seeds × server
policies, and under the compiled simulator (`repro.sim.compiled`) every
piece of per-round state — the clock, the availability epoch window, the
latency and scenario streams, the unified policy parameters, the in-flight
buffer — is a carry pytree. This module stacks K such carries along a
leading trial axis and runs the whole sweep as
``jit(scan(vmap(sim_body)))``: one program advances K policies × seeds by
a chunk of simulated rounds, at N=10⁵⁺ devices.

Because the policy algebra is *parametric* (`sim.policies.policy_params`),
trials may mix DIFFERENT policies (WaitForAll next to BufferedKofN) in one
program — the per-lane parameter pytree selects each lane's behaviour.
Scenario processes and latency models must each share a class across
trials (one pure sample function per program), but their parameters are
per-lane state and may differ freely. Per lane the trajectory is the one
`SimScanDriver` (and therefore the heap engine) produces for that
(seed, policy, scenario, latency) — parity-tested in
tests/test_sim_compiled.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scan_engine import (_eval_rounds, _stack, chunk_bounds,
                                    run_pipelined_chunks)
from repro.fleet.executor import FleetHistory
from repro.sim.compiled import make_sim_scan_body
from repro.sim.engine import SimConfig
from repro.sim.policies import init_policy_state, policy_params


@dataclass(frozen=True)
class SimTrial:
    """One lane of a simulated fleet: the trial's model-init/round `seed`,
    its server `policy`, its availability `scenario` (process or Scenario),
    and its `latency` model; `label` names it in the history."""

    seed: int
    policy: object
    scenario: object
    latency: object
    label: str | None = None


def _check_homogeneous(objs: Sequence, what: str) -> None:
    """All trials must share one class for `what` (one pure fn per program)."""
    kinds = {type(o).__name__ for o in objs}
    if len(kinds) > 1:
        raise ValueError(
            f"all trials in one simulated fleet must share a {what} class "
            f"(one pure sample function per vmapped program); got "
            f"{sorted(kinds)} — split the sweep")


def run_sim_fleet(*, model, algo, batcher, schedule: Callable, n_rounds: int,
                  trials: Sequence[SimTrial],
                  config: SimConfig = SimConfig(),
                  eta_local: Callable | float | None = None,
                  weight_decay: float = 0.0, scan_chunk: int = 64,
                  eval_fn: Callable | None = None, eval_every: int = 10,
                  batch_fn: Callable | None = None,
                  verbose: bool = False) -> tuple[Any, FleetHistory]:
    """Run K simulated wall-clock trials as one scan(vmap) program.

    Args:
      model, algo, batcher, schedule: shared problem, exactly as
        `core.runner.run_fl` takes them (`eta_local` overrides the client
        rate, `weight_decay` applies to the local steps); the algorithm
        must be dense (cohort algorithms assemble batches on the host).
      n_rounds: simulated server rounds per trial.
      trials: `SimTrial` lanes — seed × policy × scenario × latency.
        Policies may differ per lane (the unified algebra is parametric);
        scenario processes and latency models must share a class.
      config: shared `SimConfig` (epoch length, server overhead, lookahead
        window — static shapes, so it is per-sweep, not per-lane).
      scan_chunk: rounds per compiled chunk (boundaries snap to evals).
      eval_fn: consumes stacked (K, ...) params -> ((K,) losses, (K,)
        accs) — `fleet.make_fleet_eval`. Runs every `eval_every` rounds,
        stamped per lane at that round's close + server overhead.
      batch_fn: optional pure ``(t) -> batch`` drawing the round batch
        IN-program (`data.pipeline.JitProceduralBatcher.batch_fn`) — at
        N=10⁵⁺ this keeps the host from assembling (L, N, ...) batch
        stacks; without it batches are host-fed per chunk like every other
        scan driver.
      verbose: print per-eval progress lines.

    Returns:
      (stacked (K, ...) params, `FleetHistory`) with per-lane
      sim_seconds/eval_seconds populated — `hist.trial(k)` gives lane k's
      plain `FLHistory` for time-to-accuracy curves.
    """
    from repro.scenarios.base import as_process
    k_trials = len(trials)
    assert k_trials > 0, "need at least one SimTrial"
    if getattr(algo, "cohort_based", False):
        raise NotImplementedError(
            "cohort-based algorithms assemble compact batches on the host; "
            "the simulated fleet needs a dense algorithm")
    n = batcher.n_clients
    procs = [as_process(tr.scenario) for tr in trials]
    lats = [tr.latency for tr in trials]
    _check_homogeneous(procs, "scenario process")
    _check_homogeneous(lats, "latency model")
    for p in procs:
        assert p.n == n, (p.n, n)
    for lt in lats:
        assert lt.n == n, (lt.n, n)

    body = make_sim_scan_body(model, algo, batcher.k_steps, weight_decay,
                              procs[0].sample_fn(), lats[0].sample_fn(),
                              config, batch_fn=batch_fn)
    xs_axes = {"t": None, "eta_loc": None, "eta_srv": None}
    if batch_fn is None:
        xs_axes["batch"] = None
    vbody = jax.vmap(body, in_axes=(0, xs_axes))
    chunk_fn = jax.jit(lambda carry, xs: jax.lax.scan(vbody, carry, xs),
                       donate_argnums=(0,))

    stack = lambda leaves: jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
    rngs = jnp.stack([jax.random.PRNGKey(int(tr.seed)) for tr in trials])
    params = jax.vmap(model.init)(rngs)
    w = config.max_lookahead_epochs
    carry = {
        "state": jax.vmap(lambda p: algo.init_state(p, n))(params),
        "params": params, "rng": rngs,
        "now": jnp.zeros(k_trials, jnp.float32),
        "e_next": jnp.zeros(k_trials, jnp.int32),
        "win": jnp.zeros((k_trials, w + 1, n), bool),
        "scen_state": stack([p.init_state() for p in procs]),
        "scen_key": jnp.stack([p.key for p in procs]),
        "lat_state": stack([lt.init_state() for lt in lats]),
        "lat_key": jnp.stack([lt.key for lt in lats]),
        "pp": stack([policy_params(tr.policy, n) for tr in trials]),
        "pstate": stack([init_policy_state(n) for _ in trials]),
        "tau": jnp.zeros((k_trials, n), jnp.int32),
        "tau_max": jnp.zeros((k_trials, n), jnp.int32),
    }

    hist = FleetHistory(k_trials, labels=[
        tr.label or f"seed{tr.seed}:{getattr(tr.policy, 'name', 'policy')}"
        for tr in trials])
    evals = _eval_rounds(n_rounds, eval_every, eval_fn is not None)
    overhead = np.float32(config.server_overhead_s)
    last_close = {"v": None}       # (K,) close times of the latest round

    def build_xs(t0, t1):
        xs = {"t": np.arange(t0, t1, dtype=np.int32),
              "eta_loc": np.asarray([
                  float(schedule(t + 1)) if eta_local is None
                  else (float(eta_local(t + 1)) if callable(eta_local)
                        else float(eta_local))
                  for t in range(t0, t1)], np.float32),
              "eta_srv": np.asarray([float(schedule(t + 1))
                                     for t in range(t0, t1)], np.float32)}
        if batch_fn is None:
            xs["batch"] = _stack([batcher.sample_round(t)
                                  for t in range(t0, t1)])
        return xs

    def writeback(c):
        carry_ref["c"] = c

    carry_ref = {"c": carry}

    def flush(t0, t1, ys, _carry):
        ys = {k: np.asarray(v) for k, v in ys.items()}
        for j, t in enumerate(range(t0, t1)):
            hist.record_round(
                t, {"loss": ys["loss"][j], "n_active": ys["n_active"][j]},
                sim_time=ys["t_close"][j])
        last_close["v"] = ys["t_close"][-1]

    def on_sync(t):
        sim_t = (last_close["v"].astype(np.float32) + overhead) \
            .astype(np.float64)
        el, ea = eval_fn(carry_ref["c"]["params"])
        hist.record_eval(t, el, ea, sim_time=sim_t)
        if verbose:
            print(f"  round {t:5d} sim_t={sim_t.mean():10.2f}s "
                  f"loss={np.asarray(el).mean():.4f} "
                  f"acc={np.asarray(ea).mean():.4f}")

    t0 = time.time()
    final = run_pipelined_chunks(
        carry, chunk_bounds(n_rounds, scan_chunk, evals),
        chunk_fn=chunk_fn, build_xs=build_xs, writeback=writeback,
        flush=flush, sync_rounds=evals, on_sync=on_sync)
    hist.wall_time = time.time() - t0
    return final["params"], hist
