import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Profiling helper for the §Perf loop: lowers one (arch x shape), prints the
# top computations by bytes/flops (loop-expanded), the largest buffer shapes,
# and the collective mix — the "profile" the hypothesis loop reads.

import argparse
import collections
import re

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.specs import plan
from repro.roofline import analysis as A

_DT = {"bf16": 2, "f32": 4, "s32": 4, "pred": 1, "u32": 4, "s8": 1, "f16": 2}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--memory-dtype", default=None)
    ap.add_argument("--sequential-clients", default=None,
                    choices=["true", "false"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    overrides = {}
    if args.memory_dtype:
        overrides["memory_dtype"] = args.memory_dtype
    if args.sequential_clients:
        overrides["sequential_clients"] = args.sequential_clients == "true"
    if args.capacity_factor:
        overrides["moe_capacity_factor"] = args.capacity_factor

    mesh = make_production_mesh()
    p = plan(args.arch, args.shape, mesh, **overrides)
    jitted = jax.jit(p.fn, in_shardings=p.in_shardings,
                     out_shardings=p.out_shardings,
                     donate_argnums=p.donate_argnums)
    compiled = jitted.lower(*p.args).compile()
    text = compiled.as_text()
    comps = A.parse_hlo(text)
    symtab = {op.name: op.type_str for c in comps.values() for op in c.ops}
    memo: dict = {}
    f, b, cv, coll = A._analyze_computation(comps["__entry__"], symtab,
                                            comps, memo)
    ma = compiled.memory_analysis()
    print(f"== {args.arch} x {args.shape} {overrides or ''}")
    print(f"flops/chip={f / 1e12:.2f}TF bytes/chip={b / 1e12:.3f}TB "
          f"conv_bytes(cpu-only)={cv / 1e12:.3f}TB "
          f"coll/chip={sum(coll.values()) / 1e9:.2f}GB "
          f"temp={ma.temp_size_in_bytes / 1e9:.1f}GB "
          f"args={ma.argument_size_in_bytes / 1e9:.1f}GB")
    print("collectives:", {k: f"{v / 1e9:.2f}GB" for k, v in coll.items()})

    print("\n-- top computations (bytes per single execution) --")
    rows = sorted(((v[1], v[0], k) for k, v in memo.items()), reverse=True)
    for by, fl, name in rows[:args.top]:
        print(f"{by / 1e9:10.2f} GB {fl / 1e9:12.1f} GF  {name[:70]}")

    print("\n-- largest buffer shapes --")
    sizes: collections.Counter = collections.Counter()
    for m in re.finditer(r"= (\w+)\[([\d,]+)\]", text):
        dt, dims = m.group(1), m.group(2)
        bb = _DT.get(dt)
        if not bb:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        key = f"{dt}[{dims}]"
        sizes[key] = max(sizes[key], n * bb)
    for shape, bb in sorted(sizes.items(), key=lambda kv: -kv[1])[:args.top]:
        print(f"{bb / 1e9:10.2f} GB  x{text.count(shape):5d}  {shape}")


if __name__ == "__main__":
    main()
