"""Loop-aware HLO analysis -> three-term roofline (EXPERIMENTS.md §Roofline).

`compiled.cost_analysis()` counts each `while` body ONCE, so an 80-layer
`lax.scan` model would report 1-layer costs. This analyzer parses the
optimized HLO text (`compiled.as_text()`), reconstructs the computation call
graph, extracts each while loop's trip count from its condition computation,
and multiplies body costs through — giving loop-exact:

  * matmul FLOPs (from `dot` ops: 2 * prod(result dims) * prod(contracting)),
  * HBM traffic estimate (sum of result + operand bytes over materialized ops
    — each buffer written once and read by its consumers),
  * collective bytes by type, using *operand* sizes per the brief
    (all-gather operand = result/groups; reduce-scatter operand = result*groups).

Everything is per-device (the HLO is the SPMD per-chip program).

Hardware constants (TPU v5e, from the brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # B/s per chip
    "ici_bw": 50e9,         # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = <type> opcode(...)` where <type> is `f32[8,32]{1,0}` or a tuple
# `(s32[], bf16[8,32]{1,0}, ...)`; layouts `{...}` optional.
_TYPE = r"(?:\([^)]*\)|[a-z0-9_]+\[[\d,]*\](?:\{[^}]*\})?)"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(" + _TYPE + r")\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str] | None:
    m = _SHAPE_RE.search(type_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return dims, m.group(1)


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str   # text after '(' — operands + attributes


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    # resolved lazily:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            cur.ops.append(Op(mo.group(1), mo.group(2).strip(), mo.group(3),
                              mo.group(4)))
    comps["__entry__"] = comps.get(entry_name, Computation("__missing__"))
    return comps


def _group_size(rest: str, default: int = 1) -> int:
    # iota form: replica_groups=[8,32]<=[256]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2,3},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _operand_names(rest: str) -> list[str]:
    # operands are before the first '),' attribute boundary; conservative:
    depth, out, cur = 0, [], ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        out.append(cur)
    names = []
    for frag in out:
        m = re.search(r"%([\w\.\-]+)", frag)
        if m:
            names.append(m.group(1))
    return names


# Materialization points whose result+operand bytes count as HBM traffic.
# Top-level elementwise ops (add/select/convert/...) are nearly always inside
# fusions after optimization; counting stray ones would double-charge chains.
_HEAVY = {"fusion", "dot", "copy", "custom-call", "convolution",
          "reduce", "scatter", "gather", "sort",
          "dynamic-update-slice", "dynamic-slice", "concatenate",
          "pad", "slice"}
_HEAVY |= set(COLLECTIVES)




_CONV_ONLY = {"parameter", "convert", "bitcast", "copy", "reshape",
              "transpose", "broadcast", "constant"}


def _fusion_bytes(op: Op, res_bytes: int, type_of, comps) -> tuple:
    """Traffic of a fusion op, looking *inside* the fused computation.

    Two CPU-HLO patterns would otherwise overcount by ~n_layers x:
      * an operand that the fused computation dynamic-slices (layer scans
        slicing their stacked params) — charge the slice, not the stack;
      * a fused root dynamic-update-slice (cache token writes) — charge the
        updated region, not the whole (aliased) buffer.
    """
    sub_m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
    subc = comps.get(sub_m.group(1)) if sub_m else None
    onames = _operand_names(op.rest)

    # pure dtype-conversion fusions are a CPU-backend artifact (XLA:CPU
    # upcasts bf16 dot operands to f32); native-bf16 TPUs never materialize
    # them — classify separately so the roofline memory term can exclude them
    is_conversion = bool(subc and subc.ops and
                         all(o.opcode in _CONV_ONLY for o in subc.ops))

    sliced_params: dict[str, int] = {}   # param name -> slice result bytes
    param_names: dict[int, str] = {}
    root_dus_update: int | None = None
    if subc is not None and subc.ops:
        for o in subc.ops:
            if o.opcode == "parameter":
                m = re.match(r"(\d+)\)", o.rest)
                if m:
                    param_names[int(m.group(1))] = o.name
        sub_tab = {o.name: o.type_str for o in subc.ops}
        for o in subc.ops:
            if o.opcode == "dynamic-slice":
                srcs = _operand_names(o.rest)
                if srcs:
                    sliced_params[srcs[0]] = _shape_bytes(o.type_str)
        root = subc.ops[-1]
        if root.opcode == "dynamic-update-slice":
            upd = _operand_names(root.rest)
            if len(upd) > 1 and upd[1] in sub_tab:
                root_dus_update = _shape_bytes(sub_tab[upd[1]])

    total = (2 * root_dus_update) if root_dus_update is not None else res_bytes
    for i, nm in enumerate(onames[:6]):
        t = type_of(nm)
        if not t:
            continue
        ob = _shape_bytes(t)
        pname = param_names.get(i)
        if pname is not None and pname in sliced_params:
            ob = 2 * sliced_params[pname]
        elif root_dus_update is not None and i == 0 and ob >= res_bytes:
            ob = 0  # the aliased base buffer of an in-place cache update
        else:
            ob = min(ob, 16 * max(res_bytes, 1))
        total += ob
    return float(total), is_conversion


def _analyze_computation(comp: Computation, symtab: dict[str, str],
                         comps: dict[str, Computation],
                         memo: dict[str, tuple]) -> tuple:
    """Returns (flops, bytes, conv_bytes, coll_by_type) with loops expanded.

    conv_bytes = traffic of pure dtype-conversion fusions (CPU-only artifact).
    """
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = (0.0, 0.0, 0.0, {})  # cycle guard
    flops = 0.0
    nbytes = 0.0
    conv_bytes = 0.0
    coll: dict[str, float] = {}

    local_tab = {op.name: op.type_str for op in comp.ops}

    def type_of(name: str) -> str | None:
        return local_tab.get(name) or symtab.get(name)

    for op in comp.ops:
        res_bytes = _shape_bytes(op.type_str)
        if op.opcode == "while":
            body_m = re.search(r"body=%?([\w\.\-]+)", op.rest)
            cond_m = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            trip_m = _TRIP_RE.search(op.rest)
            trip = 1
            if trip_m:
                trip = int(trip_m.group(1))
            elif cond_m and cond_m.group(1) in comps:
                consts = [int(c) for c in re.findall(
                    r"constant\((\d+)\)",
                    "\n".join(f"{o.opcode}({o.rest}" for o in
                              comps[cond_m.group(1)].ops))]
                if consts:
                    trip = max(consts)
            if body_m and body_m.group(1) in comps:
                bf, bb, bcv, bc = _analyze_computation(
                    comps[body_m.group(1)], symtab, comps, memo)
                flops += trip * bf
                nbytes += trip * bb
                conv_bytes += trip * bcv
                for k, v in bc.items():
                    coll[k] = coll.get(k, 0.0) + trip * v
            continue
        if op.opcode in ("call", "conditional"):
            for sub in re.findall(r"to_apply=%?([\w\.\-]+)", op.rest) + \
                    re.findall(r"branch_computations=\{%?([\w\.\-]+)", op.rest):
                if sub in comps:
                    sf, sb, scv, sc = _analyze_computation(comps[sub], symtab,
                                                           comps, memo)
                    flops += sf
                    nbytes += sb
                    conv_bytes += scv
                    for k, v in sc.items():
                        coll[k] = coll.get(k, 0.0) + v
            continue

        if op.opcode == "dot":
            dims = _shape_dims(op.type_str)
            ops_names = _operand_names(op.rest)
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
            if dims and ops_names and cdims is not None:
                lhs_t = type_of(ops_names[0])
                lhs = _shape_dims(lhs_t) if lhs_t else None
                k = 1
                if lhs:
                    for ci in cdims.group(1).split(","):
                        if ci:
                            k *= lhs[0][int(ci)]
                flops += 2.0 * float(np.prod(dims[0], dtype=np.float64)) * k
        elif op.opcode == "fusion":
            # count any dots hidden inside the fused computation
            sub = re.search(r"calls=%?([\w\.\-]+)", op.rest)
            if sub and sub.group(1) in comps:
                sf = _analyze_computation(comps[sub.group(1)], symtab,
                                          comps, memo)[0]
                flops += sf

        if op.opcode in COLLECTIVES or op.opcode.rstrip("-start") in COLLECTIVES:
            base = op.opcode.replace("-start", "")
            g = _group_size(op.rest)
            if base == "all-gather":
                operand_bytes = res_bytes / max(g, 1)
            elif base == "reduce-scatter":
                operand_bytes = res_bytes * max(g, 1)
            else:
                operand_bytes = res_bytes
            coll[base] = coll.get(base, 0.0) + operand_bytes

        if op.opcode in _HEAVY:
            if op.opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region, not the whole operand (layer
                # scans slice their stacked params every iteration — charging
                # the full stack would overcount ~n_layers x)
                nbytes += 2 * res_bytes
            elif op.opcode == "dynamic-update-slice":
                # reads + writes the updated region (operand 1); base aliased
                onames = _operand_names(op.rest)
                upd = type_of(onames[1]) if len(onames) > 1 else None
                nbytes += 2 * (_shape_bytes(upd) if upd else res_bytes)
            elif op.opcode == "fusion":
                fb, is_conv = _fusion_bytes(op, res_bytes, type_of, comps)
                if is_conv:
                    conv_bytes += fb
                else:
                    nbytes += fb
            else:
                onames = _operand_names(op.rest)
                op_bytes = 0
                for nm in onames[:4]:
                    t = type_of(nm)
                    if t:
                        op_bytes += _shape_bytes(t)
                nbytes += res_bytes + op_bytes

    memo[comp.name] = (flops, nbytes, conv_bytes, coll)
    return memo[comp.name]


def analyze_compiled(compiled) -> dict:
    """Full analysis of a jax compiled object."""
    text = compiled.as_text()
    comps = parse_hlo(text)
    symtab: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            symtab[op.name] = op.type_str
    memo: dict[str, tuple] = {}
    # exclude fused computations from direct traversal (reached via their op)
    flops, nbytes, conv_bytes, coll = _analyze_computation(
        comps["__entry__"], symtab, comps, memo)

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older JAX: one dict per computation
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    out = {
        "hlo_flops_parsed": flops,
        "hlo_bytes_parsed": nbytes,
        "conversion_bytes_cpu_artifact": conv_bytes,
        "collective_bytes": coll,
        "collective_bytes_total": float(sum(coll.values())),
        "cost_analysis_flops": float(ca.get("flops", 0.0)),
        "cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
    }
    return out


def roofline_terms(analysis: dict, hw: dict = HW) -> dict:
    """Seconds per step for each roofline term (per chip — HLO is per-chip)."""
    # parsed values are loop-exact; cost_analysis counts while bodies once.
    # Fall back to cost_analysis only if parsing found (nearly) nothing.
    flops = analysis["hlo_flops_parsed"]
    if flops < 0.01 * analysis["cost_analysis_flops"]:
        flops = analysis["cost_analysis_flops"]
    nbytes = analysis["hlo_bytes_parsed"]
    if nbytes < 0.01 * analysis["cost_analysis_bytes"]:
        nbytes = analysis["cost_analysis_bytes"]
    cbytes = analysis["collective_bytes_total"]
    t_compute = flops / hw["peak_flops"]
    t_memory = nbytes / hw["hbm_bw"]
    t_coll = cbytes / hw["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {**terms, "bottleneck": dom.replace("_s", ""),
            "step_time_lower_bound_s": max(terms.values())}


def model_flops(cfg, params_total: int, params_active: int, shape,
                kind: str) -> float:
    """Useful model FLOPs (6·N·D train / 2·N·D inference), MoE-active-aware."""
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * params_active * tokens
    if kind in ("prefill", "encode"):
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * params_active * tokens
    # decode: one token per sequence
    return 2.0 * params_active * shape.global_batch
