from repro.roofline.analysis import analyze_compiled, roofline_terms, HW  # noqa: F401
