from repro.sharding.rules import (param_specs, client_state_specs,  # noqa: F401
                                  cache_specs, batch_specs, DATA, MODEL)
