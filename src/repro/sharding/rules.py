"""Partition rules: parameter / client-state / cache / batch PartitionSpecs.

Axis conventions (launch/mesh.py):
    single pod : ("data", "model")              16 x 16
    multi-pod  : ("pod", "data", "model")       2 x 16 x 16

* `model` carries tensor parallelism: attention heads, d_ff, experts, d_inner.
* `data` carries client parallelism (MIFA's client axis) and, for `fsdp`
  configs, a second parameter shard dim (2-D FSDP x TP).
* `pod` extends the client/data axis across pods (pure data parallel across
  DCN; parameters replicated across pods so per-layer all-gathers stay on ICI).

Rules are matched on the *trailing* dims of each leaf by parameter name, so
layer-stacked leaves (leading segment axis) reuse the same table.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig

DATA = "data"
MODEL = "model"


def data_axes(mesh) -> tuple:
    """Client/data axes — ('pod','data') on the multi-pod mesh."""
    return ("pod", DATA) if "pod" in mesh.axis_names else (DATA,)


# --------------------------------------------------------------------------- #
# trailing-dim rule table: name -> spec for the *trailing* dims
# --------------------------------------------------------------------------- #

def _trailing_spec(name: str, parent: str, ndim_trailing: int,
                   fsdp: bool) -> tuple:
    f = DATA if fsdp else None
    table: dict[str, tuple] = {
        # embeddings / head: d_model on `model` => local gather at lookup;
        # lm_head vocab on `model` => vocab-sharded logits (psum'd logsumexp)
        "embed": (f, MODEL),
        "lm_head": (f, MODEL),
        "frontend_proj": (None, MODEL),
        # attention (GQA), FLAT layout: (d, H*hd) / (H*hd, d) / biases (H*hd,)
        "wq": (f, MODEL),
        "wk": (f, MODEL),
        "wv": (f, MODEL),
        "wo": (MODEL, f),
        "bq": (MODEL,),
        "bk": (MODEL,),
        "bv": (MODEL,),
        # MLA (flat)
        "w_dkv": (f, None),
        "w_kpe": (f, None),
        "w_uk": (None, MODEL),
        "w_uv": (None, MODEL),
        # ssm (mamba2)
        "in_proj": (f, MODEL),
        "out_proj": (MODEL, f),
        "conv_w": (None, MODEL),
        "conv_b": (MODEL,),
        "A_log": (MODEL,),
        "D": (MODEL,),
        "dt_bias": (MODEL,),
        "norm_scale": (MODEL,),
        # router
        "router": (None, None),
        # norms
        "scale": (None,),
        # tabular models
        "w": (None, None) if ndim_trailing == 2 else (None,),
        "b": (None,),
    }
    if name in ("w1", "w3"):
        if ndim_trailing == 3:            # moe experts (E, d, f)
            return (MODEL, None, None)
        return (f, MODEL)                 # dense mlp (d, f)
    if name == "w2":
        if ndim_trailing == 3:            # (E, f, d)
            return (MODEL, None, None)
        return (MODEL, f)                 # (f, d)
    if name in table:
        spec = table[name]
        if len(spec) == ndim_trailing:
            return spec
        # tolerate rank differences (e.g. tabular "w" 2d vs bias 1d)
        if len(spec) > ndim_trailing:
            return spec[-ndim_trailing:]
        return (None,) * (ndim_trailing - len(spec)) + spec
    return (None,) * ndim_trailing


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize(spec: tuple, shape: tuple, mesh) -> tuple:
    """Drop sharding on dims the mesh axis size does not divide — and on
    entries naming an axis this mesh does not have (a multi-pod spec reused
    on a single-pod mesh replicates those dims instead of raising).

    Production note: frameworks usually *pad* indivisible dims (e.g. granite's
    vocab 49155 -> 49168) instead; we keep exact assigned shapes and replicate
    those dims, recording the memory cost in §Roofline.
    """
    names = set(mesh.axis_names)
    out = []
    for dim, entry in zip(shape, spec):
        axes = (tuple(entry) if isinstance(entry, (tuple, list))
                else (entry,)) if entry is not None else ()
        if any(a not in names for a in axes):
            out.append(None)
            continue
        n = _axis_size(mesh, entry)
        out.append(entry if (n > 1 and dim % n == 0) or n == 1 else None)
    return tuple(out)


def _path_names(path) -> list[str]:
    names = []
    for part in path:
        if hasattr(part, "key"):
            names.append(str(part.key))
        elif hasattr(part, "idx"):
            names.append(str(part.idx))
    return names


def _base_ndim(name: str, parent: str) -> int:
    """Rank of the *unstacked* parameter (trailing dims the table describes)."""
    ranks = {
        "embed": 2, "lm_head": 2, "frontend_proj": 2,
        "wq": 2, "wk": 2, "wv": 2, "wo": 2, "bq": 1, "bk": 1, "bv": 1,
        "w_dkv": 2, "w_kpe": 2, "w_uk": 2, "w_uv": 2,
        "in_proj": 2, "out_proj": 2, "conv_w": 2, "conv_b": 1,
        "A_log": 1, "D": 1, "dt_bias": 1, "norm_scale": 1,
        "router": 2, "scale": 1,
    }
    if name in ("w1", "w2", "w3"):
        return 3 if parent == "moe" else 2
    if name == "w":
        return 2
    if name == "b":
        return 1
    return ranks.get(name, 0)


def _spec_for(path, leaf, fsdp: bool, extra_leading: int = 0) -> P:
    names = _path_names(path)
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    base = _base_ndim(name, parent)
    nd = leaf.ndim - extra_leading
    trailing = min(base, nd) if base else nd
    spec = _trailing_spec(name, parent, trailing, fsdp)
    lead = (None,) * (leaf.ndim - len(spec) - extra_leading)
    return spec, lead


def param_specs(params: Any, cfg: ArchConfig, mesh) -> Any:
    """PartitionSpec pytree matching `params`."""
    def fn(path, leaf):
        spec, lead = _spec_for(path, leaf, cfg.fsdp)
        full = lead + tuple(spec)
        return P(*sanitize(full, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(fn, params)


def client_state_specs(params: Any, cfg: ArchConfig, mesh,
                       sequential_clients: bool = False,
                       n_clients: int = 0) -> Any:
    """Specs for MIFA's update array: leaves (N_clients, *param_shape).

    vmap mode: client axis -> data (and pod); param dims use model-only rules
    (the data axis is taken by clients, so fsdp is dropped).
    sequential (scan) mode: clients unsharded; param dims keep full 2-D
    (data x model) sharding — per-client grads are computed on the whole mesh.
    """
    dax = data_axes(mesh)

    def fn(path, leaf):
        if sequential_clients:
            # G always keeps full 2-D (data x model) sharding in sequential
            # mode — independent of whether the *params* use fsdp — since
            # per-client updates are computed on the whole mesh.
            spec, lead = _spec_for(path, leaf, True, extra_leading=1)
            full = (None,) + lead + tuple(spec)
        else:
            spec, lead = _spec_for(path, leaf, False, extra_leading=1)
            full = (dax,) + lead + tuple(spec)
        # G leaves are (N_clients, *param_shape); sanitize with that shape
        return P(*sanitize(full, (n_clients,) + tuple(leaf.shape), mesh))

    return jax.tree_util.tree_map_with_path(fn, params)


def data_axis_size(mesh) -> int:
    """Total extent of the client/data axes — the shard count for MemoryBank
    rows and the MIFA update array."""
    d = 1
    for a in data_axes(mesh):
        d *= mesh.shape[a]
    return d


def padded_bank_rows(n_clients: int, mesh) -> int:
    """Row count for a sharded MemoryBank: N real rows + the dummy pad row,
    rounded up so the client axis divides the mesh's data extent (otherwise
    `sanitize` would silently replicate the whole bank)."""
    d = data_axis_size(mesh)
    return -((n_clients + 1) // -d) * d


def bank_row_specs(params: Any, cfg: ArchConfig, mesh, n_rows: int) -> Any:
    """Specs for MemoryBank rows: leaves (n_rows, *param_shape), the client
    axis sharded over data (and pod) — the same layout as the dense MIFA
    update array, so the cohort gather/scatter is a local row exchange."""
    return client_state_specs(params, cfg, mesh, n_clients=n_rows)


def fleet_trial_specs(stacked_params: Any, cfg: ArchConfig, mesh) -> Any:
    """Specs for fleet-stacked parameters: leaves (K, *param_shape).

    Independent trials are pure data parallelism, so the trial axis shards
    over the mesh's data (and pod) axes; the param dims reuse the model-only
    trailing rules (the data axis is taken by trials, so fsdp is dropped) —
    the same convention as the vmap-mode client axis in
    `client_state_specs`. Indivisible trial counts fall back to replication
    via `sanitize`, so K should be a multiple of `data_axis_size(mesh)`.
    """
    dax = data_axes(mesh)

    def fn(path, leaf):
        spec, lead = _spec_for(path, leaf, False, extra_leading=1)
        full = (dax,) + lead + tuple(spec)
        return P(*sanitize(full, tuple(leaf.shape), mesh))

    return jax.tree_util.tree_map_with_path(fn, stacked_params)


def fleet_axis_specs(stacked_state: Any, mesh) -> Any:
    """Generic trial-axis specs for opaque fleet state (algorithm state,
    memory-bank rows, RNG keys): axis 0 over data/pod, the rest replicated.
    Use `fleet_trial_specs` for parameters, where trailing dims can keep
    their model sharding. Scalar leaves (per-fleet counters) replicate."""
    dax = data_axes(mesh)

    def fn(leaf):
        if leaf.ndim == 0:
            return P()
        full = (dax,) + (None,) * (leaf.ndim - 1)
        return P(*sanitize(full, tuple(leaf.shape), mesh))

    return jax.tree.map(fn, stacked_state)


def scan_carry_specs(carry: dict, mesh, *, cfg: ArchConfig | None = None,
                     n_clients: int = 0, row_counts: tuple = ()) -> dict:
    """PartitionSpecs for the whole-run scan carry (`core.scan_engine`).

    The carry is ``{"state", "params", "rng"}`` plus the scenario keys
    ``{"scen_state", "scen_key"}`` and the τ accumulators ``{"tau",
    "tau_max"}``. Placement:

      * ``params`` — `param_specs` when `cfg` is given (model/fsdp rules);
        replicated otherwise (the tiny paper models replicate anyway).
      * client-indexed state — any leaf whose leading dim is `n_clients`,
        `n_clients + 1` (dense bank rows incl. the dummy row) or one of
        `row_counts` (padded bank rows) shards axis 0 over the mesh's
        data (and pod) axes: MIFA's update array, bank rows, per-client
        quantisation scales, scenario chain state, and the τ vectors.
      * everything else (RNG keys, scalars, running sums Ḡ/g_sum) —
        replicated. g_sum stays replicated deliberately: it is the result
        of a client-axis reduction, so XLA all-reduces partial sums into
        every shard.

    Indivisible client axes fall back to replication via `sanitize` —
    sharded runs want N a multiple of `data_axis_size(mesh)` (banks pad
    via `padded_bank_rows`).
    """
    dax = data_axes(mesh)
    rows = {n_clients, n_clients + 1, *row_counts} - {0, 1}

    def client_leaf(leaf):
        if leaf.ndim and leaf.shape[0] in rows:
            full = (dax,) + (None,) * (leaf.ndim - 1)
            return P(*sanitize(full, tuple(leaf.shape), mesh))
        return P()

    def replicated(tree):
        return jax.tree.map(lambda _: P(), tree)

    out = {}
    for key, sub in carry.items():
        if key == "params":
            out[key] = (param_specs(sub, cfg, mesh) if cfg is not None
                        else replicated(sub))
        elif key in ("rng", "scen_key"):
            out[key] = replicated(sub)
        else:   # state / scen_state / tau / tau_max
            out[key] = jax.tree.map(client_leaf, sub)
    return out


def fleet_carry_specs(carry: dict, mesh, *,
                      cfg: ArchConfig | None = None) -> dict:
    """PartitionSpecs for the fleet scan carry: every leaf carries a
    leading (K,) trial axis, so the trial axis shards over data/pod
    (`fleet_axis_specs`); stacked params keep their model-dim rules via
    `fleet_trial_specs` when `cfg` is given."""
    out = {}
    for key, sub in carry.items():
        if key == "params" and cfg is not None:
            out[key] = fleet_trial_specs(sub, cfg, mesh)
        else:
            out[key] = fleet_axis_specs(sub, mesh)
    return out


def cache_specs(cache: Any, cfg: ArchConfig, mesh, batch_size: int) -> Any:
    """KV/SSM cache specs.

    Stacked entries: (n_layers, B, C, KV, hd) etc. Batch shards over data when
    divisible; for the single-request long-context shape (B=1) the *sequence*
    dim of attention caches shards over data instead (flash-decode style).
    """
    dax = data_axes(mesh)
    n_dev_data = 1
    for a in dax:
        n_dev_data *= mesh.shape[a]
    batch_sharded = batch_size % n_dev_data == 0 and batch_size >= n_dev_data
    bspec = dax if batch_sharded else None
    sspec = None if batch_sharded else dax

    model_size = mesh.shape[MODEL]

    def fn(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = leaf.ndim == {"k": 5, "v": 5, "c": 4, "pe": 4,
                                "state": 5, "conv": 4}.get(name, -1)
        lead = (None,) if stacked else ()
        if name in ("k", "v"):      # (B, C, KV, hd)
            kv = leaf.shape[-2]
            if kv % model_size == 0:
                full = lead + (bspec, sspec, MODEL, None)
            elif batch_sharded:
                # too few kv heads for the model axis: seq-shard the cache
                # over `model` instead (flash-decode style partial softmax)
                full = lead + (bspec, MODEL, None, None)
            else:
                dd = tuple(dax) + (MODEL,)
                full = lead + (bspec, dd, None, None)
            return P(*sanitize(full, leaf.shape, mesh))
        if name in ("c", "pe"):     # (B, S, r) — MLA compressed cache
            full = lead + (bspec, sspec if sspec else MODEL, None)
            return P(*sanitize(full, leaf.shape, mesh))
        if name == "state":         # (B, H, P, N)
            full = lead + (bspec, MODEL, None, None)
            return P(*sanitize(full, leaf.shape, mesh))
        if name == "conv":          # (B, W-1, conv_ch)
            full = lead + (bspec, None, MODEL)
            return P(*sanitize(full, leaf.shape, mesh))
        return P()

    return jax.tree_util.tree_map_with_path(fn, cache)


def batch_specs(batch: Any, mesh, *, client_axis: bool = True,
                sequential_clients: bool = False) -> Any:
    """Training batches (N, K, mb, ...) or serving batches (B, ...).

    vmap mode shards the leading client axis over data; sequential mode shards
    the per-client minibatch dim (axis 2) instead.
    """
    dax = data_axes(mesh)

    def fn(leaf):
        if client_axis and sequential_clients:
            # shard the per-client minibatch dim over `data` only (pods hold
            # the fsdp replica axis in sequential mode)
            spec = [None, None, DATA] + [None] * (leaf.ndim - 3)
        else:
            spec = [dax] + [None] * (leaf.ndim - 1)
        return P(*sanitize(tuple(spec), leaf.shape, mesh))

    return jax.tree.map(fn, batch)
