"""Server round policies: who is dispatched, when the round closes, whose
updates are applied.

The engine hands each policy the cohort it selected, the availability mask at
dispatch time, and the (N,) arrival-time vector (np.inf = never arrives within
the lookahead horizon), and gets back (close_time, applied_mask):

  * WaitForAll — broadcast to every device; block until ALL respond. Offline
    devices respond only after their next active availability epoch, so a
    single blackout device stalls the fleet.
  * WaitForS   — the paper's Eq. 3 protocol: sample S devices uniformly, block
    until all S respond. Because the engine applies one global update per
    round at close time, all S updates are computed at the same (frozen)
    iterate — exactly the straggler-prone baseline `FedAvgSampling`
    approximates without a clock.
  * Deadline   — broadcast (or over-select a cohort), close at a fixed
    deadline, drop late responders. Fast but biased against slow devices.
  * Impatient  — MIFA's server: close as soon as every *currently available*
    device has responded; never wait for unavailable ones (memory corrects
    the bias on the algorithm side).
  * BufferedKofN — FedBuff-style buffered-async server: close at the K-th
    arrival, keep later responders *in flight* (they land in later rounds,
    staleness-discounted), never re-dispatch an in-flight device.

Every policy also exposes a **unified parametric form** (`unified(n)` +
the module-level `unified_select` / `unified_resolve` pure functions) so
the compiled simulator (`repro.sim.compiled`) can lift ALL policies into
one jit-able ``(params, pstate, arrivals) -> (close, applied, weights)``
surface whose parameters ride the scan carry — mixed-policy fleets then
vmap as a single program. Cohort sampling is keyed by
``jax.random.fold_in(sel_key, t)`` on both the host and jit surfaces, so
the heap engine and the compiled engine select bit-identical cohorts. All
time arithmetic is float32 on both surfaces (see `repro.sim.engine`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

_INF32 = np.float32(np.inf)


def _fold_in_cohort(sel_seed: int, t: int, n: int, k: int) -> np.ndarray:
    """Host cohort mask: first k entries of the fold_in(sel_seed, t)
    permutation — the materialised twin of `unified_select`'s jit draw."""
    if k >= n:
        return np.ones(n, bool)
    key = jax.random.fold_in(jax.random.PRNGKey(sel_seed), t)
    perm = np.asarray(jax.random.permutation(key, n))
    mask = np.zeros(n, bool)
    mask[perm[:k]] = True
    return mask


def _close_at_last_finite(arrivals: np.ndarray, mask: np.ndarray, now: float,
                          idle_s: float) -> tuple[np.float32, np.ndarray]:
    """Close at the last finite arrival in `mask` (float32), or idle one
    epoch if nobody in the wait set ever returns."""
    applied = mask & np.isfinite(arrivals)
    if not applied.any():
        return np.float32(now) + np.float32(idle_s), applied
    return np.float32(arrivals[applied].max()), applied


@dataclass(frozen=True)
class WaitForAll:
    """Fully synchronous server: broadcast, then block for every responder."""

    name: str = "wait_for_all"
    sel_seed: int = 0

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Dispatch round t to all n devices: (N,) all-True cohort mask.
        (`rng` is accepted for engine compatibility but unused — selection
        is keyed, so both simulation surfaces agree.)"""
        return np.ones(n, bool)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Close when the LAST cohort arrival lands: (close_time, applied
        mask). Devices that never return (inf arrival) are dropped."""
        return _close_at_last_finite(arrivals, cohort, now, epoch_s)

    def unified(self, n: int) -> dict:
        """Parametric form: broadcast (sel_k=0), wait for all finite
        arrivals (wait_mode=1), no deadline, unbuffered."""
        return dict(sel_k=0, wait_avail_only=False, wait_mode=1, buffer_k=0,
                    deadline_s=np.inf, buffered=False, sel_seed=self.sel_seed)


@dataclass(frozen=True)
class WaitForS:
    """The paper's Eq. 3 protocol: sample S devices, block for all S."""

    s: int
    name: str = "wait_for_s"
    sel_seed: int = 0

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Sample S of n devices uniformly (paper Eq. 3): (N,) cohort mask,
        keyed by fold_in(sel_seed, t) so both surfaces pick the same S.
        (`rng` is accepted for engine compatibility but unused.)"""
        return _fold_in_cohort(self.sel_seed, t, n, self.s)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Block until every sampled device responds: (close_time, applied
        mask) at the last finite arrival — the straggler-bound baseline."""
        return _close_at_last_finite(arrivals, cohort, now, epoch_s)

    def unified(self, n: int) -> dict:
        """Parametric form: sample sel_k=s, wait for all finite arrivals
        (wait_mode=1), no deadline, unbuffered."""
        return dict(sel_k=self.s, wait_avail_only=False, wait_mode=1,
                    buffer_k=0, deadline_s=np.inf, buffered=False,
                    sel_seed=self.sel_seed)


@dataclass(frozen=True)
class Deadline:
    """Close at now + deadline_s; apply whoever arrived. cohort_size=None
    broadcasts to all devices (over-selection in the limit)."""

    deadline_s: float
    cohort_size: int | None = None
    name: str = "deadline"
    sel_seed: int = 0

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Broadcast, or over-select `cohort_size` devices: (N,) mask keyed
        by fold_in(sel_seed, t). (`rng` kept for compatibility, unused.)"""
        if self.cohort_size is None or self.cohort_size >= n:
            return np.ones(n, bool)
        return _fold_in_cohort(self.sel_seed, t, n, self.cohort_size)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Close exactly at now + deadline_s; apply whoever arrived by
        then (late responders are dropped): (close_time, applied mask)."""
        close = np.float32(now) + np.float32(self.deadline_s)
        return close, cohort & (arrivals <= close)

    def unified(self, n: int) -> dict:
        """Parametric form: cohort of sel_k (0 = broadcast), deadline-only
        close (wait_mode=0), unbuffered."""
        k = 0 if self.cohort_size is None or self.cohort_size >= n \
            else self.cohort_size
        return dict(sel_k=k, wait_avail_only=False, wait_mode=0, buffer_k=0,
                    deadline_s=self.deadline_s, buffered=False,
                    sel_seed=self.sel_seed)


@dataclass(frozen=True)
class Impatient:
    """MIFA: wait only for devices available at dispatch time."""

    name: str = "impatient"
    sel_seed: int = 0

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Dispatch to every device: (N,) all-True cohort mask. (`rng` kept
        for engine compatibility, unused.)"""
        return np.ones(n, bool)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Close after the devices available AT DISPATCH respond; never
        wait for currently-unavailable ones: (close_time, applied mask)."""
        return _close_at_last_finite(arrivals, cohort & avail_now, now,
                                     epoch_s)

    def unified(self, n: int) -> dict:
        """Parametric form: broadcast, wait set restricted to devices
        available at dispatch (wait_avail_only), wait_mode=1, unbuffered."""
        return dict(sel_k=0, wait_avail_only=True, wait_mode=1, buffer_k=0,
                    deadline_s=np.inf, buffered=False, sel_seed=self.sel_seed)


@dataclass(frozen=True)
class BufferedKofN:
    """FedBuff-style buffered-async server: close each round at the K-th
    update arrival; slower responders stay *in flight* and merge into a
    later round's buffer with a staleness discount 1/sqrt(1 + s), where s
    is the merge round minus the dispatch round. In-flight devices are not
    re-dispatched. An optional deadline_s caps how long the server blocks
    when fewer than K updates are in flight."""

    k: int
    deadline_s: float = np.inf
    name: str = "buffered"
    sel_seed: int = 0

    stateful: ClassVar[bool] = True

    def init_pstate(self, n: int) -> dict:
        """Fresh in-flight buffer: pending (N,) f32 arrival times (inf =
        nothing in flight) and pending_t (N,) dispatch rounds."""
        return {"pending": np.full(n, _INF32, np.float32),
                "pending_t": np.zeros(n, np.int64)}

    def select_pending(self, t: int, n: int, pstate: dict) -> np.ndarray:
        """Dispatch to every device with no update in flight: (N,) mask."""
        return ~np.isfinite(pstate["pending"])

    def resolve_pending(self, pstate, cohort, avail_now, arrivals, now,
                        epoch_s, t):
        """Merge this round's arrivals with the in-flight buffer and close
        at the K-th smallest arrival (capped by deadline_s; idle one epoch
        if nothing is in flight). Returns (close, applied, staleness
        weights, new pstate) — the float32 host mirror of
        `unified_resolve`'s buffered branch."""
        merged = np.where(cohort, arrivals.astype(np.float32),
                          pstate["pending"]).astype(np.float32)
        merged_t = np.where(cohort, t, pstate["pending_t"])
        finite = np.isfinite(merged)
        n_finite = int(finite.sum())
        k_eff = min(self.k, n_finite)
        idle = np.float32(now) + np.float32(epoch_s)
        if k_eff > 0:
            kth = np.sort(np.where(finite, merged, _INF32))[k_eff - 1]
        else:
            kth = idle
        close = np.minimum(np.float32(kth),
                           np.float32(now) + np.float32(self.deadline_s))
        applied = finite & (merged <= close)
        stale = (np.int64(t) - merged_t).astype(np.float32)
        weights = np.where(
            applied, np.float32(1.0) / np.sqrt(np.float32(1.0) + stale),
            np.float32(0.0)).astype(np.float32)
        pstate = {"pending": np.where(applied, _INF32,
                                      merged).astype(np.float32),
                  "pending_t": np.where(applied, 0, merged_t)}
        return close, applied, weights, pstate

    def unified(self, n: int) -> dict:
        """Parametric form: broadcast minus in-flight, K-th-arrival close
        (wait_mode=2, buffer_k=k), buffered merges with staleness."""
        return dict(sel_k=0, wait_avail_only=False, wait_mode=2,
                    buffer_k=self.k, deadline_s=self.deadline_s,
                    buffered=True, sel_seed=self.sel_seed)


# --------------------------------------------------------------------- #
# Unified jit-native surface: one pure (params, state) algebra covering
# every policy above, so the compiled simulator threads a single resolve
# through lax.scan and mixed-policy fleets vmap as one program.
# --------------------------------------------------------------------- #

def policy_params(policy, n: int) -> dict:
    """Lift `policy` into the unified parameter pytree (jnp leaves, so a
    fleet can stack heterogeneous policies along its trial axis): sel_k,
    wait_avail_only, wait_mode (0=deadline-only, 1=all-finite, 2=buffer-K),
    buffer_k, deadline_s, buffered, sel_key."""
    u = policy.unified(n)
    return {"sel_k": jnp.int32(u["sel_k"]),
            "wait_avail_only": jnp.bool_(u["wait_avail_only"]),
            "wait_mode": jnp.int32(u["wait_mode"]),
            "buffer_k": jnp.int32(u["buffer_k"]),
            "deadline_s": jnp.float32(u["deadline_s"]),
            "buffered": jnp.bool_(u["buffered"]),
            "sel_key": jax.random.PRNGKey(u["sel_seed"])}


def init_policy_state(n: int) -> dict:
    """Jit-side policy state riding the scan carry: the in-flight buffer
    (pending arrival times + dispatch rounds); inert for unbuffered
    policies, but kept shape-uniform so every policy shares one carry."""
    return {"pending": jnp.full(n, jnp.inf, jnp.float32),
            "pending_t": jnp.zeros(n, jnp.int32)}


def unified_select(t, pp: dict, pstate: dict):
    """Pure cohort draw for round t: first sel_k entries of the
    fold_in(sel_key, t) permutation (sel_k=0 broadcasts), minus in-flight
    devices when buffered. Bit-identical to the host policies' select."""
    n = pstate["pending"].shape[0]
    perm = jax.random.permutation(jax.random.fold_in(pp["sel_key"], t), n)
    pos = jnp.zeros(n, jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32))
    mask = jnp.where(pp["sel_k"] > 0, pos < pp["sel_k"], True)
    return mask & jnp.where(pp["buffered"],
                            ~jnp.isfinite(pstate["pending"]), True)


def unified_resolve(pp: dict, pstate: dict, cohort, avail_now, arrivals,
                    now, epoch_s, t):
    """Pure round close for ALL policies: (close, applied, weights, new
    pstate, info). `arrivals` is the (N,) f32 vector (inf = never returns);
    every branch of the policy algebra is computed and selected by the
    params, so the function is jit/vmap-safe with no Python control flow.

    The algebra: merge arrivals with the in-flight buffer (buffered only);
    the wait set is either the finite arrivals or, for wait_avail_only
    (Impatient), the cohort devices available at dispatch; close at the
    K-th smallest waited arrival (K = all finite for wait_mode=1, buffer_k
    for wait_mode=2, none for the deadline-only wait_mode=0), capped by
    now + deadline_s. Applied = waited arrivals that landed by close;
    weights are 1 or the buffered staleness discount 1/sqrt(1+s). `info`
    carries n_late (finite-but-dropped, heap LATE semantics) and n_never
    (cohort devices past the lookahead horizon)."""
    inf = jnp.float32(jnp.inf)
    arrivals = arrivals.astype(jnp.float32)
    arr_in = jnp.where(cohort, arrivals, inf)
    merged = jnp.where(pp["buffered"],
                       jnp.where(cohort, arrivals, pstate["pending"]),
                       arr_in)
    merged_t = jnp.where(cohort, jnp.int32(t), pstate["pending_t"])
    finite = jnp.isfinite(merged)
    waitset = jnp.where(pp["wait_avail_only"], cohort & avail_now, finite)
    wait_fin = waitset & finite
    wait_arr = jnp.where(wait_fin, merged, inf)
    n_finite = jnp.sum(wait_fin).astype(jnp.int32)
    k = jnp.where(pp["wait_mode"] == 2,
                  jnp.minimum(pp["buffer_k"], n_finite),
                  jnp.where(pp["wait_mode"] == 1, n_finite, 0))
    kth = jnp.sort(wait_arr)[jnp.maximum(k - 1, 0)]
    idle = now + epoch_s
    arr_close = jnp.where(k > 0, kth, idle)
    ddl = now + pp["deadline_s"]
    close = jnp.where(pp["wait_mode"] == 0, ddl,
                      jnp.minimum(arr_close, ddl)).astype(jnp.float32)
    applied = waitset & (merged <= close)
    stale = (jnp.int32(t) - merged_t).astype(jnp.float32)
    w_buf = jnp.float32(1.0) / jnp.sqrt(jnp.float32(1.0) + stale)
    weights = jnp.where(applied,
                        jnp.where(pp["buffered"], w_buf, jnp.float32(1.0)),
                        jnp.float32(0.0)).astype(jnp.float32)
    keep = pp["buffered"] & ~applied
    new_pstate = {"pending": jnp.where(keep, merged, inf),
                  "pending_t": jnp.where(keep, merged_t, 0)}
    info = {"n_late": jnp.sum(finite & ~applied
                              & ~pp["buffered"]).astype(jnp.int32),
            "n_never": jnp.sum(cohort
                               & ~jnp.isfinite(arrivals)).astype(jnp.int32)}
    return close, applied, weights, new_pstate, info
