"""Server round policies: who is dispatched, when the round closes, whose
updates are applied.

The engine hands each policy the cohort it selected, the availability mask at
dispatch time, and the (N,) arrival-time vector (np.inf = never arrives within
the lookahead horizon), and gets back (close_time, applied_mask):

  * WaitForAll — broadcast to every device; block until ALL respond. Offline
    devices respond only after their next active availability epoch, so a
    single blackout device stalls the fleet.
  * WaitForS   — the paper's Eq. 3 protocol: sample S devices uniformly, block
    until all S respond. Because the engine applies one global update per
    round at close time, all S updates are computed at the same (frozen)
    iterate — exactly the straggler-prone baseline `FedAvgSampling`
    approximates without a clock.
  * Deadline   — broadcast (or over-select a cohort), close at a fixed
    deadline, drop late responders. Fast but biased against slow devices.
  * Impatient  — MIFA's server: close as soon as every *currently available*
    device has responded; never wait for unavailable ones (memory corrects
    the bias on the algorithm side).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _sample_cohort(n: int, k: int, rng) -> np.ndarray:
    mask = np.zeros(n, bool)
    mask[rng.permutation(n)[:k]] = True
    return mask


def _close_at_last_finite(arrivals: np.ndarray, mask: np.ndarray, now: float,
                          idle_s: float) -> tuple[float, np.ndarray]:
    applied = mask & np.isfinite(arrivals)
    if not applied.any():
        return now + idle_s, applied
    return float(arrivals[applied].max()), applied


@dataclass(frozen=True)
class WaitForAll:
    name: str = "wait_for_all"

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Dispatch round t to all n devices: (N,) all-True cohort mask."""
        return np.ones(n, bool)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Close when the LAST cohort arrival lands: (close_time, applied
        mask). Devices that never return (inf arrival) are dropped."""
        return _close_at_last_finite(arrivals, cohort, now, epoch_s)


@dataclass(frozen=True)
class WaitForS:
    s: int
    name: str = "wait_for_s"

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Sample S of n devices uniformly (paper Eq. 3): (N,) cohort mask."""
        return _sample_cohort(n, self.s, rng)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Block until every sampled device responds: (close_time, applied
        mask) at the last finite arrival — the straggler-bound baseline."""
        return _close_at_last_finite(arrivals, cohort, now, epoch_s)


@dataclass(frozen=True)
class Deadline:
    """Close at now + deadline_s; apply whoever arrived. cohort_size=None
    broadcasts to all devices (over-selection in the limit)."""

    deadline_s: float
    cohort_size: int | None = None
    name: str = "deadline"

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Broadcast, or over-select `cohort_size` devices: (N,) mask."""
        if self.cohort_size is None or self.cohort_size >= n:
            return np.ones(n, bool)
        return _sample_cohort(n, self.cohort_size, rng)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Close exactly at now + deadline_s; apply whoever arrived by
        then (late responders are dropped): (close_time, applied mask)."""
        close = now + self.deadline_s
        return close, cohort & (arrivals <= close)


@dataclass(frozen=True)
class Impatient:
    """MIFA: wait only for devices available at dispatch time."""

    name: str = "impatient"

    def select(self, t: int, n: int, rng) -> np.ndarray:
        """Dispatch to every device: (N,) all-True cohort mask."""
        return np.ones(n, bool)

    def resolve(self, cohort, avail_now, arrivals, now, epoch_s):
        """Close after the devices available AT DISPATCH respond; never
        wait for currently-unavailable ones: (close_time, applied mask)."""
        return _close_at_last_finite(arrivals, cohort & avail_now, now,
                                     epoch_s)
