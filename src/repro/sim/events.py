"""Heap-based discrete-event queue.

Time is *simulated* seconds — the engine never sleeps. Ties are broken by a
monotone sequence number so the pop order (and therefore every downstream
statistic) is deterministic for a fixed seed.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

ARRIVAL = "arrival"          # a client's update reached the server
LATE = "late"                # arrival after the round closed (dropped)
ROUND_CLOSE = "round_close"  # the server applied the global update


@dataclass(order=True, frozen=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    client: int = field(compare=False, default=-1)
    round: int = field(compare=False, default=-1)

    def as_tuple(self) -> tuple:
        return (self.time, self.seq, self.kind, self.client, self.round)


class EventQueue:
    def __init__(self):
        self._heap: list[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client: int = -1,
             round: int = -1) -> Event:
        ev = Event(float(time), self._seq, kind, client, round)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Event:
        return self._heap[0]

    def __len__(self) -> int:
        return len(self._heap)
