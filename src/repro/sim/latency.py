"""Per-client round-trip latency models (compute + communication, seconds).

`sample(t)` returns the full (N,) latency vector for round t; the engine
indexes the cohort out of it, so draws are identical regardless of which
clients a policy selects — runs with different policies but the same seeds see
the same device speeds.
"""
from __future__ import annotations

import numpy as np


def _per_client(x, n: int) -> np.ndarray:
    out = np.broadcast_to(np.asarray(x, np.float64), (n,)).copy()
    assert np.all(out >= 0), "latency parameters must be non-negative"
    return out


class ShiftedExponentialLatency:
    """t_i = shift_i + Exp(scale_i): the classic straggler model — a
    deterministic floor (compute at full utilisation + link RTT) plus an
    exponential tail (contention, background load)."""

    def __init__(self, shifts, scales, n: int | None = None, seed: int = 0):
        n = n if n is not None else len(np.atleast_1d(shifts))
        self.n = n
        self.shifts = _per_client(shifts, n)
        self.scales = _per_client(scales, n)
        self.rng = np.random.default_rng(seed)

    def sample(self, t: int) -> np.ndarray:
        """(N,) round-trip seconds for round t (fresh exponential draws)."""
        return self.shifts + self.rng.exponential(self.scales)


class LognormalLatency:
    """Compute time exp(N(mu_i, sigma_i)) plus a fixed comm cost comm_i —
    heavy-tailed device speed, as measured in production FL fleets."""

    def __init__(self, mu, sigma, comm=0.0, n: int | None = None,
                 seed: int = 0):
        n = n if n is not None else len(np.atleast_1d(mu))
        self.n = n
        self.mu = np.broadcast_to(np.asarray(mu, np.float64), (n,)).copy()
        self.sigma = _per_client(sigma, n)
        self.comm = _per_client(comm, n)
        self.rng = np.random.default_rng(seed)

    def sample(self, t: int) -> np.ndarray:
        """(N,) round-trip seconds: lognormal compute + fixed comm cost."""
        return np.exp(self.rng.normal(self.mu, self.sigma)) + self.comm


class TraceLatency:
    """Replay a recorded (T, N) matrix of round-trip seconds; rounds past the
    trace end replay the last row."""

    def __init__(self, trace: np.ndarray):
        self.trace = np.array(trace, np.float64, copy=True)
        assert self.trace.ndim == 2 and np.all(self.trace >= 0)
        self.n = self.trace.shape[1]

    def sample(self, t: int) -> np.ndarray:
        """(N,) recorded round-trip seconds for round t (clamped replay)."""
        return self.trace[min(t, len(self.trace) - 1)].copy()


def tiered_shifted_exponential(n: int, *, tiers=((2.0, 1.0), (1.0, 0.4),
                                                 (0.4, 0.15)),
                               seed: int = 0) -> ShiftedExponentialLatency:
    """Device-tier fleet: equal thirds of (shift, scale) tiers, slowest first —
    mirrors the slow/mid/fast split of the adversarial availability benchmark."""
    shifts = np.empty(n)
    scales = np.empty(n)
    k = len(tiers)
    for j, (sh, sc) in enumerate(tiers):
        lo = j * n // k
        hi = (j + 1) * n // k if j < k - 1 else n
        shifts[lo:hi], scales[lo:hi] = sh, sc
    return ShiftedExponentialLatency(shifts, scales, seed=seed)
