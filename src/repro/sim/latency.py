"""Per-client round-trip latency models (compute + communication, seconds).

Every model carries TWO sampling surfaces — the discipline
`repro.scenarios` proved out for availability processes:

  * jit-native: `sample_fn()` returns a pure ``(key, t, state) -> (N,)
    float32`` function, safe under `jax.jit`/`jax.vmap`/`jax.lax.scan`.
    Every numeric parameter rides the `state` pytree (`init_state()`), not
    the function's closure, so the fleet executor can stack per-trial
    latency parameters along its trial axis and the compiled simulator
    (`repro.sim.compiled`) draws a whole round's RTTs inside the program.
  * host: `sample(t)` returns the same (N,) vector as NumPy — it
    *materialises* the jit surface (one jitted call per round), so the two
    surfaces are bit-identical by construction. The heap engine indexes
    the cohort out of the full vector, so draws are identical regardless
    of which clients a policy selects — runs with different policies but
    the same seeds see the same device speeds.

Draws are keyed by ``jax.random.fold_in(key, t)``: RTTs depend only on
(seed, t), never on query order. All values are float32 — simulated-time
arithmetic is f32 end to end so the heap engine and the compiled engine
produce bit-equal close times (see `repro.sim.engine`).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def _per_client(x, n: int) -> np.ndarray:
    out = np.broadcast_to(np.asarray(x, np.float64), (n,)).copy()
    assert np.all(out >= 0), "latency parameters must be non-negative"
    return out


class LatencyModel:
    """Base latency law: two surfaces (host + jit) drawing identical RTTs.

    Subclasses set `n` (device count) and `seed`, and implement
    `init_state()` (parameter pytree with jnp leaves — nothing numeric may
    hide in the sample function's closure) and `sample_fn()` (the pure jit
    surface). `sample(t)` is inherited: it materialises the jit surface,
    which is what makes the surfaces bit-identical by construction.
    """

    n: int
    seed: int = 0

    @property
    def key(self) -> jax.Array:
        """Base PRNG key; both surfaces derive round keys by fold_in(key, t)."""
        return jax.random.PRNGKey(self.seed)

    def init_state(self) -> dict:
        """Jit-side parameter pytree (jnp leaves, stackable per fleet trial)."""
        raise NotImplementedError

    def sample_fn(self) -> Callable:
        """Pure ``(key, t, state) -> (N,) float32 RTT seconds``, jit/vmap-safe."""
        raise NotImplementedError

    def sample(self, t: int) -> np.ndarray:
        """(N,) float32 round-trip seconds for round t — the jit surface
        materialised to NumPy, bit-identical to in-program draws."""
        if getattr(self, "_host_fn", None) is None:
            self._host_fn = jax.jit(self.sample_fn())
            self._host_state = self.init_state()
        return np.asarray(self._host_fn(self.key, jnp.int32(t),
                                        self._host_state))


class ShiftedExponentialLatency(LatencyModel):
    """t_i = shift_i + Exp(scale_i): the classic straggler model — a
    deterministic floor (compute at full utilisation + link RTT) plus an
    exponential tail (contention, background load)."""

    def __init__(self, shifts, scales, n: int | None = None, seed: int = 0):
        n = n if n is not None else len(np.atleast_1d(shifts))
        self.n = n
        self.shifts = _per_client(shifts, n)
        self.scales = _per_client(scales, n)
        self.seed = seed

    def init_state(self) -> dict:
        """{'shifts', 'scales'}: the (N,) f32 per-device parameters."""
        return {"shifts": jnp.asarray(self.shifts, jnp.float32),
                "scales": jnp.asarray(self.scales, jnp.float32)}

    def sample_fn(self) -> Callable:
        """Pure ``(key, t, state) -> (N,) f32``: shift + scale·Exp(1) draws."""
        def rtt_fn(key, t, state):
            e = jax.random.exponential(jax.random.fold_in(key, t),
                                       state["shifts"].shape, jnp.float32)
            return state["shifts"] + state["scales"] * e
        return rtt_fn


class LognormalLatency(LatencyModel):
    """Compute time exp(N(mu_i, sigma_i)) plus a fixed comm cost comm_i —
    heavy-tailed device speed, as measured in production FL fleets."""

    def __init__(self, mu, sigma, comm=0.0, n: int | None = None,
                 seed: int = 0):
        n = n if n is not None else len(np.atleast_1d(mu))
        self.n = n
        self.mu = np.broadcast_to(np.asarray(mu, np.float64), (n,)).copy()
        self.sigma = _per_client(sigma, n)
        self.comm = _per_client(comm, n)
        self.seed = seed

    def init_state(self) -> dict:
        """{'mu', 'sigma', 'comm'}: the (N,) f32 per-device parameters."""
        return {"mu": jnp.asarray(self.mu, jnp.float32),
                "sigma": jnp.asarray(self.sigma, jnp.float32),
                "comm": jnp.asarray(self.comm, jnp.float32)}

    def sample_fn(self) -> Callable:
        """Pure ``(key, t, state) -> (N,) f32``: exp(mu + sigma·z) + comm."""
        def rtt_fn(key, t, state):
            z = jax.random.normal(jax.random.fold_in(key, t),
                                  state["mu"].shape, jnp.float32)
            return jnp.exp(state["mu"] + state["sigma"] * z) + state["comm"]
        return rtt_fn


class TraceLatency(LatencyModel):
    """Replay a recorded (T, N) matrix of round-trip seconds; rounds past the
    trace end replay the last row. Deterministic: the jit surface ignores
    its key and gathers the clamped row from the trace riding `state`."""

    def __init__(self, trace: np.ndarray):
        self.trace = np.array(trace, np.float64, copy=True)
        assert self.trace.ndim == 2 and np.all(self.trace >= 0)
        self.n = self.trace.shape[1]
        self.seed = 0

    def init_state(self) -> dict:
        """{'trace'}: the recorded (T, N) f32 RTT matrix."""
        return {"trace": jnp.asarray(self.trace, jnp.float32)}

    def sample_fn(self) -> Callable:
        """Pure ``(key, t, state) -> (N,) f32``: clamped trace-row replay."""
        def rtt_fn(key, t, state):
            tr = state["trace"]
            return tr[jnp.minimum(t, tr.shape[0] - 1)]
        return rtt_fn


def tiered_shifted_exponential(n: int, *, tiers=((2.0, 1.0), (1.0, 0.4),
                                                 (0.4, 0.15)),
                               seed: int = 0) -> ShiftedExponentialLatency:
    """Device-tier fleet: equal thirds of (shift, scale) tiers, slowest first —
    mirrors the slow/mid/fast split of the adversarial availability benchmark."""
    shifts = np.empty(n)
    scales = np.empty(n)
    k = len(tiers)
    for j, (sh, sc) in enumerate(tiers):
        lo = j * n // k
        hi = (j + 1) * n // k if j < k - 1 else n
        shifts[lo:hi], scales[lo:hi] = sh, sc
    return ShiftedExponentialLatency(shifts, scales, seed=seed)
