"""Discrete-event federated runtime simulator (simulated seconds, no sleeping).

Layers:
  * events   — heap-based event queue (arrival / round-close records).
  * latency  — per-client round-trip-time models (shifted-exponential,
               lognormal compute+comm, trace replay), each exposing a pure
               jit-native ``sample_fn`` plus a host ``sample`` surface.
  * policies — server round policies: WaitForAll, WaitForS (paper Eq. 3),
               Deadline (over-select, drop late), Impatient (MIFA),
               BufferedKofN (FedBuff-style buffered async). All lower to one
               parametric algebra (`policy_params` / `unified_resolve`) so
               mixed-policy fleets compile as one program.
  * engine   — FedSimEngine: drives RoundRunner rounds on a simulated clock,
               reusing the availability processes in core.participation.
               Reference semantics for the compiled path.
  * compiled — SimScanDriver: the same simulation as a jit(scan) program —
               clock, epoch window, policy state all ride the scan carry;
               bit-exact against FedSimEngine (tests/test_sim_compiled.py).
"""
from repro.sim.events import Event, EventQueue  # noqa: F401
from repro.sim.latency import (LatencyModel, LognormalLatency,  # noqa: F401
                               ShiftedExponentialLatency, TraceLatency,
                               tiered_shifted_exponential)
from repro.sim.policies import (BufferedKofN, Deadline,  # noqa: F401
                                Impatient, WaitForAll, WaitForS,
                                init_policy_state, policy_params,
                                unified_resolve, unified_select)
from repro.sim.engine import FedSimEngine, SimConfig  # noqa: F401
from repro.sim.compiled import (SimScanDriver, SimSpec,  # noqa: F401
                                run_sim_scan, sim_scan_supported)
