"""Discrete-event federated runtime simulator (simulated seconds, no sleeping).

Layers:
  * events   — heap-based event queue (arrival / round-close records).
  * latency  — per-client round-trip-time models (shifted-exponential,
               lognormal compute+comm, trace replay).
  * policies — server round policies: WaitForAll, WaitForS (paper Eq. 3),
               Deadline (over-select, drop late), Impatient (MIFA).
  * engine   — FedSimEngine: drives RoundRunner rounds on a simulated clock,
               reusing the availability processes in core.participation.
"""
from repro.sim.events import Event, EventQueue  # noqa: F401
from repro.sim.latency import (LognormalLatency,  # noqa: F401
                               ShiftedExponentialLatency, TraceLatency,
                               tiered_shifted_exponential)
from repro.sim.policies import (Deadline, Impatient,  # noqa: F401
                                WaitForAll, WaitForS)
from repro.sim.engine import FedSimEngine, SimConfig  # noqa: F401
