"""Compiled runtime simulator: the heap engine's semantics as a scan body.

`repro.sim.engine.FedSimEngine` is a host heap loop — per round it runs
Python epoch scans per device, pushes/pops heap events, and dispatches one
jitted round. That caps wall-clock studies at small N. This module lifts
the WHOLE per-round event flow into a pure `lax.scan` body so T simulated
rounds compile into chunked XLA programs (and K-trial policy sweeps vmap
the same body — `repro.fleet.sim`):

  1. availability  — the scenario's jit-native sampler fills a rolling
     (W+1, N) epoch window in the carry (W = SimConfig.max_lookahead_epochs);
     each epoch is drawn exactly once, in order, so the draws are
     bit-identical to the heap engine's lazy epoch cache. Next-active-epoch
     resolution is one argmax over the window — no (T, N) trace, no
     per-device Python scan.
  2. latency       — `sim.latency` models' pure ``(key, t, state) -> rtt``
     surface draws the whole round's RTTs in-program.
  3. policy        — `sim.policies.unified_select` / `unified_resolve`:
     one parametric close/apply algebra covering WaitForAll / WaitForS /
     Deadline / Impatient / BufferedKofN, its state (the in-flight buffer)
     riding the carry.
  4. round         — `core.runner.make_dense_round_fn`, the same pure round
     function every other driver uses; weight-aware algorithms (FedBuffAvg)
     receive the policy's staleness weights as the active mask.

Simulated time is float32 with the same op ordering as the heap engine, so
close times, applied masks, and losses are bit-equal between the two
drivers on every supported config — the heap stays the reference
semantics; `sim_scan_supported` names the blocker (cohort algorithms,
update-clock schedules, host-only latency/policy surfaces, oversized epoch
windows) when a config must fall back.

Carry layout (`SimScanDriver._init_carry`): the scan-engine carry
``{"state", "params", "rng"}`` plus the simulator extension ``{"now",
"e_next", "win", "scen_state", "scen_key", "lat_state", "lat_key", "pp",
"pstate", "tau", "tau_max"}`` — clock, epoch window, scenario / latency /
policy streams and parameters, and τ accumulators. Everything numeric
rides the carry, never the closure, so `jax.vmap` over a leading trial
axis sweeps seeds × policies × latency params as one program.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runner import RoundRunner, make_dense_round_fn
from repro.core.scan_engine import (_eval_rounds, _stack, chunk_bounds,
                                    run_pipelined_chunks)
from repro.sim.engine import SimConfig
from repro.sim.policies import (init_policy_state, policy_params,
                                unified_resolve, unified_select)

# epoch windows larger than this many bools would dominate device memory
# (the window is per fleet lane); sized so W=512 still fits N=10^5
MAX_WINDOW_ELEMS = 1 << 26


@dataclass(frozen=True)
class SimSpec:
    """Simulation request for `run_fl(sim=...)`: the server `policy`, the
    `latency` model, and the temporal `config` (epoch length, server
    overhead, lookahead horizon). The compiled engine serves it when
    `sim_scan_supported` says yes; otherwise the heap engine does."""

    policy: object
    latency: object
    config: SimConfig = field(default_factory=SimConfig)


def sim_scan_supported(runner: RoundRunner, sim: SimSpec) -> tuple[bool, str]:
    """Can this (runner, sim) pair run as a compiled scan? (ok, reason).

    The blockers mirror `core.scan_engine.scan_supported` and add the
    simulator's own: availability must come from a scenario (jit-native
    sampler), the latency model and policy must expose their jit surfaces
    (`sample_fn` / `unified`), and the (W+1, N) epoch window must fit.
    """
    if runner.scen_process is None:
        return False, ("the compiled simulator samples availability inside "
                       "the program; pass scenario= (host participation "
                       "processes have no jit-native surface)")
    if getattr(runner.scen_process, "scan_window", None) is not None:
        return False, ("windowed scenarios (trace replay) page their "
                       "availability window in host-side between chunks, "
                       "but the compiled simulator pre-draws whole epochs "
                       "inside the program with no host hook at epoch "
                       "granularity; the heap engine serves trace-driven "
                       "availability through the host surface")
    if runner.cohort_mode:
        return False, ("cohort-based algorithms assemble compact batches on "
                       "the host per round; the simulated clock cannot ride "
                       "their scan carry")
    if runner.uses_update_clock:
        return False, ("update-clock schedules read the device-side "
                       "applied-update counter between rounds; the host "
                       "cannot precompute a chunk of learning rates")
    if not hasattr(sim.latency, "sample_fn"):
        return False, (f"{type(sim.latency).__name__} has no jit-native "
                       "sample_fn surface; only host sampling is possible")
    if not hasattr(sim.policy, "unified"):
        return False, (f"{type(sim.policy).__name__} has no unified() "
                       "parametric form; only the heap engine can drive it")
    w = sim.config.max_lookahead_epochs
    if (w + 1) * runner.n_clients > MAX_WINDOW_ELEMS:
        return False, (
            f"the ({w + 1}, {runner.n_clients}) availability epoch window "
            f"exceeds {MAX_WINDOW_ELEMS} elements; lower "
            "SimConfig.max_lookahead_epochs for compiled runs")
    return True, ""


def make_sim_scan_body(model, algo, k_steps: int, weight_decay: float,
                       scen_fn: Callable, lat_fn: Callable, config: SimConfig,
                       *, emit_masks: bool = False,
                       batch_fn: Callable | None = None) -> Callable:
    """Build the simulator's `lax.scan` body: one simulated round per step.

    ``(carry, xs) -> (carry, ys)`` where xs carries ``{"t", "eta_loc",
    "eta_srv"}`` plus ``"batch"`` unless `batch_fn(t)` draws batches
    in-program (`data.pipeline.JitProceduralBatcher.batch_fn`). The body:
    fill the epoch window up to k0+W, resolve each device's dispatch start
    (now if available, else its next active epoch start, else inf), draw
    RTTs, select the cohort, close the round via the unified policy
    algebra, and apply the round function with the applied mask (or the
    staleness weights, for weight-aware algorithms). ys are the round
    metrics plus ``t_open / t_close / n_dispatched / n_applied / n_late /
    n_never / tau_sum / tau_sq_sum`` (and the ``cohort`` / ``applied`` /
    ``weights`` vectors under `emit_masks`, for parity tests).

    `scen_fn` / `lat_fn` are the jit-native scenario and latency surfaces;
    every numeric parameter rides the carry so the fleet can vmap the body.
    """
    base = make_dense_round_fn(model, algo, k_steps, weight_decay)
    weight_aware = getattr(algo, "weight_aware", False)
    w = config.max_lookahead_epochs
    epoch_s = jnp.float32(config.epoch_s)
    overhead_s = jnp.float32(config.server_overhead_s)
    inf = jnp.float32(jnp.inf)

    def body(carry, x):
        t = x["t"]
        now = carry["now"]
        k0 = jnp.floor(now / epoch_s).astype(jnp.int32)

        # 1. epoch window: draw epochs e_next..k0+W consecutively (each
        # exactly once, in order — the heap engine's lazy cache draws the
        # same sequence, so the masks are bit-identical)
        def fill_cond(c):
            return c[1] <= k0 + w

        def fill_step(c):
            win, e, scen_state = c
            mask, scen_state = scen_fn(carry["scen_key"], e, scen_state)
            return win.at[e % (w + 1)].set(mask), e + 1, scen_state
        win, e_next, scen_state = jax.lax.while_loop(
            fill_cond, fill_step,
            (carry["win"], carry["e_next"], carry["scen_state"]))

        # 2. dispatch starts: now if available now, else the start of the
        # device's first active epoch in (k0, k0+W], else inf (never)
        avail_now = win[k0 % (w + 1)]
        future = win[(k0 + 1 + jnp.arange(w)) % (w + 1)]       # (W, N)
        returns = future.any(axis=0)
        next_epoch = k0 + 1 + jnp.argmax(future, axis=0).astype(jnp.int32)
        starts = jnp.where(avail_now, now,
                           jnp.where(returns,
                                     next_epoch.astype(jnp.float32) * epoch_s,
                                     inf))

        # 3. latency + cohort + arrivals
        rtt = lat_fn(carry["lat_key"], t, carry["lat_state"])
        cohort = unified_select(t, carry["pp"], carry["pstate"])
        arrivals = jnp.where(cohort, starts + rtt, inf)

        # 4. close the round (unified policy algebra; pstate = the
        # buffered policies' in-flight buffer)
        close, applied, weights, pstate, info = unified_resolve(
            carry["pp"], carry["pstate"], cohort, avail_now, arrivals,
            now, epoch_s, t)

        # 5. the same pure round function every other driver applies
        active = weights if weight_aware else applied
        rng, sub = jax.random.split(carry["rng"])
        batch = batch_fn(t) if batch_fn is not None else x["batch"]
        state, params, metrics = base(carry["state"], carry["params"], batch,
                                      active, x["eta_loc"], x["eta_srv"],
                                      sub)

        tau = jnp.where(applied, 0, carry["tau"] + 1)
        out = {"state": state, "params": params, "rng": rng,
               "now": close + overhead_s, "e_next": e_next, "win": win,
               "scen_state": scen_state, "scen_key": carry["scen_key"],
               "lat_state": carry["lat_state"], "lat_key": carry["lat_key"],
               "pp": carry["pp"], "pstate": pstate,
               "tau": tau, "tau_max": jnp.maximum(carry["tau_max"], tau)}
        ys = dict(metrics, t_open=now, t_close=close,
                  n_dispatched=jnp.sum(cohort).astype(jnp.int32),
                  n_applied=jnp.sum(applied).astype(jnp.int32),
                  n_late=info["n_late"], n_never=info["n_never"],
                  tau_sum=jnp.sum(tau), tau_sq_sum=jnp.sum(tau * tau))
        if emit_masks:
            ys.update(cohort=cohort, applied=applied, weights=weights)
        return out, ys

    return body


def init_sim_carry(runner: RoundRunner, sim: SimSpec) -> dict:
    """The simulator's scan carry from a freshly constructed runner:
    state/params/rng plus clock (now=0), empty epoch window, scenario and
    latency streams/params, unified policy params/state, and τ counters.
    Params are copied (the chunk call donates the carry)."""
    r = runner
    proc = r.scen_process
    n = r.n_clients
    w = sim.config.max_lookahead_epochs
    return {"state": r.state, "params": jax.tree.map(jnp.array, r.params),
            "rng": r.rng,
            "now": jnp.float32(0.0), "e_next": jnp.int32(0),
            "win": jnp.zeros((w + 1, n), bool),
            "scen_state": proc.init_state(), "scen_key": proc.key,
            "lat_state": sim.latency.init_state(),
            "lat_key": sim.latency.key,
            "pp": policy_params(sim.policy, n),
            "pstate": init_policy_state(n),
            "tau": jnp.asarray(r.stats.tau, jnp.int32),
            "tau_max": jnp.asarray(r.stats.tau_max_per_dev, jnp.int32)}


class SimScanDriver:
    """Drives a `RoundRunner` through T *simulated* rounds as chunked scan
    programs — the compiled twin of `sim.engine.FedSimEngine`.

    Constructed by `run_fl(sim=..., engine="scan")` after
    `sim_scan_supported` says yes. Mirrors `core.scan_engine.ScanDriver`:
    chunks snap to eval rounds, the carry is donated across chunks, history
    and τ statistics are written back so `runner.finalize()` works
    unchanged — with every round stamped in simulated seconds, and evals
    stamped at close + server overhead exactly like the heap engine.
    `round_log` collects the heap engine's per-round records (open/close
    times, dispatch/applied/late/never counts) for time-to-accuracy plots.
    """

    def __init__(self, runner: RoundRunner, sim: SimSpec, *,
                 scan_chunk: int = 64, emit_masks: bool = False):
        if scan_chunk < 1:
            raise ValueError(f"scan_chunk must be >= 1, got {scan_chunk}")
        self.r = runner
        self.sim = sim
        self.scan_chunk = scan_chunk
        self.emit_masks = emit_masks
        self.round_log: list[dict] = []
        self.applied_log: list[np.ndarray] = []
        self.cohort_log: list[np.ndarray] = []
        body = make_sim_scan_body(
            runner.model, runner.algo, runner.batcher.k_steps,
            runner.weight_decay, runner.scen_process.sample_fn(),
            sim.latency.sample_fn(), sim.config, emit_masks=emit_masks)
        self._chunk_fn = jax.jit(
            lambda carry, xs: jax.lax.scan(body, carry, xs),
            donate_argnums=(0,))

    # ------------------------------------------------------------------ #
    def _build_xs(self, t0: int, t1: int) -> dict:
        r = self.r
        pairs = [r.learning_rates(t) for t in range(t0, t1)]
        return {"t": np.arange(t0, t1, dtype=np.int32),
                "eta_loc": np.asarray([p[0] for p in pairs], np.float32),
                "eta_srv": np.asarray([p[1] for p in pairs], np.float32),
                "batch": _stack([r.batcher.sample_round(t)
                                 for t in range(t0, t1)])}

    def _writeback(self, carry: dict) -> None:
        r = self.r
        r.state, r.params, r.rng = (carry["state"], carry["params"],
                                    carry["rng"])
        r.scen_state = carry["scen_state"]

    def _flush(self, t0: int, t1: int, ys: dict, carry: dict) -> None:
        """Block on a chunk's results; rebuild per-round history, the
        simulated-seconds axis, τ statistics, and the round log."""
        self.r.stats.absorb_scan(carry["tau"], carry["tau_max"],
                                 ys["tau_sum"], ys["tau_sq_sum"])
        ys = {k: np.asarray(v) for k, v in ys.items()}
        skip = ("tau_sum", "tau_sq_sum", "t_open", "t_close", "n_dispatched",
                "n_applied", "n_late", "n_never", "cohort", "applied",
                "weights")
        for j, t in enumerate(range(t0, t1)):
            self.r.hist.record_round(
                t, {k: v[j] for k, v in ys.items() if k not in skip},
                sim_time=ys["t_close"][j])
            self.round_log.append(
                {"round": t, "t_open": float(ys["t_open"][j]),
                 "t_close": float(ys["t_close"][j]),
                 "duration_s": float(ys["t_close"][j] - ys["t_open"][j]),
                 "n_dispatched": int(ys["n_dispatched"][j]),
                 "n_applied": int(ys["n_applied"][j]),
                 "n_late": int(ys["n_late"][j]),
                 "n_never": int(ys["n_never"][j]),
                 "train_loss": float(ys["loss"][j])})
            if self.emit_masks:
                self.applied_log.append(ys["applied"][j])
                self.cohort_log.append(ys["cohort"][j])

    # ------------------------------------------------------------------ #
    def run(self, n_rounds: int, *, eval_fn: Callable | None = None,
            eval_every: int = 10, verbose: bool = False) -> None:
        """Simulate `n_rounds` rounds, mutating the runner in place; evals
        run at the heap engine's cadence, stamped at close + overhead."""
        r = self.r
        cfg = self.sim.config
        evals = _eval_rounds(n_rounds, eval_every, eval_fn is not None)

        def on_sync(t):
            sim_t = float(np.float32(r.hist.sim_seconds[-1])
                          + np.float32(cfg.server_overhead_s))
            el, ea = r.evaluate(t, eval_fn, sim_time=sim_t)
            if verbose:
                print(f"  round {t:5d} sim_t={sim_t:10.2f}s "
                      f"train={r.hist.train_loss[-1]:.4f} eval={el:.4f} "
                      f"acc={ea:.4f}")

        run_pipelined_chunks(
            init_sim_carry(r, self.sim),
            chunk_bounds(n_rounds, self.scan_chunk, evals),
            chunk_fn=self._chunk_fn, build_xs=self._build_xs,
            writeback=self._writeback, flush=self._flush,
            sync_rounds=evals, on_sync=on_sync)


def run_sim_scan(runner: RoundRunner, sim: SimSpec, n_rounds: int, *,
                 scan_chunk: int = 64, eval_fn: Callable | None = None,
                 eval_every: int = 10, verbose: bool = False):
    """Convenience wrapper: drive `runner` through the compiled simulator
    and return `(params, FLHistory)` — the `run_fl(sim=...)` fast path,
    callable directly when you already hold a constructed runner."""
    t0 = time.time()
    SimScanDriver(runner, sim, scan_chunk=scan_chunk).run(
        n_rounds, eval_fn=eval_fn, eval_every=eval_every, verbose=verbose)
    runner.hist.wall_time = time.time() - t0
    return runner.finalize()
