"""FedSimEngine — discrete-event driver for federated rounds.

Simulated time advances on a heap of arrival events; nothing sleeps. The
availability processes from `core.participation` are reinterpreted on a
*temporal* axis: one draw per fixed-length availability epoch (`epoch_s`
simulated seconds), cached so each epoch is drawn exactly once, in order
(the processes hold stateful RNGs). A device dispatched while unavailable
responds only after its next active epoch — this is where wait-for-straggler
policies bleed wall-clock.

Per server round t:
  1. policy.select(t) picks the cohort; latency.sample(t) draws device RTTs.
  2. Each cohort device's arrival time = (dispatch now, or the start of its
     next active epoch) + its RTT; arrivals are pushed on the event heap.
  3. policy.resolve(...) returns (close_time, applied_mask); the heap is
     drained up to close_time. Arrivals after it are logged as LATE 6-tuples
     ``(arrival_time, seq, LATE, client, round, close_time)`` — the true
     arrival time is preserved so lateness is measurable. Stateful policies
     (``policy.stateful``, e.g. `BufferedKofN`) instead keep late arrivals
     *in flight* on the heap and merge them into later rounds, with
     staleness weights passed to weight-aware algorithms.
  4. RoundRunner.step(t, applied_mask, sim_time=close_time) applies the
     global update through the *unchanged* jitted round API.

Simulated time is float32 end to end with the same op ordering as the
compiled engine (`repro.sim.compiled`), so the two drivers produce
bit-equal close times and applied masks — the heap stays the reference
semantics; the compiled engine is the fast path.

The same algorithm/round API therefore runs under any temporal policy, and
FLHistory/TauStats carry a simulated-seconds axis for time-to-accuracy plots.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.runner import RoundRunner
from repro.sim.events import ARRIVAL, LATE, ROUND_CLOSE, EventQueue


@dataclass(frozen=True)
class SimConfig:
    epoch_s: float = 4.0             # availability re-poll granularity
    server_overhead_s: float = 0.05  # aggregation + broadcast per round
    max_lookahead_epochs: int = 10_000  # device never back => arrival = inf


class FedSimEngine:
    """Discrete-event driver: simulated-seconds rounds over a RoundRunner.

    `policy` decides who is dispatched and when rounds close;
    `participation` (any ``.sample(t)`` process, incl. scenario host
    samplers) is replayed on the temporal axis; `latency` draws per-device
    RTTs. See the module docstring for the per-round event flow.
    """

    def __init__(self, runner: RoundRunner, policy, participation, latency,
                 config: SimConfig = SimConfig(), seed: int = 0):
        assert latency.n == runner.n_clients, (latency.n, runner.n_clients)
        self.runner = runner
        self.policy = policy
        self.participation = participation
        self.latency = latency
        self.config = config
        self.rng = np.random.default_rng(seed)
        self.queue = EventQueue()
        # simulated time is float32 end to end, with the same op order as
        # the compiled engine (repro.sim.compiled) — close times and
        # applied masks are therefore bit-equal across the two drivers
        self.now = np.float32(0.0)
        self.event_log: list[tuple] = []
        self.round_log: list[dict] = []
        self.applied_log: list[np.ndarray] = []
        self.pstate = (policy.init_pstate(runner.n_clients)
                       if getattr(policy, "stateful", False) else None)
        self.n_never_total = 0
        self._warned_never = False
        # seed the cache with the epoch-0 draw: validates the process width
        # without consuming a second sample(0) from stateful processes
        mask0 = np.asarray(participation.sample(0), bool)
        assert mask0.shape == (runner.n_clients,), \
            (mask0.shape, runner.n_clients)
        self._avail_cache: list[np.ndarray] = [mask0]
        # epoch lookahead memo (valid because drawn epochs are immutable and
        # queries move forward in time): next known active epoch per device,
        # and the exclusive end of the last failed scan
        self._next_active: dict[int, int] = {}
        self._dark_until = np.zeros(runner.n_clients, np.int64)

    # ------------------------------------------------------------------ #
    def avail(self, epoch: int) -> np.ndarray:
        """Availability mask for an epoch; drawn once, in epoch order."""
        while len(self._avail_cache) <= epoch:
            k = len(self._avail_cache)
            self._avail_cache.append(
                np.asarray(self.participation.sample(k), bool))
        return self._avail_cache[epoch]

    def _next_active_epoch(self, i: int, k0: int) -> int | None:
        cached = self._next_active.get(i)
        if cached is not None and cached > k0:
            return cached
        end = k0 + 1 + self.config.max_lookahead_epochs
        for k in range(max(k0 + 1, int(self._dark_until[i])), end):
            if self.avail(k)[i]:
                self._next_active[i] = k
                return k
        self._dark_until[i] = end   # device i known inactive before `end`
        return None

    # ------------------------------------------------------------------ #
    def run_round(self, t: int) -> dict:
        """Simulate one server round: dispatch, drain arrivals, apply the
        policy's mask through RoundRunner, advance the clock. Returns the
        round record (open/close times, dispatch/applied/late counts, plus
        n_never — dispatched devices past the lookahead horizon)."""
        cfg = self.config
        n = self.runner.n_clients
        now = np.float32(self.now)
        epoch_s = np.float32(cfg.epoch_s)
        stateful = getattr(self.policy, "stateful", False)
        if stateful:
            cohort = np.asarray(
                self.policy.select_pending(t, n, self.pstate), bool)
        else:
            cohort = np.asarray(self.policy.select(t, n, self.rng), bool)
        rtt = np.asarray(self.latency.sample(t), np.float32)
        k0 = int(now // epoch_s)
        avail_now = self.avail(k0)

        n_never = 0
        arrivals = np.full(n, np.inf, np.float32)
        for i in np.flatnonzero(cohort):
            if avail_now[i]:
                start = now
            else:
                k = self._next_active_epoch(i, k0)
                if k is None:
                    n_never += 1
                    continue                      # never returns: stays inf
                start = np.float32(np.float32(k) * epoch_s)
            arrivals[i] = np.float32(start + rtt[i])
            self.queue.push(arrivals[i], ARRIVAL, client=i, round=t)
        if n_never:
            self.n_never_total += n_never
            if not self._warned_never:
                self._warned_never = True
                warnings.warn(
                    f"{n_never} dispatched device(s) in round {t} never "
                    "become available again within "
                    f"SimConfig.max_lookahead_epochs={cfg.max_lookahead_epochs}"
                    " epochs; their arrivals stay inf and they are dropped "
                    "(raise the knob to look further ahead)", stacklevel=2)

        weights = None
        if stateful:
            close, applied, weights, self.pstate = \
                self.policy.resolve_pending(self.pstate, cohort, avail_now,
                                            arrivals, now, epoch_s, t)
        else:
            close, applied = self.policy.resolve(cohort, avail_now, arrivals,
                                                 now, epoch_s)
        n_late = 0
        if stateful:
            # buffered policies: arrivals after close stay IN FLIGHT on the
            # heap (they merge into a later round's buffer) — drain <= close
            while len(self.queue) and self.queue.peek().time <= close:
                ev = self.queue.pop()
                if applied[ev.client]:
                    self.event_log.append(ev.as_tuple())
                else:
                    n_late += 1
                    self.event_log.append((ev.time, ev.seq, LATE, ev.client,
                                           t, close))
        else:
            while len(self.queue):
                ev = self.queue.pop()
                if ev.time <= close and applied[ev.client]:
                    self.event_log.append(ev.as_tuple())
                else:  # late responder (deadline) or unwaited-for (impatient)
                    n_late += 1
                    self.event_log.append((ev.time, ev.seq, LATE, ev.client,
                                           t, close))
        self.event_log.append((close, -1, ROUND_CLOSE, -1, t))

        active = applied
        if weights is not None and getattr(self.runner.algo, "weight_aware",
                                           False):
            active = weights
        metrics = self.runner.step(t, active, sim_time=close)
        self.applied_log.append(applied.copy())
        self.now = np.float32(close) + np.float32(cfg.server_overhead_s)
        rec = {"round": t, "t_open": float(now), "t_close": float(close),
               "duration_s": float(close - now),
               "n_dispatched": int(cohort.sum()),
               "n_applied": int(applied.sum()), "n_late": n_late,
               "n_never": n_never,
               "train_loss": float(metrics["loss"])}
        self.round_log.append(rec)
        return rec

    def run(self, n_rounds: int, *, eval_fn: Callable | None = None,
            eval_every: int = 10, max_sim_seconds: float | None = None):
        """Simulate up to n_rounds (or until the simulated clock runs out).

        `max_sim_seconds` is checked at round close — rounds are not
        pre-empted, so the final round may overshoot the budget (by however
        long that round's policy blocked). Returns (params, FLHistory) with
        sim_seconds/eval_seconds populated."""
        for t in range(n_rounds):
            self.run_round(t)
            last = (t == n_rounds - 1 or
                    (max_sim_seconds is not None
                     and self.now >= max_sim_seconds))
            if eval_fn is not None and (t % eval_every == 0 or last):
                self.runner.evaluate(t, eval_fn, sim_time=self.now)
            if last:
                break
        return self.runner.finalize()
