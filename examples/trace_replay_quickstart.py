"""Quickstart: replay a recorded availability trace, kill the run, resume.

Synthesizes a repro-trace-v1 file (Gilbert–Elliott bursts + permanent
churn — the arbitrary-unavailability regime on disk), trains MIFA over it
with the scan engine while checkpointing, then simulates a preemption:
a second run is stopped halfway, resumed from its latest snapshot, and
checked fp32 bit-exact against the uninterrupted one. Trace format and
the checkpoint runbook: docs/operations.md.

    PYTHONPATH=src python examples/trace_replay_quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import CheckpointSpec, list_checkpoints  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core import MIFA, run_fl  # noqa: E402
from repro.data import (ClientBatcher, label_skew_partition,  # noqa: E402
                        make_classification)
from repro.models import build_model  # noqa: E402
from repro.optim import inv_t  # noqa: E402
from repro.scenarios import (Scenario, TraceReplay,  # noqa: E402
                             open_trace, synthesize_trace)


def main() -> None:
    n_clients, rounds, kill_at, every = 20, 96, 48, 16
    cfg = get_config("paper_logistic").replace(fl_clients=n_clients)
    model = build_model(cfg)
    X, y = make_classification(10, cfg.d_model, 200, seed=0)
    idx, _ = label_skew_partition(y, n_clients, seed=0)
    batcher = ClientBatcher(X, y, idx, batch_size=32, k_steps=5, seed=0)

    work = tempfile.mkdtemp(prefix="trace_quickstart_")

    # 1. record a trace: bursty availability, 10% of devices churn out
    #    for good (docs/operations.md shows ingesting a REAL log instead)
    trace_path = synthesize_trace(os.path.join(work, "fleet.npy"),
                                  n=n_clients, horizon=rounds, seed=7,
                                  rate=0.5, burst=6.0, churn_frac=0.1)
    trace = open_trace(trace_path)
    print(f"recorded {trace.n_rounds} rounds x {trace.n_clients} devices "
          f"-> {os.path.getsize(trace_path)} bytes on disk")

    # 2. replay it: masks stream off disk in 32-round windows; the scan
    #    engine refreshes the window at chunk boundaries, so no (T, N)
    #    mask matrix ever exists
    scen = lambda: Scenario(TraceReplay(trace_path, window=32),
                            name="recorded")
    kw = dict(model=model, algo=MIFA(memory="array"), batcher=batcher,
              schedule=inv_t(1.0), weight_decay=1e-3, seed=0,
              eval_every=rounds, engine="scan", scan_chunk=16)
    spec = lambda d, **k: CheckpointSpec(
        every=every, dir=os.path.join(work, d), **k)

    params_full, hist_full = run_fl(scenario=scen(), n_rounds=rounds,
                                    checkpoint=spec("full"), **kw)
    print(f"uninterrupted run: final train loss "
          f"{hist_full.train_loss[-1]:.4f}, tau_bar {hist_full.tau_bar:.2f}")

    # 3. the preemption: same config, stopped at round 48...
    run_fl(scenario=scen(), n_rounds=kill_at, checkpoint=spec("ck"), **kw)
    snaps = [r for r, _ in list_checkpoints(os.path.join(work, "ck"))]
    print(f"killed at round {kill_at}; snapshots on disk: {snaps}")

    # 4. ...and resumed from the latest snapshot to the full horizon
    params_res, hist_res = run_fl(scenario=scen(), n_rounds=rounds,
                                  checkpoint=spec("ck", resume=True), **kw)
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree.leaves(params_full),
                               jax.tree.leaves(params_res)))
    same_hist = hist_full.train_loss == hist_res.train_loss
    print(f"resumed run: max |param diff| = {diff:g}, "
          f"loss history identical = {same_hist}")
    assert diff == 0.0 and same_hist, "resume must be fp32 bit-exact"


if __name__ == "__main__":
    main()
