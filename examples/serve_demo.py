"""Batched-serving example: prefill + greedy decode across architectures,
including the SSM (O(1)-state) and MLA (compressed-cache) decode paths.

    PYTHONPATH=src python examples/serve_demo.py
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(HERE, "..", "src")


def main() -> None:
    env = dict(os.environ, PYTHONPATH=SRC)
    for arch in ["granite-3-8b", "mamba2-1.3b", "deepseek-v2-lite-16b",
                 "gemma3-4b"]:
        print(f"\n=== serving {arch} (smoke config) ===")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--smoke", "--batch", "2", "--prompt-len", "16",
             "--new-tokens", "8"],
            check=True, env=env, cwd=os.path.join(HERE, ".."))


if __name__ == "__main__":
    main()
