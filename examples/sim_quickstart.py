"""Simulated-time quickstart: what does waiting for stragglers *cost*?

Same learning problem as examples/quickstart.py, but driven by the
discrete-event runtime simulator: every device gets a round-trip latency
(tiered shifted-exponential) and a periodic-blackout availability pattern,
and four server policies race to a target eval loss on the simulated clock.

    PYTHONPATH=src python examples/sim_quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import (MIFA, AdversarialParticipation,  # noqa: E402
                        BiasedFedAvg, RoundRunner, label_correlated_probs)
from repro.data import (ClientBatcher, label_skew_partition,  # noqa: E402
                        make_classification)
from repro.models import build_model  # noqa: E402
from repro.optim import inv_t  # noqa: E402
from repro.sim import (Deadline, FedSimEngine, Impatient,  # noqa: E402
                       SimConfig, WaitForAll, WaitForS,
                       tiered_shifted_exponential)


def blackout(n: int, seed: int = 0):
    """Slow third dark 3 of every 4 epochs; mid third 1 of 3; rest 1 of 8."""
    rng = np.random.default_rng(seed)
    periods = np.full(n, 8, np.int64)
    offs = np.full(n, 1, np.int64)
    third = n // 3
    periods[:third], offs[:third] = 4, 3
    periods[third:2 * third], offs[third:2 * third] = 3, 1
    return AdversarialParticipation(n, periods, offs,
                                    rng.integers(0, 8, n))


def main() -> None:
    n_clients, rounds, target = 21, 100, 1.4
    cfg = get_config("paper_logistic").replace(fl_clients=n_clients)
    model = build_model(cfg)
    X, y = make_classification(10, cfg.d_model, 200, seed=0)
    Xte, yte = make_classification(10, cfg.d_model, 50, seed=99)
    idx, labels = label_skew_partition(y, n_clients, seed=0)
    label_correlated_probs(labels, p_min=0.1)  # (printed setups use blackout)
    batcher = ClientBatcher(X, y, idx, batch_size=32, k_steps=5, seed=0)

    def eval_fn(params):
        batch = {"x": jnp.asarray(Xte), "y": jnp.asarray(yte)}
        loss, _ = model.loss_fn(params, batch)
        return float(loss), float(model.accuracy(params, batch))

    print(f"{'policy':<28}{'sim hrs':>8}{'to target':>10}{'loss':>8}"
          f"{'acc':>7}{'round s':>9}")
    for name, policy, algo in [
        ("wait-for-all", WaitForAll(), BiasedFedAvg()),
        ("wait-for-S (Eq. 3)", WaitForS(s=7), BiasedFedAvg()),
        ("deadline 3s (drop late)", Deadline(deadline_s=3.0), BiasedFedAvg()),
        ("impatient + MIFA", Impatient(), MIFA(memory="array")),
    ]:
        runner = RoundRunner(model=model, algo=algo, batcher=batcher,
                             schedule=inv_t(1.0), weight_decay=1e-3, seed=0)
        engine = FedSimEngine(runner, policy, blackout(n_clients),
                              tiered_shifted_exponential(n_clients, seed=7),
                              config=SimConfig(epoch_s=4.0), seed=13)
        _, hist = engine.run(rounds, eval_fn=eval_fn, eval_every=5)
        to_target = next((f"{s:8.0f}s" for s, el, _ in hist.eval_curve()
                          if el <= target), "   never")
        dur = np.mean([r["duration_s"] for r in engine.round_log])
        print(f"{name:<28}{engine.now / 3600:>8.2f}{to_target:>10}"
              f"{hist.eval_loss[-1][1]:>8.3f}{hist.eval_acc[-1][1]:>7.3f}"
              f"{dur:>9.2f}")


if __name__ == "__main__":
    main()
