"""Compiled-simulator quickstart: a policy × seed race on the wall clock,
as ONE compiled program.

Where examples/sim_quickstart.py steps the discrete-event heap engine one
Python round at a time, this drives the compiled simulator
(`repro.sim.compiled`, docs/architecture.md §11): the simulated clock, the
availability lookahead window, the latency draws, and the server policy —
including the buffered-async (FedBuff-style) K-of-N policy with
staleness-discounted merges — all live inside `jit(scan(vmap(...)))`.
Every (seed, policy) lane below advances in lockstep inside one XLA
program via `repro.fleet.run_sim_fleet`, and any single lane reproduces
the heap engine bit-for-bit (tests/test_sim_compiled.py).

    PYTHONPATH=src python examples/async_sim_quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import FedBuffAvg  # noqa: E402
from repro.data import JitProceduralBatcher  # noqa: E402
from repro.fleet import (SimTrial, make_fleet_eval,  # noqa: E402
                         run_sim_fleet)
from repro.models.layers import softmax_cross_entropy  # noqa: E402
from repro.scenarios import ClusterCorrelated  # noqa: E402
from repro.sim import (BufferedKofN, Deadline, Impatient,  # noqa: E402
                       SimConfig, WaitForAll,
                       tiered_shifted_exponential)

import jax.numpy as jnp  # noqa: E402

N, ROUNDS, SEEDS = 512, 60, (0, 1, 2)
TARGET_LOSS = 0.45


class TinyLogistic:
    """16-feature logistic shim — the model shape benchmarks use at N=10⁵."""

    def init(self, rng):
        return {"w": jnp.zeros((16, 2), jnp.float32),
                "b": jnp.zeros((2,), jnp.float32)}

    def loss_fn(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return softmax_cross_entropy(logits, batch["y"]), {}

    def accuracy(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return jnp.mean(jnp.argmax(logits, -1) == batch["y"])


def main() -> None:
    # procedural data with a jit-native surface: `batch_fn` draws each
    # round's (N, K, mb, dim) batch inside the compiled program
    batcher = JitProceduralBatcher(n_clients=N, dim=16, batch_size=8,
                                   k_steps=2, noise=2.5, seed=0)
    policies = [
        ("wait-for-all", WaitForAll()),
        ("deadline-3s", Deadline(deadline_s=3.0)),
        ("impatient", Impatient()),
        ("buffered-K/4", BufferedKofN(k=N // 4)),
    ]
    trials = [
        SimTrial(seed=seed, policy=policy,
                 scenario=ClusterCorrelated(N, 8, q_fail=0.25,
                                            q_recover=0.4, p_device=0.9,
                                            seed=100 + seed),
                 latency=tiered_shifted_exponential(N, seed=7 + seed),
                 label=f"{name}/seed{seed}")
        for seed in SEEDS for name, policy in policies]

    model = TinyLogistic()
    _, hist = run_sim_fleet(
        model=model, algo=FedBuffAvg(), batcher=batcher,
        schedule=lambda t: 0.02, n_rounds=ROUNDS, trials=trials,
        config=SimConfig(epoch_s=4.0, max_lookahead_epochs=64),
        scan_chunk=10, eval_fn=make_fleet_eval(model,
                                               batcher.eval_batch(1024)),
        eval_every=5, batch_fn=batcher.batch_fn())

    print(f"{len(trials)} lanes x {ROUNDS} rounds in one compiled program "
          f"({hist.wall_time:.1f}s host)\n")
    print(f"{'policy':<16}{'sim-s to loss<%.2f' % TARGET_LOSS:>20}"
          f"{'final loss':>12}{'final acc':>11}")
    for name, _ in policies:
        lanes = [hist.trial(k) for k, tr in enumerate(trials)
                 if tr.label.startswith(name)]
        tts = []
        for h in lanes:
            hit = [t for t, loss, _ in h.eval_curve()
                   if loss <= TARGET_LOSS]
            tts.append(hit[0] if hit else None)
        med = (f"{np.median([t for t in tts if t is not None]):.0f}"
               if all(t is not None for t in tts) else "never")
        print(f"{name:<16}{med:>20}"
              f"{np.mean([h.eval_loss[-1][1] for h in lanes]):>12.4f}"
              f"{np.mean([h.eval_acc[-1][1] for h in lanes]):>11.4f}")
    print("\nThe buffered and impatient servers stop paying simulated "
          "seconds for stragglers; the buffered lanes merge them later "
          "with 1/sqrt(1+staleness) weight instead of dropping them.")


if __name__ == "__main__":
    main()
