"""Scenario sweep: seed × scenario × algorithm on the vmapped fleet.

Runs the README "Scenarios" snippet end-to-end: a `FleetSpec` grid over
Gilbert–Elliott burst lengths (correlated availability) plus single runs on
a cluster-outage and a staged-blackout scenario, with availability sampled
INSIDE the jitted round (jit-native surface — no precomputed (T, N) trace).
Prints each scenario's theory regime (`tau_bound()`) next to its results.

    PYTHONPATH=src python examples/scenario_sweep.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import MIFA, BiasedFedAvg, run_fl  # noqa: E402
from repro.data import (ClientBatcher, label_skew_partition,  # noqa: E402
                        make_classification)
from repro.fleet import expand_grid, make_fleet_eval, run_fleet  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import inv_t  # noqa: E402
from repro.scenarios import make_scenario  # noqa: E402


def main() -> None:
    n_clients, rounds = 24, 100
    cfg = get_config("paper_logistic").replace(fl_clients=n_clients)
    model = build_model(cfg)
    X, y = make_classification(10, cfg.d_model, 200, seed=0)
    Xte, yte = make_classification(10, cfg.d_model, 50, seed=99)
    idx, _ = label_skew_partition(y, n_clients, seed=0)
    batcher = ClientBatcher(X, y, idx, batch_size=32, k_steps=5, seed=0)
    fleet_eval = make_fleet_eval(model, {"x": Xte, "y": yte})

    # --- fleet grid: seeds x burst-length points x algorithms ----------- #
    specs = expand_grid(
        algos={"mifa": MIFA(memory="array"), "fedavg": BiasedFedAvg()},
        seeds=(0, 1, 2),
        avail_grid=({"burst": 4.0}, {"burst": 16.0}),
        make_scenario=lambda seed, burst: make_scenario(
            "gilbert_elliott", n=n_clients, seed=seed, rate=0.5,
            burst=burst).process)
    print(f"{'spec':<14}{'trials':>7}{'mean eval loss':>16}")
    for spec in specs:
        _, hist = run_fleet(spec=spec, model=model, batcher=batcher,
                            schedule=inv_t(1.0), n_rounds=rounds,
                            weight_decay=1e-3, eval_fn=fleet_eval,
                            eval_every=rounds)
        mean_loss = float(np.mean(np.asarray(hist.eval_loss[-1][1])))
        print(f"{spec.name:<14}{spec.n_trials:>7}{mean_loss:>16.4f}")

    # --- single runs on other scenario families, in-jit as well --------- #
    print(f"\n{'scenario':<28}{'regime':<22}{'mifa loss':>10}")
    for name, kwargs in [
        ("cluster", {"n_clusters": 4, "q_fail": 0.08, "q_recover": 0.08}),
        ("staged_blackout", {"dark_frac": 0.5, "stage_len": rounds // 5}),
        ("diurnal", {"period": 24.0}),
    ]:
        scen = make_scenario(name, n=n_clients, seed=7, **kwargs)
        tb = scen.process.tau_bound()
        regime = (f"deterministic t0={tb.t0:.0f}" if tb.deterministic
                  else "stochastic")
        _, hist = run_fl(model=model, algo=MIFA(memory="array"),
                         scenario=scen, batcher=batcher,
                         schedule=inv_t(1.0), n_rounds=rounds,
                         weight_decay=1e-3, seed=0)
        print(f"{name:<28}{regime:<22}{hist.train_loss[-1]:>10.4f}")


if __name__ == "__main__":
    main()
