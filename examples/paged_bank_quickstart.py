"""Paged device bank quickstart: a big-N MIFA run with small device memory.

Runs `run_fl(engine="scan")` over `PagedDeviceBank` — MIFA's per-client
memory lives in a fixed pool of device pages behind a jit-native page
table, so device bytes are (n_slots+1)·page_size·d no matter how many
clients exist; cold pages spill to host RAM and refault on demand
(docs/architecture.md §10). The same run over `DenseBank` is asserted
bit-exact: physical page placement never changes a single float.

    PYTHONPATH=src python examples/paged_bank_quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.bank import BankedMIFA, make_bank  # noqa: E402
from repro.core import run_fl  # noqa: E402
from repro.data import ProceduralBatcher  # noqa: E402
from repro.models.layers import softmax_cross_entropy  # noqa: E402

N_CLIENTS, ROUNDS, COHORT = 50_000, 40, 16
PAGE_SIZE, N_SLOTS = 64, 32        # device pool: 33 pages of 64 rows
DIM, CLASSES = 16, 2


class TinyLogistic:
    def init(self, rng):
        import jax.numpy as jnp
        return {"w": jnp.zeros((DIM, CLASSES), jnp.float32),
                "b": jnp.zeros((CLASSES,), jnp.float32)}

    def loss_fn(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return softmax_cross_entropy(logits, batch["y"]), {}


class SparseCohorts:
    """COHORT random clients per round out of N_CLIENTS (host process)."""

    def __init__(self, seed=0):
        rng = np.random.default_rng(seed)
        self.trace = np.zeros((ROUNDS, N_CLIENTS), bool)
        for t in range(ROUNDS):
            ids = np.unique(rng.integers(0, N_CLIENTS, 2 * COHORT))[:COHORT]
            self.trace[t, ids] = True
        self.n = N_CLIENTS

    def sample(self, t):
        return self.trace[t]


def run(backend, **bank_kwargs):
    batcher = ProceduralBatcher(n_clients=N_CLIENTS, dim=DIM,
                                n_classes=CLASSES, batch_size=8, k_steps=2,
                                seed=0)
    algo = BankedMIFA(make_bank(backend, **bank_kwargs))
    params, hist = run_fl(model=TinyLogistic(), algo=algo, batcher=batcher,
                          participation=SparseCohorts(), n_rounds=ROUNDS,
                          schedule=lambda t: 0.1, seed=0,
                          cohort_capacity=COHORT, engine="scan", scan_chunk=2)
    return params, hist, algo.bank


def main() -> None:
    params, hist, bank = run("paged_device",
                             page_size=PAGE_SIZE, n_slots=N_SLOTS)
    pool_rows = (N_SLOTS + 1) * PAGE_SIZE
    d = DIM * CLASSES + CLASSES
    print(f"N={N_CLIENTS:,} clients, {ROUNDS} rounds, cohort {COHORT}")
    print(f"device pool: {pool_rows} rows ({pool_rows * d * 4 / 1e3:.0f} kB)"
          f" vs dense rows {(N_CLIENTS + 1) * d * 4 / 1e6:.1f} MB")
    print(f"page faults: {bank.faults}, evictions: {bank.evictions}")
    print(f"final train loss: {hist.train_loss[-1]:.4f}")

    dense_params, dense_hist, _ = run("dense")
    same = all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(dense_params)))
    assert same and hist.train_loss == dense_hist.train_loss
    print("bit-exact vs DenseBank: True")


if __name__ == "__main__":
    main()
