"""End-to-end driver: federated training of a ~100M-param transformer.

A granite-family decoder (12L, d=768, vocab 32k ≈ 110M params) trained with
MIFA across 8 silo clients on synthetic non-iid token streams, with Bernoulli
availability. A few hundred rounds on CPU takes a while — use --rounds to
trim; the default prints progress every round.

    PYTHONPATH=src python examples/train_100m.py --rounds 200
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import MIFA, BernoulliParticipation, TauStats  # noqa: E402
from repro.core.local_update import client_updates  # noqa: E402
from repro.data import TokenBatcher  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import cosine  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mb", type=int, default=1)
    ap.add_argument("--eta0", type=float, default=0.02)
    args = ap.parse_args()

    cfg = get_config("granite-3-8b").replace(
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        vocab_size=32_768, fl_clients=args.clients, fl_local_steps=1,
        param_dtype="float32", remat=False)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    n_params = model.param_count(params)
    print(f"~100M driver: {n_params / 1e6:.1f}M params, "
          f"{args.clients} clients, seq {args.seq}")

    batcher = TokenBatcher(n_clients=args.clients, vocab=cfg.vocab_size,
                           seq_len=args.seq, batch_size=args.mb, k_steps=1,
                           stream_len=1 << 18, seed=0)
    probs = np.linspace(0.3, 1.0, args.clients)
    part = BernoulliParticipation(probs, seed=1)
    algo = MIFA(memory="array")
    state = algo.init_state(params, args.clients)
    sched = cosine(args.eta0, total=args.rounds, warmup=args.rounds // 20)
    stats = TauStats(args.clients)

    @jax.jit
    def round_fn(state, params, batch, active, eta):
        updates, losses = client_updates(model.loss_fn, params, batch, eta,
                                         K=1)
        return algo.round_step(state, params, updates, losses, active, eta)

    t0 = time.time()
    first_loss = None
    for t in range(args.rounds):
        active = part.sample(t)
        stats.update(active)
        batch = {"tokens": jnp.asarray(batcher.sample_round(t)["tokens"])}
        eta = jnp.float32(sched(t))
        state, params, m = round_fn(state, params, batch,
                                    jnp.asarray(active), eta)
        loss = float(m["loss"])
        if first_loss is None:
            first_loss = loss
        if t % 10 == 0 or t == args.rounds - 1:
            print(f"round {t:4d} loss={loss:.4f} "
                  f"active={int(active.sum())}/{args.clients} "
                  f"({(time.time() - t0) / (t + 1):.2f}s/round)")
    print(f"loss {first_loss:.3f} -> {loss:.3f} over {args.rounds} rounds, "
          f"tau_bar={stats.tau_bar:.2f}, wall={time.time() - t0:.0f}s")
    assert loss < first_loss, "training must make progress"


if __name__ == "__main__":
    main()
