"""Paper §7 reproduction with ASCII convergence curves (Fig. 2 analogue).

Runs the four algorithms on the synthetic non-iid task with label-correlated
Bernoulli availability at p_min=0.1 and plots eval-loss curves in the
terminal.

    PYTHONPATH=src python examples/paper_reproduction.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import (MIFA, BiasedFedAvg, FedAvgIS,  # noqa: E402
                        FedAvgSampling, BernoulliParticipation,
                        label_correlated_probs, run_fl)
from repro.data import (ClientBatcher, label_skew_partition,  # noqa: E402
                        make_classification)
from repro.models import build_model  # noqa: E402
from repro.optim import inv_t  # noqa: E402


def ascii_plot(curves: dict, width: int = 70, height: int = 16) -> None:
    all_y = np.concatenate([np.asarray(v) for v in curves.values()])
    lo, hi = float(all_y.min()), float(np.percentile(all_y, 98))
    grid = [[" "] * width for _ in range(height)]
    marks = "MBSI*"
    for (name, ys), mark in zip(curves.items(), marks):
        ys = np.asarray(ys)
        xs = np.linspace(0, width - 1, len(ys)).astype(int)
        for x, yv in zip(xs, ys):
            r = int((min(yv, hi) - lo) / max(hi - lo, 1e-9) * (height - 1))
            grid[height - 1 - r][x] = mark
    print(f"eval loss [{lo:.2f}..{hi:.2f}]  " +
          "  ".join(f"{m}={n}" for (n, _), m in zip(curves.items(), marks)))
    for row in grid:
        print("|" + "".join(row))
    print("+" + "-" * width + "-> rounds")


def main() -> None:
    n_clients, rounds, p_min = 50, 150, 0.1
    cfg = get_config("paper_logistic").replace(fl_clients=n_clients)
    model = build_model(cfg)
    X, y = make_classification(10, cfg.d_model, 300, seed=0)
    Xte, yte = make_classification(10, cfg.d_model, 60, seed=9)
    idx, labels = label_skew_partition(y, n_clients, seed=0)
    probs = label_correlated_probs(labels, p_min=p_min)
    batcher = ClientBatcher(X, y, idx, batch_size=50, k_steps=5, seed=0)

    def eval_fn(params):
        b = {"x": jnp.asarray(Xte), "y": jnp.asarray(yte)}
        loss, _ = model.loss_fn(params, b)
        return float(loss), float(model.accuracy(params, b))

    curves = {}
    for name, algo, clock in [
        ("MIFA", MIFA(memory="array"), False),
        ("Biased", BiasedFedAvg(), False),
        ("Sampling25", FedAvgSampling(s=25), True),
        ("IS", FedAvgIS(tuple(probs.tolist())), False),
    ]:
        part = BernoulliParticipation(probs, seed=11)
        _, hist = run_fl(model=model, algo=algo, participation=part,
                         batcher=batcher, schedule=inv_t(1.0),
                         n_rounds=rounds, weight_decay=1e-3, seed=0,
                         eval_fn=eval_fn, eval_every=5,
                         uses_update_clock=clock)
        curves[name] = [l for _, l in hist.eval_loss]
        print(f"{name:<12} final eval loss {curves[name][-1]:.4f}")
    ascii_plot(curves)


if __name__ == "__main__":
    main()
