"""Quickstart: federated training under device unavailability, in ~40 lines.

Trains a logistic model over 20 simulated devices with label-skewed data and
Bernoulli availability, comparing MIFA against biased FedAvg and the original
sampling-based FedAvg — the paper's headline comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import (MIFA, BiasedFedAvg, FedAvgSampling,  # noqa: E402
                        BernoulliParticipation, label_correlated_probs,
                        run_fl)
from repro.data import (ClientBatcher, label_skew_partition,  # noqa: E402
                        make_classification)
from repro.models import build_model  # noqa: E402
from repro.optim import inv_t  # noqa: E402


def main() -> None:
    n_clients, rounds = 20, 120
    cfg = get_config("paper_logistic").replace(fl_clients=n_clients)
    model = build_model(cfg)

    # non-iid data: each device holds only two classes
    X, y = make_classification(10, cfg.d_model, 200, seed=0)
    Xte, yte = make_classification(10, cfg.d_model, 50, seed=99)
    idx, labels = label_skew_partition(y, n_clients, seed=0)
    probs = label_correlated_probs(labels, p_min=0.1)  # stragglers exist
    batcher = ClientBatcher(X, y, idx, batch_size=32, k_steps=5, seed=0)

    def eval_fn(params):
        batch = {"x": jnp.asarray(Xte), "y": jnp.asarray(yte)}
        loss, _ = model.loss_fn(params, batch)
        return float(loss), float(model.accuracy(params, batch))

    print(f"{'algorithm':<22}{'eval loss':>10}{'accuracy':>10}{'tau_bar':>9}")
    for name, algo, clock in [
        ("MIFA (paper)", MIFA(memory="array"), False),
        ("MIFA (delta memory)", MIFA(memory="delta"), False),
        ("biased FedAvg", BiasedFedAvg(), False),
        ("FedAvg sampling S=10", FedAvgSampling(s=10), True),
    ]:
        part = BernoulliParticipation(probs, seed=42)
        _, hist = run_fl(model=model, algo=algo, participation=part,
                         batcher=batcher, schedule=inv_t(1.0),
                         n_rounds=rounds, weight_decay=1e-3, seed=0,
                         eval_fn=eval_fn, eval_every=rounds,
                         uses_update_clock=clock)
        print(f"{name:<22}{hist.eval_loss[-1][1]:>10.4f}"
              f"{hist.eval_acc[-1][1]:>10.3f}{hist.tau_bar:>9.2f}")


if __name__ == "__main__":
    main()
