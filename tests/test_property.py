"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import MIFA, BiasedFedAvg, tau_matrix
from repro.core.quantized_memory import dequantize_leaf, quantize_leaf
from repro.models.layers import softmax_cross_entropy

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(1, 6))
def test_mifa_fedavg_equivalence_property(seed, n, rounds):
    """Remark 5.1 as a property: all-active MIFA == FedAvg for random trees."""
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (3,))}
    am, af = MIFA(memory="array"), BiasedFedAvg()
    sm, sf = am.init_state(params, n), af.init_state(params, n)
    pm = pf = params
    for t in range(rounds):
        key, k = jax.random.split(key)
        u = {"w": jax.random.normal(k, (n, 3))}
        active = jnp.ones(n, bool)
        eta = jnp.float32(0.1)
        sm, pm, _ = am.round_step(sm, pm, u, jnp.zeros(n), active, eta)
        sf, pf, _ = af.round_step(sf, pf, u, jnp.zeros(n), active, eta)
    np.testing.assert_allclose(np.asarray(pm["w"]), np.asarray(pf["w"]),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(2, 10))
def test_mifa_delta_equivalence_property(seed, n, rounds):
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (4,))}
    a1, a2 = MIFA(memory="array"), MIFA(memory="delta")
    s1, s2 = a1.init_state(params, n), a2.init_state(params, n)
    p1 = p2 = params
    for t in range(rounds):
        key, k1, k2 = jax.random.split(key, 3)
        u = {"w": jax.random.normal(k1, (n, 4))}
        active = (jnp.ones(n, bool) if t == 0
                  else jax.random.bernoulli(k2, 0.5, (n,)))
        eta = jnp.float32(0.1)
        s1, p1, _ = a1.round_step(s1, p1, u, jnp.zeros(n), active, eta)
        s2, p2, _ = a2.round_step(s2, p2, u, jnp.zeros(n), active, eta)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-4, atol=1e-5)


@given(st.integers(0, 2**31 - 1))
def test_stochastic_rounding_unbiased(seed):
    """E[dequant(quant(x))] == x — the property MIFA's analysis needs."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 16)) * 0.37
    acc = np.zeros((1, 16))
    reps = 300
    for i in range(reps):
        q, s = quantize_leaf(jax.random.fold_in(key, i), x)
        acc += np.asarray(dequantize_leaf(q, s))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(acc / reps, np.asarray(x),
                               atol=4 * scale / np.sqrt(reps) + 1e-7)


@given(st.integers(0, 2**31 - 1), st.integers(1, 40), st.integers(1, 10))
def test_tau_matrix_invariants(seed, T, n):
    rng = np.random.default_rng(seed)
    masks = rng.random((T, n)) < rng.random(n)
    masks[0] = True
    tm = tau_matrix(masks)
    assert (tm >= 0).all()
    assert (tm[masks] == 0).all()           # active => tau 0
    if T > 1:
        inc = tm[1:][~masks[1:]] - tm[:-1][~masks[1:]]
        assert (inc == 1).all()             # inactive => tau increments
    assert tm.max() < T                     # bounded by rounds since round 0


@given(st.integers(0, 2**31 - 1), st.integers(2, 5), st.integers(2, 50))
def test_cross_entropy_matches_numpy(seed, b, v):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (b, v)) * 3
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, v)
    got = float(softmax_cross_entropy(logits, labels))
    ln = np.asarray(logits, np.float64)
    p = np.exp(ln - ln.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = -np.log(p[np.arange(b), np.asarray(labels)]).mean()
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_client_update_is_grad_sum(seed, k):
    """G^i == Σ_k ∇f(w_{t,k}) == (w_t - w_{t,K})/η  (paper Algorithm 1)."""
    from repro.core.local_update import device_update
    key = jax.random.PRNGKey(seed)

    def loss_fn(p, mb):
        return jnp.sum((p["w"] - mb) ** 2), {}

    params = {"w": jax.random.normal(key, (3,))}
    mbs = jax.random.normal(jax.random.fold_in(key, 1), (k, 3))
    eta = 0.05
    G, _ = device_update(loss_fn, params, mbs, jnp.float32(eta))
    # replay manually
    w = np.asarray(params["w"], np.float64)
    for i in range(k):
        g = 2 * (w - np.asarray(mbs[i], np.float64))
        w = w - eta * g
    manual = (np.asarray(params["w"], np.float64) - w) / eta
    np.testing.assert_allclose(np.asarray(G["w"]), manual, rtol=1e-4, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(1, 6))
def test_bank_cohort_rounds_match_dense_mifa_property(seed, n, rounds):
    """fp32 MemoryBank cohort rounds == dense MIFA('array') for random
    trees, cohorts, and round counts (the bank acceptance property)."""
    from repro.bank import DenseBank
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (3,))}
    mifa = MIFA(memory="array")
    sm = mifa.init_state(params, n)
    bank = DenseBank()
    bs = bank.init(params, n)
    pm = params
    for t in range(rounds):
        key, k1, k2 = jax.random.split(key, 3)
        u = {"w": jax.random.normal(k1, (n, 3))}
        active = np.array(jax.random.bernoulli(k2, 0.5, (n,)))
        sm, pm, _ = mifa.round_step(sm, pm, u, jnp.zeros(n),
                                    jnp.asarray(active), jnp.float32(0.1))
        ids = np.flatnonzero(active)
        bs = bank.scatter(bs, ids, {"w": u["w"][ids]})
    np.testing.assert_allclose(
        np.asarray(bank.mean_g(bs)["w"]),
        np.asarray(jnp.mean(sm["G"]["w"], 0)), rtol=1e-5, atol=1e-6)
