import math

import jax.numpy as jnp
import numpy as np

from repro.optim import (cosine, inv_t, nonconvex_fixed,
                         paper_strongly_convex, sgd_init, sgd_step)


def test_inv_t_matches_paper_experiments():
    s = inv_t(0.1)
    assert s(1) == 0.1
    assert s(10) == 0.1 / 10


def test_strongly_convex_schedule():
    mu, L, K = 0.1, 1.0, 5
    s = paper_strongly_convex(mu, L, K, t0=0.0)
    a = 100.0 * (L / mu) ** 1.5
    assert s(1) == 4.0 / (mu * K * (1 + a))
    assert s(100) < s(1)


def test_nonconvex_schedule_constant():
    s = nonconvex_fixed(N=10, K=5, T=1000, L=1.0, nu_bar=3.0)
    assert s(1) == s(999)
    assert s(1) == math.sqrt(10 / (5 * 1000 * 1.0 * 4.0)) / 5


def test_cosine_warmup():
    s = cosine(1.0, total=100, warmup=10)
    assert s(0) < s(9) <= 1.0
    assert abs(s(10) - 1.0) < 1e-9
    assert s(100) < 1e-9 + 0.0


def test_sgd_momentum():
    params = {"w": jnp.zeros((2,))}
    grads = {"w": jnp.ones((2,))}
    st = sgd_init(params, momentum=0.9)
    p1, st = sgd_step(params, grads, st, eta=0.1, momentum=0.9)
    np.testing.assert_allclose(p1["w"], [-0.1, -0.1])
    p2, st = sgd_step(p1, grads, st, eta=0.1, momentum=0.9)
    np.testing.assert_allclose(p2["w"], [-0.29, -0.29], rtol=1e-6)


def test_sgd_weight_decay():
    params = {"w": jnp.ones((1,))}
    grads = {"w": jnp.zeros((1,))}
    p, _ = sgd_step(params, grads, {}, eta=0.1, weight_decay=0.5)
    np.testing.assert_allclose(p["w"], [0.95])
