"""Discrete-event runtime simulator: determinism, policy ordering,
latency models, event-heap edge cases, and the Assumption-4 property of
the blackout patterns."""
import numpy as np
import pytest

from repro.core import (MIFA, AdversarialParticipation, BiasedFedAvg,
                        RoundRunner, TraceParticipation, tau_matrix)
from repro.optim import inv_t
from repro.sim import (Deadline, EventQueue, FedSimEngine, Impatient,
                       LognormalLatency, ShiftedExponentialLatency, SimConfig,
                       TraceLatency, WaitForAll, WaitForS,
                       tiered_shifted_exponential)

N = 9


def blackout(seed=0):
    periods = np.array([4] * 3 + [3] * 3 + [8] * 3)
    offs = np.array([3] * 3 + [1] * 3 + [1] * 3)
    phases = np.random.default_rng(seed).integers(0, 8, N)
    return AdversarialParticipation(N, periods, offs, phases)


@pytest.fixture
def make_runner(tiny_problem):
    def _make(algo, seed=0):
        model, batcher = tiny_problem(n_clients=N, n_per_class=60)
        return RoundRunner(model=model, algo=algo, batcher=batcher,
                           schedule=inv_t(1.0), weight_decay=1e-3, seed=seed)
    return _make


@pytest.fixture
def make_engine(make_runner):
    def _make(policy, algo, seed=0, participation=None, latency=None,
              config=None):
        return FedSimEngine(
            make_runner(algo, seed),
            policy, participation if participation is not None else blackout(),
            latency if latency is not None
            else tiered_shifted_exponential(N, seed=7),
            config=config or SimConfig(epoch_s=4.0), seed=13 + seed)
    return _make


# --------------------------------------------------------------------------- #
# event queue
# --------------------------------------------------------------------------- #

def test_event_queue_fifo_on_ties():
    q = EventQueue()
    q.push(5.0, "arrival", client=0)
    q.push(1.0, "arrival", client=1)
    q.push(1.0, "arrival", client=2)
    popped = [q.pop() for _ in range(3)]
    assert [e.client for e in popped] == [1, 2, 0]
    assert popped[0].seq < popped[1].seq


# --------------------------------------------------------------------------- #
# engine determinism + simulated-seconds axis
# --------------------------------------------------------------------------- #

def test_engine_deterministic_event_sequence(make_engine):
    logs = []
    for _ in range(2):
        eng = make_engine(Impatient(), MIFA(memory="array"))
        _, hist = eng.run(8)
        logs.append((list(eng.event_log), list(hist.sim_seconds)))
    assert logs[0][0] == logs[1][0]        # identical event sequence
    assert logs[0][1] == logs[1][1]        # identical round close times


def test_sim_seconds_strictly_increasing(make_engine):
    eng = make_engine(WaitForS(s=3), BiasedFedAvg())
    _, hist = eng.run(10)
    t = np.asarray(hist.sim_seconds)
    assert len(t) == 10 and np.all(np.diff(t) > 0)
    assert len(eng.runner.stats.times) == 10   # TauStats timestamped view
    times, taus = eng.runner.stats.timeline()
    assert taus.shape == (10, N) and np.all(np.diff(times) > 0)


def test_impatient_never_slower_than_wait_for_all(make_engine):
    rounds = 10
    eng_imp = make_engine(Impatient(), BiasedFedAvg())
    eng_all = make_engine(WaitForAll(), BiasedFedAvg())
    eng_imp.run(rounds)
    eng_all.run(rounds)
    # same seeds => identical latency draws; waiting for blacked-out devices
    # can only lengthen each round
    imp = [r["duration_s"] for r in eng_imp.round_log]
    al = [r["duration_s"] for r in eng_all.round_log]
    assert all(a <= b + 1e-9 for a, b in zip(imp, al))
    assert eng_imp.now < eng_all.now


def test_deadline_drops_late_responders(make_engine):
    eng = make_engine(Deadline(deadline_s=0.5), BiasedFedAvg())
    eng.run(6)
    # 0.5s deadline < slow-tier shift (2.0s): slow devices must be dropped
    assert all(r["duration_s"] == pytest.approx(0.5) for r in eng.round_log)
    assert any(r["n_late"] > 0 for r in eng.round_log[1:])
    assert all(r["n_applied"] < N for r in eng.round_log[1:])


def test_wait_for_s_applies_exactly_s(make_engine):
    eng = make_engine(WaitForS(s=4), BiasedFedAvg())
    eng.run(6)
    assert all(r["n_applied"] == 4 for r in eng.round_log)


def test_max_sim_seconds_stops_at_first_round_close_past_budget(make_engine):
    ref = make_engine(WaitForS(s=3), BiasedFedAvg())
    ref.run(20)
    budget = ref.round_log[4]["t_close"]    # exactly 5 rounds fit
    eng = make_engine(WaitForS(s=3), BiasedFedAvg())
    _, hist = eng.run(20, max_sim_seconds=budget)
    # checked at round close: stops at the first round ending >= budget,
    # which may overshoot by that round's duration but never runs another
    assert len(hist.rounds) == 5
    assert hist.sim_seconds[-1] >= budget
    assert hist.sim_seconds[-2] < budget


def test_round0_all_devices_respond(make_engine):
    eng = make_engine(Impatient(), MIFA(memory="array"))
    rec = eng.run_round(0)
    assert rec["n_applied"] == N   # paper Remark 5.2: round 0 all active


# --------------------------------------------------------------------------- #
# edge cases: ties, zero latency, empty cohorts, exhausted traces
# --------------------------------------------------------------------------- #

def test_simultaneous_arrivals_resolve_fifo(make_engine):
    """All devices arrive at the exact same instant: the heap must break
    ties by push order (client id order at dispatch), deterministically."""
    always_on = TraceParticipation(np.ones((1, N), bool))
    lat = TraceLatency(np.full((1, N), 1.5))
    logs = []
    for _ in range(2):
        eng = make_engine(WaitForAll(), BiasedFedAvg(),
                          participation=always_on, latency=lat)
        eng.run(3)
        logs.append(list(eng.event_log))
        arrivals = [e for e in eng.event_log if e[2] == "arrival"
                    and e[4] == 1]
        # one tie-broken arrival per device, in dispatch (client-id) order
        assert [e[3] for e in arrivals] == list(range(N))
        assert len({e[0] for e in arrivals}) == 1          # same timestamp
        seqs = [e[1] for e in arrivals]
        assert seqs == sorted(seqs)
    assert logs[0] == logs[1]


def test_zero_latency_devices_close_instantly(make_engine):
    """RTT=0 for everyone: rounds close at dispatch time (duration 0) and
    still apply every available device; only server overhead advances t."""
    always_on = TraceParticipation(np.ones((1, N), bool))
    lat = TraceLatency(np.zeros((1, N)))
    cfg = SimConfig(epoch_s=4.0, server_overhead_s=0.25)
    eng = make_engine(WaitForAll(), BiasedFedAvg(), participation=always_on,
                      latency=lat, config=cfg)
    _, hist = eng.run(4)
    assert all(r["duration_s"] == 0.0 for r in eng.round_log)
    assert all(r["n_applied"] == N for r in eng.round_log)
    np.testing.assert_allclose(hist.sim_seconds,
                               [0.0, 0.25, 0.5, 0.75])


def test_deadline_with_empty_cohort(make_engine):
    """cohort_size=0 dispatches nobody: the round must still close at the
    deadline with zero applied updates instead of crashing or blocking."""
    eng = make_engine(Deadline(deadline_s=1.0, cohort_size=0),
                      BiasedFedAvg())
    eng.run(3)
    assert all(r["n_applied"] == 0 for r in eng.round_log)
    assert all(r["n_dispatched"] == 0 for r in eng.round_log)
    assert all(r["duration_s"] == pytest.approx(1.0) for r in eng.round_log)


def test_trace_participation_exhaustion_mid_run(make_engine):
    """A trace shorter than the simulated horizon clamps to its last row;
    a device dark in that row never returns — WaitForAll must not block on
    it past the lookahead, and later rounds apply N-1 devices."""
    trace = np.ones((2, N), bool)
    trace[1, 0] = False                      # device 0 dark from epoch 1 on
    part = TraceParticipation(trace)
    lat = TraceLatency(np.full((1, N), 0.5))
    cfg = SimConfig(epoch_s=1.0, max_lookahead_epochs=25)
    eng = make_engine(WaitForAll(), BiasedFedAvg(), participation=part,
                      latency=lat, config=cfg)
    eng.run(5)
    assert eng.round_log[0]["n_applied"] == N            # forced round 0
    assert all(r["n_applied"] == N - 1 for r in eng.round_log[2:])
    assert np.isfinite(eng.now)


# --------------------------------------------------------------------------- #
# latency models
# --------------------------------------------------------------------------- #

def test_latency_models_shapes_and_determinism():
    for make in (lambda s: ShiftedExponentialLatency(0.5, 1.0, n=N, seed=s),
                 lambda s: LognormalLatency(0.0, 0.5, comm=0.1, n=N, seed=s),
                 lambda s: tiered_shifted_exponential(N, seed=s)):
        a, b = make(3), make(3)
        sa = np.stack([a.sample(t) for t in range(5)])
        sb = np.stack([b.sample(t) for t in range(5)])
        assert sa.shape == (5, N) and np.all(sa > 0)
        np.testing.assert_array_equal(sa, sb)


def test_trace_latency_replays_and_clamps():
    trace = np.arange(6, dtype=float).reshape(2, 3)
    lat = TraceLatency(trace)
    np.testing.assert_array_equal(lat.sample(0), [0, 1, 2])
    np.testing.assert_array_equal(lat.sample(7), [3, 4, 5])
    trace[0, 0] = 99.0                      # no aliasing of caller's array
    assert lat.sample(0)[0] == 0.0


# --------------------------------------------------------------------------- #
# Assumption 4 property for the periodic-blackout patterns
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(4))
def test_adversarial_blackouts_satisfy_assumption4(seed):
    """τ(t,i) <= t0 + t/b with t0 = max blackout length, for any b >= 1."""
    rng = np.random.default_rng(seed)
    n = 8
    periods = rng.integers(2, 12, n)
    offs = np.minimum(rng.integers(1, 10, n), periods - 1)
    p = AdversarialParticipation(n, periods, offs,
                                 rng.integers(0, 12, n))
    masks = np.stack([p.sample(t) for t in range(300)])
    tm = tau_matrix(masks)
    t0 = int(offs.max())
    assert tm.max() <= t0                   # bounded staleness
    t_idx = np.arange(300)[:, None]
    for b in (1, 4, 16):
        assert np.all(tm <= t0 + t_idx / b)
