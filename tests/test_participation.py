import numpy as np
import pytest

from repro.core.participation import (AdversarialParticipation,
                                      BernoulliParticipation,
                                      TraceParticipation, TauStats,
                                      label_correlated_probs, tau_matrix)


def test_bernoulli_first_round_all_active():
    p = BernoulliParticipation(np.full(20, 0.01), seed=0)
    assert p.sample(0).all()


def test_bernoulli_marginal_rate():
    probs = np.linspace(0.1, 0.9, 10)
    p = BernoulliParticipation(probs, seed=0)
    masks = np.stack([p.sample(t) for t in range(1, 4001)])
    rates = masks.mean(0)
    assert np.allclose(rates, probs, atol=0.05)


def test_label_correlated_probs_semantics():
    labels = np.array([[0, 1], [9, 9], [4, 7]])
    p = label_correlated_probs(labels, p_min=0.1)
    assert p[0] == pytest.approx(0.1)      # straggler: smallest labels
    assert p[1] == pytest.approx(1.0)
    assert np.all((p >= 0.1) & (p <= 1.0))
    assert p[0] < p[2] < p[1]              # smaller labels participate less


def test_tau_stats_match_matrix():
    rng = np.random.default_rng(0)
    masks = rng.random((50, 8)) < 0.5
    masks[0] = True
    tm = tau_matrix(masks)
    st = TauStats(8)
    for t in range(50):
        st.update(masks[t])
    assert st.tau_bar == pytest.approx(tm.mean())
    assert st.tau_max == tm.max()
    assert st.d_bar == pytest.approx((tm.astype(float) ** 2).mean())
    assert st.d_max_bar == pytest.approx((tm.max(0).astype(float) ** 2).mean())


def test_adversarial_satisfies_assumption4():
    n = 6
    periods = np.array([4, 5, 6, 7, 8, 9])
    offs = np.array([1, 2, 3, 3, 4, 4])
    p = AdversarialParticipation(n, periods, offs)
    masks = np.stack([p.sample(t) for t in range(200)])
    tm = tau_matrix(masks)
    # τ(t,i) is bounded by the longest blackout => Assumption 4 with t0=max(offs)
    assert tm.max() <= offs.max()
    assert masks[0].all()


def test_scenario_ports_match_legacy_processes():
    """The jit-native scenario ports reproduce the legacy host classes:
    Adversarial masks are EXACTLY equal on both surfaces, and Bernoulli
    marginal rates match (the RNG streams legitimately differ)."""
    import jax.numpy as jnp
    from repro.scenarios import Adversarial, Bernoulli

    n = 6
    periods = np.array([4, 5, 6, 7, 8, 9])
    offs = np.array([1, 2, 3, 3, 4, 4])
    phases = np.arange(n)
    legacy = AdversarialParticipation(n, periods, offs, phases)
    port = Adversarial(periods, offs, phases=phases, n=n)
    host = port.host_sampler()
    sample = port.sample_fn()
    state = port.init_state()
    for t in range(100):
        want = legacy.sample(t)
        np.testing.assert_array_equal(host.sample(t), want)
        mask, state = sample(port.key, jnp.int32(t), state)
        np.testing.assert_array_equal(np.asarray(mask), want)

    probs = np.linspace(0.2, 0.9, 8)
    b = Bernoulli(probs, seed=0).host_sampler()
    rates = np.stack([b.sample(t) for t in range(1, 3001)]).mean(0)
    assert np.allclose(rates, probs, atol=0.05)


def test_trace_participation_forces_first_round():
    tr = np.zeros((5, 3), bool)
    p = TraceParticipation(tr)
    assert p.sample(0).all()
    assert not p.sample(1).any()


def test_trace_participation_does_not_mutate_input():
    tr = np.zeros((5, 3), bool)
    TraceParticipation(tr)
    assert not tr.any()          # row 0 forced active only on the copy


def test_tau_grows_when_inactive():
    masks = np.array([[True, True], [True, False], [True, False], [True, True]])
    tm = tau_matrix(masks)
    assert tm[:, 0].tolist() == [0, 0, 0, 0]
    assert tm[:, 1].tolist() == [0, 1, 2, 0]
