"""launch.steps train_step semantics == core MIFA round (single device)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import MIFA
from repro.core.local_update import client_updates
from repro.launch.steps import make_train_step
from repro.models import build_model

N, K, MB, S = 4, 2, 2, 32


def _setup(arch="granite_3_8b", sequential=False):
    cfg = get_smoke_config(arch).replace(
        compute_dtype="float32", param_dtype="float32",
        fl_clients=N, fl_local_steps=K, sequential_clients=sequential,
        memory_dtype="float32")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = {"tokens": jax.random.randint(rng, (N, K, MB, S), 0,
                                          cfg.vocab_size)}
    G = jax.tree.map(lambda p: jnp.zeros((N,) + p.shape), params)
    active = jnp.array([True, False, True, True])
    eta = jnp.float32(0.05)
    return cfg, model, params, G, batch, active, eta


def test_vmap_train_step_matches_core_mifa():
    cfg, model, params, G, batch, active, eta = _setup()
    step = make_train_step(model, cfg, N, K)
    p1, G1, m1 = jax.jit(step)(params, G, batch, active, eta)

    algo = MIFA(memory="array", memory_dtype="float32")
    state = {"G": G, "t": jnp.zeros((), jnp.int32)}
    updates, losses = client_updates(model.loss_fn, params, batch, eta, K=K)
    state2, p2, m2 = algo.round_step(state, params, updates, losses, active,
                                     eta)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)
    for a, b in zip(jax.tree.leaves(G1), jax.tree.leaves(state2["G"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)


def test_sequential_train_step_matches_vmap():
    """The memory-optimized client scan computes the same round."""
    cfg, model, params, G, batch, active, eta = _setup()
    step_v = make_train_step(model, cfg, N, K)
    p1, G1, m1 = jax.jit(step_v)(params, G, batch, active, eta)

    cfg_s = cfg.replace(sequential_clients=True)
    step_s = make_train_step(model, cfg_s, N, K)
    p2, G2, m2 = jax.jit(step_s)(params, G, batch, active, eta)

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=1e-6)
    for a, b in zip(jax.tree.leaves(G1), jax.tree.leaves(G2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def test_inactive_clients_do_not_move_their_memory():
    cfg, model, params, G, batch, active, eta = _setup()
    G = jax.tree.map(lambda g: g + 7.0, G)  # sentinel stale updates
    step = make_train_step(model, cfg, N, K)
    _, G1, _ = jax.jit(step)(params, G, batch, active, eta)
    for leaf in jax.tree.leaves(G1):
        # client 1 is inactive: its stored update must remain the sentinel
        np.testing.assert_allclose(np.asarray(leaf)[1], 7.0, atol=1e-6)
