"""End-to-end dry-run: lower+compile one (arch x shape) on the production mesh
in a subprocess (XLA_FLAGS isolation), verifying the JSON artifact schema."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.parametrize("arch,shape", [("mamba2_1_3b", "decode_32k")])
def test_dryrun_single_combo(tmp_path, arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "pod", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{arch}__{shape}__pod.json"))
    assert rec["status"] == "ok"
    assert rec["n_chips"] == 256
    r = rec["roofline"]
    assert r["bottleneck"] in ("compute", "memory", "collective")
    assert r["step_time_lower_bound_s"] > 0
    m = rec["analysis"]["memory"]
    assert m["peak_estimate_bytes"] > 0
    assert rec["params_total"] > 1e9  # mamba2-1.3b


def test_skip_reasons_documented(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "hubert_xlarge", "--shape", "decode_32k", "--mesh", "pod",
         "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=240)
    assert out.returncode == 0
    rec = json.load(open(tmp_path / "hubert_xlarge__decode_32k__pod.json"))
    assert rec["status"] == "skip"
    assert "encoder-only" in rec["reason"]
