"""Trace replay, elastic fleets, and whole-run checkpoint/resume.

The anchor properties for `scenarios.trace_replay` / `scenarios.elastic` /
`checkpoint.run_state`:

  * the v1 trace file round-trips (array and streamed-iterator writers),
    clamps past the end, and refuses malformed inputs;
  * `TraceReplay` draws bit-identical masks on the host and jit surfaces
    across window re-pages, under every engine and every `scan_chunk` —
    and the scan engine streams windows without EVER materialising a
    (T, N) mask matrix (monkeypatch-verified on the read primitive);
  * `ElasticProcess` is exactly `inner AND presence`, composes over
    trace replay (window protocol forwarded), and classifies departures
    as the arbitrary (no τ-bound) regime;
  * a run killed mid-horizon and resumed from its latest snapshot
    produces fp32 bit-exact params + history vs the uninterrupted run,
    for dense MIFA and both banked (cohort) backends — the PR's
    durability acceptance gate.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bank import BankedMIFA, DenseBank, make_bank
from repro.checkpoint import (CheckpointSpec, checkpoint_path,
                              latest_checkpoint, list_checkpoints)
from repro.core import MIFA, run_fl
from repro.scenarios import (ElasticProcess, GilbertElliott, Scenario,
                             TraceReplay, elastic_capacity, make_scenario,
                             open_trace, staged_arrivals, synthesize_trace,
                             write_trace)
from repro.scenarios.elastic import NEVER
from repro.scenarios.trace_replay import TraceFile

N, T = 8, 12

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "fixtures", "device_trace_n20_t64.npy")


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    """A small synthesized trace with churn shared by the module's tests."""
    p = str(tmp_path_factory.mktemp("traces") / "dev.npy")
    return synthesize_trace(p, n=N, horizon=40, seed=5, rate=0.6,
                            burst=3.0, churn_frac=0.25)


def _kw(tiny_problem, **over):
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=T,
              weight_decay=1e-3, seed=0, cohort_capacity=N)
    kw.update(over)
    return kw


def _assert_same(run_a, run_b):
    (pa, ha), (pb, hb) = run_a, run_b
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ha.train_loss == hb.train_loss
    assert ha.n_active == hb.n_active
    assert ha.rounds == hb.rounds
    assert (ha.tau_bar, ha.tau_max) == (hb.tau_bar, hb.tau_max)


# --------------------------------------------------------------------------- #
# trace file format
# --------------------------------------------------------------------------- #

def test_write_read_roundtrip_array(tmp_path):
    rng = np.random.default_rng(0)
    masks = rng.random((17, 11)) < 0.5
    p = write_trace(str(tmp_path / "t"), masks)      # .npy appended
    assert p.endswith(".npy") and os.path.exists(p[:-4] + ".json")
    tf = open_trace(p)
    assert (tf.n_rounds, tf.n_clients) == (17, 11)
    np.testing.assert_array_equal(tf.read_block(0, 17), masks)
    # partial block + clamp past the end: rows repeat the last row
    np.testing.assert_array_equal(tf.read_block(15, 5),
                                  masks[[15, 16, 16, 16, 16]])


def test_write_read_roundtrip_iterator(tmp_path):
    rng = np.random.default_rng(1)
    masks = rng.random((10, 9)) < 0.4
    p = write_trace(str(tmp_path / "t.npy"),
                    iter([masks[:4], masks[4:7], masks[7:]]),
                    n_clients=9, n_rounds=10)
    np.testing.assert_array_equal(open_trace(p).read_block(0, 10), masks)


def test_write_trace_rejects_malformed(tmp_path):
    with pytest.raises(ValueError, match="n_clients"):
        write_trace(str(tmp_path / "a"), iter([np.ones((2, 3), bool)]))
    with pytest.raises(ValueError, match="sum to"):
        write_trace(str(tmp_path / "b"), iter([np.ones((2, 3), bool)]),
                    n_clients=3, n_rounds=5)
    with pytest.raises(ValueError, match="block must be"):
        write_trace(str(tmp_path / "c"), iter([np.ones((2, 4), bool)]),
                    n_clients=3, n_rounds=2)
    # a failed write leaves no torn payload behind
    assert not any(f.endswith(".npy") for f in os.listdir(tmp_path))


def test_open_trace_rejects_format_mismatch(tmp_path):
    p = write_trace(str(tmp_path / "t"), np.ones((3, 4), bool))
    side = p[:-4] + ".json"
    with open(side, "w") as f:
        f.write('{"format": "not-a-trace", "n_clients": 4, "n_rounds": 3}')
    with pytest.raises(ValueError, match="expected format"):
        open_trace(p)


def test_committed_fixture_is_valid():
    """The CI smoke fixture: correct sidecar, some churned-out devices."""
    tf = open_trace(FIXTURE)
    assert (tf.n_clients, tf.n_rounds) == (20, 64)
    block = tf.read_block(0, 64)
    assert (~block[-1]).any()        # churned devices dark at the end
    proc = TraceReplay(FIXTURE)
    assert not proc.tau_bound().deterministic      # arbitrary regime


# --------------------------------------------------------------------------- #
# TraceReplay: surfaces, windows, resize guard
# --------------------------------------------------------------------------- #

def test_trace_replay_host_vs_jit_across_repages(trace_path):
    """Window W=4 forces re-pages every 4 rounds; both surfaces stay
    bit-identical through them and past the end of the trace."""
    proc = TraceReplay(trace_path, window=4)
    sample = jax.jit(proc.sample_fn())
    state = proc.init_state()
    host = proc.host_sampler()
    raw = open_trace(trace_path)
    for t in range(55):                     # horizon is 40: exercises clamp
        if t % 4 == 0:                      # engine re-pages chunk-aligned
            state = proc.load_window(state, t)
        mask, state = sample(proc.key, jnp.int32(t), state)
        np.testing.assert_array_equal(np.asarray(mask), host.sample(t),
                                      err_msg=f"t={t}")
        if t > 0:
            np.testing.assert_array_equal(
                host.sample(t), raw.read_block(t, 1)[0], err_msg=f"t={t}")


def test_trace_replay_rejects_resize(trace_path):
    with pytest.raises(ValueError, match="cannot resize"):
        TraceReplay(trace_path, n=N + 1)
    with pytest.raises(ValueError, match="window"):
        TraceReplay(trace_path, window=0)


def test_registry_synthesizes_and_caches(tmp_path):
    scen = make_scenario("trace_replay", n=6, seed=2, horizon=20,
                         cache_dir=str(tmp_path))
    scen2 = make_scenario("trace_replay", n=6, seed=2, horizon=20,
                          cache_dir=str(tmp_path))
    assert scen.process.trace.path == scen2.process.trace.path
    assert len([f for f in os.listdir(tmp_path) if f.endswith(".npy")]) == 1


# --------------------------------------------------------------------------- #
# engines: chunk invariance, no (T, N) materialisation
# --------------------------------------------------------------------------- #

def _trace_scen(trace_path, window=T):
    return Scenario(TraceReplay(trace_path, window=window), name="trace")


@pytest.mark.parametrize("chunk", [1, 7, T])
def test_scan_chunk_invariance_vs_loop(tiny_problem, trace_path, chunk):
    kw = _kw(tiny_problem)
    loop = run_fl(algo=MIFA(memory="array"), engine="loop",
                  scenario=_trace_scen(trace_path), **kw)
    scan = run_fl(algo=MIFA(memory="array"), engine="scan_strict",
                  scan_chunk=chunk, scenario=_trace_scen(trace_path), **kw)
    _assert_same(loop, scan)


def test_scan_chunk_wider_than_window_raises(tiny_problem, trace_path):
    with pytest.raises(ValueError, match="window"):
        run_fl(algo=MIFA(memory="array"), engine="scan_strict", scan_chunk=8,
               scenario=_trace_scen(trace_path, window=4),
               **_kw(tiny_problem))


def test_scan_never_materialises_full_trace(tiny_problem, trace_path,
                                            monkeypatch):
    """Every read of the backing store is at most one window long — no
    (T, N) mask matrix ever exists; windows re-page per chunk."""
    window, lengths = 4, []
    orig = TraceFile.read_block

    def recording(self, t0, length):
        lengths.append(length)
        return orig(self, t0, length)
    monkeypatch.setattr(TraceFile, "read_block", recording)
    run_fl(algo=MIFA(memory="array"), engine="scan_strict", scan_chunk=4,
           scenario=_trace_scen(trace_path, window=window),
           **_kw(tiny_problem))
    assert lengths and max(lengths) <= window
    assert len(lengths) >= T // window        # one page-in per chunk


# --------------------------------------------------------------------------- #
# checkpoint/resume durability (the acceptance gate)
# --------------------------------------------------------------------------- #

CKPT_ALGOS = {
    "mifa_array": lambda: MIFA(memory="array"),
    "banked_dense": lambda: BankedMIFA(DenseBank()),
    "banked_paged": lambda: BankedMIFA(make_bank("paged_device", n_slots=6)),
}


@pytest.mark.parametrize("name", list(CKPT_ALGOS))
def test_kill_resume_bitexact(tiny_problem, trace_path, tmp_path, name):
    """Kill at round 9, resume from the round-8 snapshot, finish at 14:
    bit-exact vs the uninterrupted run (params, history, τ stats)."""
    kw = _kw(tiny_problem, n_rounds=14)
    run = lambda ckdir, n_rounds=14, resume=False: run_fl(
        algo=CKPT_ALGOS[name](), engine="scan_strict", scan_chunk=5,
        scenario=_trace_scen(trace_path),
        checkpoint=CheckpointSpec(every=4, dir=ckdir, resume=resume),
        **{**kw, "n_rounds": n_rounds})
    full = run(str(tmp_path / "full"))
    killed_dir = str(tmp_path / "killed")
    run(killed_dir, n_rounds=9)               # snapshots after rounds 4, 8
    assert [r for r, _ in list_checkpoints(killed_dir)] == [4, 8]
    resumed = run(killed_dir, resume=True)
    _assert_same(full, resumed)


def test_resume_from_empty_dir_is_fresh_run(tiny_problem, trace_path,
                                            tmp_path):
    kw = _kw(tiny_problem)
    a = run_fl(algo=MIFA(memory="array"), engine="scan_strict", scan_chunk=5,
               scenario=_trace_scen(trace_path), **kw)
    b = run_fl(algo=MIFA(memory="array"), engine="scan_strict", scan_chunk=5,
               scenario=_trace_scen(trace_path),
               checkpoint=CheckpointSpec(every=4, dir=str(tmp_path / "none"),
                                         resume=True), **kw)
    _assert_same(a, b)


def test_resume_past_horizon_returns_final_state(tiny_problem, trace_path,
                                                 tmp_path):
    """Snapshot round >= n_rounds: restore and return, run nothing."""
    kw = _kw(tiny_problem)
    d = str(tmp_path / "ck")
    done = run_fl(algo=MIFA(memory="array"), engine="scan_strict",
                  scan_chunk=5, scenario=_trace_scen(trace_path),
                  checkpoint=CheckpointSpec(every=4, dir=d), **kw)
    again = run_fl(algo=MIFA(memory="array"), engine="scan_strict",
                   scan_chunk=5, scenario=_trace_scen(trace_path),
                   checkpoint=CheckpointSpec(every=4, dir=d, resume=True),
                   **{**kw, "n_rounds": 8})
    for a, b in zip(jax.tree.leaves(done[0]), jax.tree.leaves(again[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_prunes_old_snapshots(tiny_problem, trace_path,
                                              tmp_path):
    d = str(tmp_path / "ck")
    run_fl(algo=MIFA(memory="array"), engine="scan_strict", scan_chunk=5,
           scenario=_trace_scen(trace_path),
           checkpoint=CheckpointSpec(every=4, dir=d, keep=1),
           **_kw(tiny_problem))
    assert [r for r, _ in list_checkpoints(d)] == [12]
    assert latest_checkpoint(d) == checkpoint_path(d, 12)


def test_checkpoint_validation():
    with pytest.raises(ValueError, match="every"):
        CheckpointSpec(every=0, dir="x")
    with pytest.raises(ValueError, match="keep"):
        CheckpointSpec(every=1, dir="x", keep=0)


def test_checkpoint_rejects_loop_engine(tiny_problem, trace_path, tmp_path):
    with pytest.raises(ValueError, match="scan engine"):
        run_fl(algo=MIFA(memory="array"), engine="loop",
               scenario=_trace_scen(trace_path),
               checkpoint=CheckpointSpec(every=4, dir=str(tmp_path)),
               **_kw(tiny_problem))


def test_checkpoint_refuses_silent_scan_fallback(tiny_problem, trace_path,
                                                 tmp_path):
    """A non-scannable config + checkpoint= must raise, not fall back to
    the loop and silently drop durability."""
    from repro.bank import HostBank
    with pytest.raises(ValueError, match="drop durability"):
        run_fl(algo=BankedMIFA(HostBank()), engine="scan",
               scenario=_trace_scen(trace_path),
               checkpoint=CheckpointSpec(every=4, dir=str(tmp_path)),
               **_kw(tiny_problem))


def test_resume_rejects_client_count_mismatch(tiny_problem, trace_path,
                                              tmp_path):
    d = str(tmp_path / "ck")
    run_fl(algo=MIFA(memory="array"), engine="scan_strict", scan_chunk=5,
           scenario=_trace_scen(trace_path),
           checkpoint=CheckpointSpec(every=4, dir=d), **_kw(tiny_problem))
    model, batcher = tiny_problem(n_clients=6)
    with pytest.raises(ValueError, match="refusing to resume"):
        run_fl(model=model, algo=MIFA(memory="array"), batcher=batcher,
               schedule=lambda t: 0.1, n_rounds=T, weight_decay=1e-3,
               scenario=GilbertElliott.from_rate_and_burst(0.5, 3.0, n=6),
               engine="scan_strict",
               checkpoint=CheckpointSpec(every=4, dir=d, resume=True))


# --------------------------------------------------------------------------- #
# elastic fleets
# --------------------------------------------------------------------------- #

def test_elastic_mask_is_inner_and_presence():
    inner = GilbertElliott.from_rate_and_burst(0.5, 3.0, n=N, seed=4)
    join = staged_arrivals(N, n_initial=3, arrive_every=5)
    leave = np.full(N, NEVER, np.int64)
    leave[0] = 12
    proc = ElasticProcess(inner, join=join, leave=leave)
    host_in = inner.host_sampler()
    host_el = proc.host_sampler()
    for t in range(25):
        present = (join <= t) & (t < leave)
        np.testing.assert_array_equal(host_el.sample(t),
                                      host_in.sample(t) & present)


def test_elastic_over_trace_scan_vs_loop(tiny_problem, trace_path):
    """Elastic composed over trace replay: the window protocol is
    forwarded, so the scan engine streams it like the bare process."""
    kw = _kw(tiny_problem)
    mk = lambda: Scenario(
        ElasticProcess(TraceReplay(trace_path, window=T),
                       join=staged_arrivals(N, n_initial=4, arrive_every=3)),
        name="elastic-trace")
    loop = run_fl(algo=MIFA(memory="array"), engine="loop", scenario=mk(),
                  **kw)
    scan = run_fl(algo=MIFA(memory="array"), engine="scan_strict",
                  scan_chunk=4, scenario=mk(), **kw)
    _assert_same(loop, scan)


def test_elastic_capacity_and_arrivals():
    assert elastic_capacity(5) == 8 and elastic_capacity(8) == 8
    join = staged_arrivals(10, n_initial=4, arrive_every=6, arrive_count=2)
    assert (join[:4] == 0).all()
    assert join.tolist()[4:] == [6, 6, 12, 12, 18, 18]
    with pytest.raises(ValueError, match="n_initial"):
        staged_arrivals(4, n_initial=0)


def test_elastic_tau_bound_classification():
    det = make_scenario("adversarial", n=4, seed=0, periods=4,
                        offs=1).process
    grow = ElasticProcess(det, join=np.array([0, 0, 3, 7]))
    b = grow.tau_bound()
    assert b.deterministic == det.tau_bound().deterministic
    assert b.t0 == det.tau_bound().t0 + 7
    gone = ElasticProcess(det, leave=np.array([NEVER, NEVER, NEVER, 9]))
    assert not gone.tau_bound().deterministic
    assert np.isinf(gone.tau_bound().t0)
    # departed / never-staying clients have zero long-run rate
    assert gone.stationary_rate()[3] == 0.0
    assert (gone.stationary_rate()[:3] > 0).all()
