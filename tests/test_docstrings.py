"""pydocstyle-lite: public-API docstring enforcement.

Two layers, scoped to the subsystems grown in PRs 1–4 (sim, bank, fleet,
scenarios, and the core round path they share):

  * every public function, class, and method DEFINED in the listed modules
    carries a non-trivial docstring;
  * for the key entry points (the surfaces README/docs tell people to
    call), every named parameter must be mentioned by name in the
    docstring — shapes and semantics live with the signature, not in
    tribal knowledge.

This is intentionally a test, not a linter config: it runs in tier-1 with
zero extra dependencies and fails with the offending symbol's name.
"""
import importlib
import inspect

import pytest

MODULES = [
    "repro.core.runner",
    "repro.core.participation",
    "repro.fleet.spec",
    "repro.fleet.executor",
    "repro.bank.base",
    "repro.sim.policies",
    "repro.sim.latency",
    "repro.sim.engine",
    "repro.sim.compiled",
    "repro.fleet.sim",
    "repro.scenarios.base",
    "repro.scenarios.processes",
    "repro.scenarios.registry",
    "repro.scenarios.trace_replay",
    "repro.scenarios.elastic",
    "repro.checkpoint.io",
    "repro.checkpoint.run_state",
]

# callable path -> params that may stay undocumented (beyond self/cls)
KEY_CALLABLES = {
    "repro.core.runner:run_fl": {"verbose"},
    "repro.fleet.executor:run_fleet": {"verbose"},
    "repro.fleet.spec:expand_grid": set(),
    "repro.bank.base:MemoryBank.gather": set(),
    "repro.bank.base:MemoryBank.scatter": set(),
    "repro.bank.base:MemoryBank.gather_fleet": set(),
    "repro.bank.base:MemoryBank.scatter_fleet": set(),
    "repro.scenarios.registry:make_scenario": set(),
    "repro.core.runner:RoundRunner.step": set(),
    "repro.core.runner:RoundRunner.step_cohort": set(),
    "repro.fleet.executor:FleetRunner.step": set(),
    "repro.fleet.executor:FleetRunner.step_cohort": set(),
    "repro.scenarios.trace_replay:write_trace": set(),
    "repro.scenarios.trace_replay:synthesize_trace": set(),
    "repro.scenarios.trace_replay:TraceReplay.load_window": set(),
    "repro.checkpoint.io:save_pytree": set(),
    "repro.checkpoint.run_state:save_run": set(),
    "repro.checkpoint.run_state:restore_run": set(),
    "repro.checkpoint.run_state:fast_forward_sampler": set(),
}


def _public_symbols(mod):
    """(name, obj) pairs for public functions/classes defined in `mod`,
    plus (Class.method, obj) for their public methods."""
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue
        yield name, obj
        if inspect.isclass(obj):
            for mname, mobj in vars(obj).items():
                if mname.startswith("_"):
                    continue
                if isinstance(mobj, property):
                    yield f"{name}.{mname}", mobj.fget
                elif inspect.isfunction(mobj):
                    yield f"{name}.{mname}", mobj
                elif isinstance(mobj, classmethod):
                    yield f"{name}.{mname}", mobj.__func__


@pytest.mark.parametrize("modname", MODULES)
def test_public_api_has_docstrings(modname):
    mod = importlib.import_module(modname)
    missing = [name for name, obj in _public_symbols(mod)
               if not (inspect.getdoc(obj) or "").strip()
               or len(inspect.getdoc(obj)) < 10]
    assert not missing, (
        f"{modname}: public symbols without a (non-trivial) docstring: "
        f"{missing}")


@pytest.mark.parametrize("path", sorted(KEY_CALLABLES))
def test_key_callables_document_every_parameter(path):
    modname, qual = path.split(":")
    obj = importlib.import_module(modname)
    for part in qual.split("."):
        obj = getattr(obj, part)
    doc = inspect.getdoc(obj) or ""
    sig = inspect.signature(obj)
    exempt = KEY_CALLABLES[path] | {"self", "cls"}
    undocumented = [p for p in sig.parameters
                    if p not in exempt and p not in doc]
    assert doc, f"{path} has no docstring"
    assert not undocumented, (
        f"{path}: parameters not mentioned in the docstring: "
        f"{undocumented}")
