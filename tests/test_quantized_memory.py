"""core.quantized_memory: round-trip bounds, unbiasedness, edge cases.

The int8 memory (dense MIFA and Int8PagedBank both reuse it) rests on two
facts: the reconstruction error is bounded by one quantum per element, and
stochastic rounding makes the stored value an unbiased estimator — the
property MIFA's analysis needs (docs/architecture.md §3).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantized_memory import (dequantize_leaf, dequantize_tree,
                                         quantize_leaf, quantize_tree)


def test_roundtrip_error_bounded_by_one_quantum():
    key = jax.random.PRNGKey(0)
    for i, scale in enumerate((1e-4, 1.0, 37.0)):
        x = jax.random.normal(jax.random.fold_in(key, i), (5, 32)) * scale
        q, s = quantize_leaf(jax.random.fold_in(key, 100 + i), x)
        got = np.asarray(dequantize_leaf(q, s))
        quantum = np.asarray(s)[:, None]                  # absmax/127 per row
        assert np.all(np.abs(got - np.asarray(x)) <= quantum + 1e-12)


def test_stochastic_rounding_unbiased_mean_over_rngs():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (2, 24)) * 0.5
    reps = 400
    acc = np.zeros_like(np.asarray(x))
    for i in range(reps):
        q, s = quantize_leaf(jax.random.fold_in(key, i), x)
        acc += np.asarray(dequantize_leaf(q, s))
    quantum = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(acc / reps, np.asarray(x),
                               atol=4 * quantum / np.sqrt(reps) + 1e-7)


def test_zero_rows_quantize_to_exact_zero():
    x = jnp.zeros((3, 16))
    q, s = quantize_leaf(jax.random.PRNGKey(0), x)
    np.testing.assert_array_equal(np.asarray(q), 0)
    assert np.all(np.asarray(s) > 0)                      # 1e-12 floor, no /0
    np.testing.assert_array_equal(np.asarray(dequantize_leaf(q, s)), 0.0)


def test_absmax_elements_are_exact_and_clipped():
    """±absmax hits ±127 with zero fractional part — reproduced exactly."""
    x = jnp.array([[3.0, -3.0, 1.5, 0.0]])
    q, s = quantize_leaf(jax.random.PRNGKey(1), x)
    q = np.asarray(q)
    assert q[0, 0] == 127 and q[0, 1] == -127
    assert np.abs(q).max() <= 127
    got = np.asarray(dequantize_leaf(jnp.asarray(q), s))
    np.testing.assert_allclose(got[0, 0], 3.0, rtol=1e-6)
    np.testing.assert_allclose(got[0, 1], -3.0, rtol=1e-6)


def test_per_row_scales_are_independent():
    x = jnp.stack([jnp.full((8,), 1000.0), jnp.full((8,), 1e-3)])
    q, s = quantize_leaf(jax.random.PRNGKey(2), x)
    got = np.asarray(dequantize_leaf(q, s))
    # the tiny row must not be flattened by the huge row's scale
    np.testing.assert_allclose(got[1], 1e-3, rtol=1e-2)
    np.testing.assert_allclose(got[0], 1000.0, rtol=1e-2)


def test_tree_roundtrip_matches_leafwise():
    key = jax.random.PRNGKey(3)
    tree = {"w": jax.random.normal(key, (4, 3, 2)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 5))}
    qt, st = quantize_tree(key, tree)
    back = dequantize_tree(qt, st)
    for leaf, orig in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        n = orig.shape[0]
        quantum = np.abs(np.asarray(orig).reshape(n, -1)).max(1) / 127.0
        err = np.abs(np.asarray(leaf) - np.asarray(orig)).reshape(n, -1)
        assert np.all(err <= quantum[:, None] + 1e-12)
    assert all(leaf.dtype == jnp.int8 for leaf in jax.tree.leaves(qt))


def test_int8_paged_device_gather_roundtrip_bound():
    """Under PagedDeviceBank(dtype='int8'), gather returns each stored row
    within one quantum of the scattered update — and the bound survives a
    spill to host and refault, because pages spill as int8 + scales."""
    from repro.bank import PagedDeviceBank
    key = jax.random.PRNGKey(5)
    n, ps = 8, 2
    params = {"w": jax.random.normal(key, (4, 3)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (3,))}
    bank = PagedDeviceBank(page_size=ps, n_slots=2, dtype="int8")
    bs = bank.init(params, n)

    def updates(k, ids):
        return jax.tree.map(
            lambda p: jax.random.normal(k, (len(ids),) + p.shape), params)

    ids0 = np.array([0, 1, 4])                  # pages 0 and 2
    u0 = updates(jax.random.fold_in(key, 2), ids0)
    bs = bank.scatter(bs, ids0, u0, rng=jax.random.fold_in(key, 3))

    def check(got, want):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            g, w = np.asarray(g), np.asarray(w)
            m = len(ids0)
            quantum = np.abs(w.reshape(m, -1)).max(1) / 127.0
            err = np.abs(g - w).reshape(m, -1)
            assert np.all(err <= quantum[:, None] + 1e-12)

    check(bank.gather(bs, ids0), u0)

    # force pages 0 and 2 to spill, then refault them via a fresh gather
    ids1 = np.array([2, 6])                     # pages 1 and 3 evict 0 and 2
    bs = bank.scatter(bs, ids1, updates(jax.random.fold_in(key, 4), ids1),
                      rng=jax.random.fold_in(key, 5))
    assert bank.evictions > 0
    check(bank.gather(bs, ids0), u0)
