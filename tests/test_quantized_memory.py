"""core.quantized_memory: round-trip bounds, unbiasedness, edge cases.

The int8 memory (dense MIFA and Int8PagedBank both reuse it) rests on two
facts: the reconstruction error is bounded by one quantum per element, and
stochastic rounding makes the stored value an unbiased estimator — the
property MIFA's analysis needs (docs/architecture.md §3).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantized_memory import (dequantize_leaf, dequantize_tree,
                                         quantize_leaf, quantize_tree)


def test_roundtrip_error_bounded_by_one_quantum():
    key = jax.random.PRNGKey(0)
    for i, scale in enumerate((1e-4, 1.0, 37.0)):
        x = jax.random.normal(jax.random.fold_in(key, i), (5, 32)) * scale
        q, s = quantize_leaf(jax.random.fold_in(key, 100 + i), x)
        got = np.asarray(dequantize_leaf(q, s))
        quantum = np.asarray(s)[:, None]                  # absmax/127 per row
        assert np.all(np.abs(got - np.asarray(x)) <= quantum + 1e-12)


def test_stochastic_rounding_unbiased_mean_over_rngs():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (2, 24)) * 0.5
    reps = 400
    acc = np.zeros_like(np.asarray(x))
    for i in range(reps):
        q, s = quantize_leaf(jax.random.fold_in(key, i), x)
        acc += np.asarray(dequantize_leaf(q, s))
    quantum = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(acc / reps, np.asarray(x),
                               atol=4 * quantum / np.sqrt(reps) + 1e-7)


def test_zero_rows_quantize_to_exact_zero():
    x = jnp.zeros((3, 16))
    q, s = quantize_leaf(jax.random.PRNGKey(0), x)
    np.testing.assert_array_equal(np.asarray(q), 0)
    assert np.all(np.asarray(s) > 0)                      # 1e-12 floor, no /0
    np.testing.assert_array_equal(np.asarray(dequantize_leaf(q, s)), 0.0)


def test_absmax_elements_are_exact_and_clipped():
    """±absmax hits ±127 with zero fractional part — reproduced exactly."""
    x = jnp.array([[3.0, -3.0, 1.5, 0.0]])
    q, s = quantize_leaf(jax.random.PRNGKey(1), x)
    q = np.asarray(q)
    assert q[0, 0] == 127 and q[0, 1] == -127
    assert np.abs(q).max() <= 127
    got = np.asarray(dequantize_leaf(jnp.asarray(q), s))
    np.testing.assert_allclose(got[0, 0], 3.0, rtol=1e-6)
    np.testing.assert_allclose(got[0, 1], -3.0, rtol=1e-6)


def test_per_row_scales_are_independent():
    x = jnp.stack([jnp.full((8,), 1000.0), jnp.full((8,), 1e-3)])
    q, s = quantize_leaf(jax.random.PRNGKey(2), x)
    got = np.asarray(dequantize_leaf(q, s))
    # the tiny row must not be flattened by the huge row's scale
    np.testing.assert_allclose(got[1], 1e-3, rtol=1e-2)
    np.testing.assert_allclose(got[0], 1000.0, rtol=1e-2)


def test_tree_roundtrip_matches_leafwise():
    key = jax.random.PRNGKey(3)
    tree = {"w": jax.random.normal(key, (4, 3, 2)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 5))}
    qt, st = quantize_tree(key, tree)
    back = dequantize_tree(qt, st)
    for leaf, orig in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        n = orig.shape[0]
        quantum = np.abs(np.asarray(orig).reshape(n, -1)).max(1) / 127.0
        err = np.abs(np.asarray(leaf) - np.asarray(orig)).reshape(n, -1)
        assert np.all(err <= quantum[:, None] + 1e-12)
    assert all(leaf.dtype == jnp.int8 for leaf in jax.tree.leaves(qt))
