"""Scan engine: whole-run lax.scan programs are bit-exact vs the loop.

The anchor properties for `core.scan_engine` / the scan-native fleet path:

  * `run_fl(engine="scan")` reproduces the per-round loop fp32 bit-for-bit
    (params, loss history, n_active, τ statistics) for dense algorithms and
    jittable banks, under both a jit-native Gilbert–Elliott scenario and a
    legacy host participation process;
  * results are invariant to the chunking (`scan_chunk` ∈ {1, 7, T}) —
    chunk boundaries are an execution detail, never a numerics knob;
  * dense scenario runs sample availability INSIDE the compiled program:
    the host surface is never queried and no (T, N) mask trace is ever
    materialised (monkeypatch-verified);
  * unsupported configurations (host banks, update-clock schedules) fall
    back to the loop with a warning — or raise under "scan_strict";
  * the fleet scan path (`run_fleet(engine="scan")`) matches the per-round
    fleet path per trial, which test_fleet already pins to sequential runs.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.bank import BankedMIFA, DenseBank, HostBank, PagedDeviceBank
from repro.core import (MIFA, BiasedFedAvg, FedAvgSampling,
                        TraceParticipation, run_fl)
from repro.core.scan_engine import chunk_bounds
from repro.fleet import Trial, run_fleet
from repro.scenarios import GilbertElliott, HostSampler

N, T = 6, 9

ALGOS = {
    "mifa_array": lambda: MIFA(memory="array"),
    "mifa_int8": lambda: MIFA(memory="int8"),
    "banked_dense": lambda: BankedMIFA(DenseBank()),
    "banked_paged": lambda: BankedMIFA(PagedDeviceBank(page_size=4)),
    "fedavg": lambda: BiasedFedAvg(),
}


def _ge(seed=0, burst=3.0):
    return GilbertElliott.from_rate_and_burst(0.5, burst, n=N,
                                              seed=100 + seed)


def _kw(tiny_problem, **over):
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=T,
              weight_decay=1e-3, seed=0, cohort_capacity=8)
    kw.update(over)
    return kw


def _assert_same(run_a, run_b):
    (pa, ha), (pb, hb) = run_a, run_b
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ha.train_loss == hb.train_loss
    assert ha.n_active == hb.n_active
    assert ha.rounds == hb.rounds
    assert (ha.tau_bar, ha.tau_max) == (hb.tau_bar, hb.tau_max)


# --------------------------------------------------------------------------- #
# bit-exact equivalence vs the per-round loop
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", list(ALGOS))
def test_scan_bitexact_vs_loop_scenario(tiny_problem, name):
    """Jit-native Gilbert–Elliott scenario through both engines."""
    kw = _kw(tiny_problem)
    loop = run_fl(algo=ALGOS[name](), engine="loop", scenario=_ge(), **kw)
    scan = run_fl(algo=ALGOS[name](), engine="scan", scan_chunk=4,
                  scenario=_ge(), **kw)
    _assert_same(loop, scan)


@pytest.mark.parametrize("name", list(ALGOS))
def test_scan_bitexact_vs_loop_participation(tiny_problem, name):
    """Legacy host participation (trace replay) through both engines."""
    kw = _kw(tiny_problem)
    trace = np.random.default_rng(3).random((T, N)) < 0.5
    loop = run_fl(algo=ALGOS[name](), engine="loop",
                  participation=TraceParticipation(trace), **kw)
    scan = run_fl(algo=ALGOS[name](), engine="scan", scan_chunk=4,
                  participation=TraceParticipation(trace), **kw)
    _assert_same(loop, scan)


@pytest.mark.parametrize("chunk", [1, 7, T])
def test_scan_chunk_boundary_invariance(tiny_problem, chunk):
    """scan_chunk is an execution detail: {1, 7, T} give identical runs."""
    kw = _kw(tiny_problem)
    ref = run_fl(algo=MIFA(memory="array"), engine="loop", scenario=_ge(),
                 **kw)
    got = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=chunk,
                 scenario=_ge(), **kw)
    _assert_same(ref, got)


def test_scan_eval_rounds_match_loop(tiny_problem):
    """Chunk boundaries snap to eval rounds: the eval curve is recorded at
    exactly the rounds the loop engine evaluates."""
    kw = _kw(tiny_problem)
    ev = lambda p: (0.5, 0.25)
    loop = run_fl(algo=MIFA(memory="array"), engine="loop", scenario=_ge(),
                  eval_fn=ev, eval_every=4, **kw)
    scan = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=5,
                  scenario=_ge(), eval_fn=ev, eval_every=4, **kw)
    assert loop[1].eval_loss == scan[1].eval_loss
    assert [t for t, _ in scan[1].eval_loss] == [0, 4, 8]


# --------------------------------------------------------------------------- #
# no (T, N) trace, no host sampling — the jit-native guarantee survives
# --------------------------------------------------------------------------- #

def test_scan_scenario_never_touches_host_surface(tiny_problem, monkeypatch):
    """Dense scenario scan: availability is sampled inside the compiled
    program; the host surface must never be queried and no (T, N) mask
    trace may be stacked anywhere on the host."""
    def boom(self, t):
        raise AssertionError("host surface queried during a dense scenario "
                             "scan — sampling must happen inside the "
                             "compiled program")
    monkeypatch.setattr(HostSampler, "sample", boom)

    stacked_shapes = []
    real_stack = np.stack

    def recording_stack(arrays, *a, **k):
        out = real_stack(arrays, *a, **k)
        stacked_shapes.append((out.shape, out.dtype))
        return out
    monkeypatch.setattr(np, "stack", recording_stack)

    kw = _kw(tiny_problem)
    _, hist = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=4,
                     scenario=_ge(), **kw)
    assert len(hist.train_loss) == T
    assert not any(shape == (T, N) and dtype == np.bool_
                   for shape, dtype in stacked_shapes), stacked_shapes


# --------------------------------------------------------------------------- #
# fallbacks and strictness
# --------------------------------------------------------------------------- #

def test_scan_host_bank_falls_back_to_loop(tiny_problem):
    kw = _kw(tiny_problem)
    ref = run_fl(algo=BankedMIFA(HostBank()), engine="loop",
                 scenario=_ge(), **kw)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = run_fl(algo=BankedMIFA(HostBank()), engine="scan",
                     scenario=_ge(), **kw)
        assert any("falling back" in str(x.message) for x in w)
    assert ref[1].train_loss == got[1].train_loss


def test_scan_strict_raises_on_host_bank(tiny_problem):
    with pytest.raises(ValueError, match="host-offloaded"):
        run_fl(algo=BankedMIFA(HostBank()), engine="scan_strict",
               scenario=_ge(), **_kw(tiny_problem))


def test_scan_update_clock_falls_back(tiny_problem):
    kw = _kw(tiny_problem)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        run_fl(algo=FedAvgSampling(s=3), engine="scan",
               uses_update_clock=True, scenario=_ge(), **kw)
        assert any("update-clock" in str(x.message) for x in w)
    with pytest.raises(ValueError, match="update-clock"):
        run_fl(algo=FedAvgSampling(s=3), engine="scan_strict",
               uses_update_clock=True, scenario=_ge(), **kw)


def test_unknown_engine_rejected(tiny_problem):
    with pytest.raises(ValueError, match="unknown engine"):
        run_fl(algo=MIFA(memory="array"), engine="turbo", scenario=_ge(),
               **_kw(tiny_problem))


def test_scan_cohort_capacity_overflow_raises(tiny_problem):
    """A pinned capacity smaller than a drawn cohort must raise (the scan
    program cannot widen per round the way the loop's pow-2 buckets do)."""
    kw = _kw(tiny_problem, cohort_capacity=2)
    with pytest.raises(ValueError, match="overflows the scan capacity"):
        run_fl(algo=BankedMIFA(DenseBank()), engine="scan",
               participation=TraceParticipation(np.ones((T, N), bool)),
               **kw)


# --------------------------------------------------------------------------- #
# paged device bank under scan: eviction, chunk-union residency, messages
# --------------------------------------------------------------------------- #

class _RawTrace:
    """Trace participation without TraceParticipation's forced all-active
    round 0 — eviction tests need sparse cohorts from the first round."""

    def __init__(self, trace):
        self.trace = np.asarray(trace, bool)
        self.n = self.trace.shape[1]

    def sample(self, t):
        return self.trace[t]


def _paged_trace():
    """Cohorts that, at page_size=2 / n_slots=2, fit per round but force
    evictions and refaults across the run."""
    cohorts = [[0, 1], [4, 5], [2, 3], [0, 5], [2], [1, 3], [4], [0, 2]]
    tr = np.zeros((len(cohorts), N), bool)
    for t, ids in enumerate(cohorts):
        tr[t, ids] = True
    return tr


def test_scan_paged_eviction_bitexact_vs_loop(tiny_problem):
    """With pages spilling and refaulting on different schedules, loop and
    scan still match DenseBank bit-for-bit: physical slots are invisible."""
    tr = _paged_trace()
    kw = _kw(tiny_problem, n_rounds=len(tr), cohort_capacity=2)
    paged = lambda: BankedMIFA(PagedDeviceBank(page_size=2, n_slots=2))
    ref = run_fl(algo=BankedMIFA(DenseBank()), engine="loop",
                 participation=_RawTrace(tr), **kw)
    loop = run_fl(algo=paged(), engine="loop",
                  participation=_RawTrace(tr), **kw)
    scan = run_fl(algo=paged(), engine="scan", scan_chunk=1,
                  participation=_RawTrace(tr), **kw)
    _assert_same(ref, loop)
    _assert_same(loop, scan)


def test_scan_paged_chunk_union_overflow_raises(tiny_problem):
    """Under scan, residency is prepared per *chunk union*; a union wider
    than the slot budget must fail with actionable advice, not corrupt."""
    tr = _paged_trace()
    kw = _kw(tiny_problem, n_rounds=len(tr), cohort_capacity=2)
    with pytest.raises(ValueError, match="slots"):
        run_fl(algo=BankedMIFA(PagedDeviceBank(page_size=2, n_slots=2)),
               engine="scan", scan_chunk=2,
               participation=_RawTrace(tr), **kw)


def test_scan_fallback_warning_names_capable_backends(tiny_problem):
    """The fallback warning must name the blocking backend and the banks
    that do support scan, so users know what to switch to."""
    kw = _kw(tiny_problem)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        run_fl(algo=BankedMIFA(HostBank()), engine="scan",
               scenario=_ge(), **kw)
    msg = next(str(x.message) for x in w if "falling back" in str(x.message))
    assert "HostBank" in msg
    assert "DenseBank" in msg and "PagedDeviceBank" in msg


# --------------------------------------------------------------------------- #
# fleet scan path
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", list(ALGOS))
def test_fleet_scan_bitexact_vs_fleet_loop(tiny_problem, name):
    """run_fleet(engine="scan") matches the per-round fleet path per trial
    (which test_fleet pins to sequential run_fl) — participation trials."""
    model, batcher = tiny_problem(n_clients=N)
    traces = np.random.default_rng(7).random((3, T, N)) < 0.5
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=T,
              weight_decay=1e-3, cohort_capacity=8)
    mk = lambda: [Trial(seed=k, participation=TraceParticipation(traces[k]))
                  for k in range(3)]
    loop = run_fleet(algo=ALGOS[name](), trials=mk(), engine="loop", **kw)
    scan = run_fleet(algo=ALGOS[name](), trials=mk(), engine="scan",
                     scan_chunk=4, **kw)
    for a, b in zip(jax.tree.leaves(loop[0]), jax.tree.leaves(scan[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in range(3):
        assert loop[1].trial(k).train_loss == scan[1].trial(k).train_loss
        assert loop[1].trial(k).n_active == scan[1].trial(k).n_active


def test_fleet_scan_scenario_in_jit(tiny_problem, monkeypatch):
    """Scenario fleet scan samples in-program (host surface never queried)
    and matches the per-round fleet path bit-for-bit."""
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=T,
              weight_decay=1e-3)
    mk = lambda: [Trial(seed=k, scenario=_ge(k)) for k in range(3)]
    loop = run_fleet(algo=MIFA(memory="array"), trials=mk(), engine="loop",
                     **kw)

    def boom(self, t):
        raise AssertionError("host surface queried during a scenario "
                             "fleet scan")
    monkeypatch.setattr(HostSampler, "sample", boom)
    scan = run_fleet(algo=MIFA(memory="array"), trials=mk(), engine="scan",
                     scan_chunk=4, **kw)
    for a, b in zip(jax.tree.leaves(loop[0]), jax.tree.leaves(scan[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in range(3):
        assert loop[1].trial(k).train_loss == scan[1].trial(k).train_loss


def test_fleet_scan_update_clock_falls_back(tiny_problem):
    model, batcher = tiny_problem(n_clients=N)
    traces = np.ones((2, T, N), bool)
    kw = dict(model=model, batcher=batcher, schedule=lambda t: 0.1,
              n_rounds=3, weight_decay=1e-3)
    trials = [Trial(seed=k, participation=TraceParticipation(traces[k]))
              for k in range(2)]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        run_fleet(algo=FedAvgSampling(s=3), trials=trials,
                  uses_update_clock=True, engine="scan", **kw)
        assert any("update-clock" in str(x.message) for x in w)


# --------------------------------------------------------------------------- #
# chunking helper
# --------------------------------------------------------------------------- #

def test_chunk_bounds_snap_to_evals():
    assert chunk_bounds(10, 4, set()) == [(0, 4), (4, 8), (8, 10)]
    # eval after rounds 0 and 5 forces cuts at 1 and 6
    assert chunk_bounds(10, 4, {0, 5}) == [(0, 1), (1, 4), (4, 6), (6, 8),
                                           (8, 10)]
    assert chunk_bounds(3, 100, set()) == [(0, 3)]
    with pytest.raises(ValueError, match="scan_chunk"):
        chunk_bounds(10, 0, set())
