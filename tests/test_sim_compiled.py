"""Compiled simulator: heap-vs-scan parity, the unified policy algebra,
buffered-async semantics, the simulated fleet, and the jit-native batcher.

The contract under test (docs/architecture.md §11): `repro.sim.compiled`
reproduces the discrete-event heap engine BIT-EXACTLY — same f32 round
close times, same applied masks, same losses — for every supported
configuration, across all five policies and both independent and
temporally-correlated availability.
"""
import warnings

import numpy as np
import pytest

from repro.core import BiasedFedAvg, FedBuffAvg, MIFA, RoundRunner, run_fl
from repro.data import JitProceduralBatcher
from repro.fleet import SimTrial, make_fleet_eval, run_sim_fleet
from repro.optim import inv_t
from repro.scenarios import Bernoulli, GilbertElliott, as_process
from repro.sim import (BufferedKofN, Deadline, FedSimEngine, Impatient,
                       SimConfig, SimScanDriver, SimSpec, WaitForAll,
                       WaitForS, sim_scan_supported,
                       tiered_shifted_exponential)
from repro.sim.compiled import run_sim_scan
from repro.sim.engine import LATE

N, T = 9, 12
CONFIG = SimConfig(epoch_s=4.0, server_overhead_s=0.1,
                   max_lookahead_epochs=40)

POLICIES = [WaitForAll(), WaitForS(s=4), Deadline(deadline_s=3.0),
            Impatient(), BufferedKofN(k=3)]
SCENARIOS = [Bernoulli(0.6, n=N, seed=5),
             GilbertElliott(0.3, 0.4, n=N, seed=5)]


def _algo_for(policy):
    return FedBuffAvg() if getattr(policy, "stateful", False) \
        else BiasedFedAvg()


@pytest.fixture
def make_runner(tiny_problem):
    def _make(algo, scenario, seed=0):
        model, batcher = tiny_problem(n_clients=N, n_per_class=60)
        return RoundRunner(model=model, algo=algo, batcher=batcher,
                           schedule=inv_t(1.0), weight_decay=1e-3, seed=seed,
                           scenario=scenario)
    return _make


def _run_both(make_runner, policy, scenario, algo=None, n_rounds=T,
              config=CONFIG, scan_chunk=5, seed=0):
    """(heap engine record, compiled driver record) for one config."""
    algo = algo or _algo_for(policy)
    lat = tiered_shifted_exponential(N, seed=7)
    sim = SimSpec(policy=policy, latency=lat, config=config)

    r_heap = make_runner(algo, scenario, seed)
    eng = FedSimEngine(r_heap, policy, as_process(scenario).host_sampler(),
                       lat, config, seed=seed)
    eng.run(n_rounds)

    r_scan = make_runner(algo, scenario, seed)
    ok, why = sim_scan_supported(r_scan, sim)
    assert ok, why
    drv = SimScanDriver(r_scan, sim, scan_chunk=scan_chunk, emit_masks=True)
    drv.run(n_rounds)
    return (eng, r_heap), (drv, r_scan)


# --------------------------------------------------------------------------- #
# heap vs compiled parity: close times, applied masks, losses
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("scenario", SCENARIOS,
                         ids=["bernoulli", "gilbert_elliott"])
@pytest.mark.parametrize("policy", POLICIES, ids=[p.name for p in POLICIES])
def test_heap_scan_parity(make_runner, policy, scenario):
    """Bit-exact parity on every supported config: the compiled scan and
    the event heap agree on round close times, applied masks, per-round
    counters, AND the resulting training losses."""
    (eng, rh), (drv, rs) = _run_both(make_runner, policy, scenario)
    for rec_h, rec_s in zip(eng.round_log, drv.round_log):
        assert rec_h["t_close"] == rec_s["t_close"], rec_h["round"]
        assert rec_h["t_open"] == rec_s["t_open"]
        for k in ("n_dispatched", "n_applied", "n_late", "n_never"):
            assert rec_h[k] == rec_s[k], (rec_h["round"], k)
    np.testing.assert_array_equal(np.stack(eng.applied_log),
                                  np.stack(drv.applied_log))
    np.testing.assert_array_equal(rh.hist.train_loss, rs.hist.train_loss)
    np.testing.assert_array_equal(rh.hist.sim_seconds, rs.hist.sim_seconds)


def test_parity_with_mifa(make_runner):
    """MIFA's memory bank rides the compiled sim body unchanged."""
    (eng, rh), (drv, rs) = _run_both(
        make_runner, Impatient(), SCENARIOS[0], algo=MIFA(memory="array"))
    np.testing.assert_array_equal(rh.hist.train_loss, rs.hist.train_loss)
    np.testing.assert_array_equal(rh.hist.sim_seconds, rs.hist.sim_seconds)


def test_run_fl_sim_engines_agree(make_runner, tiny_problem):
    """The public entry point: run_fl(sim=..., engine='loop'|'scan') gives
    identical histories, and evals are stamped at identical sim times."""
    model, batcher = tiny_problem(n_clients=N, n_per_class=60)
    lat = tiered_shifted_exponential(N, seed=7)
    sim = SimSpec(policy=WaitForS(s=4), latency=lat, config=CONFIG)
    kw = dict(model=model, algo=BiasedFedAvg(), batcher=batcher,
              schedule=inv_t(1.0), n_rounds=T, scenario=SCENARIOS[0],
              sim=sim, seed=3, eval_every=4)
    _, h_loop = run_fl(engine="loop", **kw)
    _, h_scan = run_fl(engine="scan", **kw)
    np.testing.assert_array_equal(h_loop.train_loss, h_scan.train_loss)
    np.testing.assert_array_equal(h_loop.sim_seconds, h_scan.sim_seconds)
    np.testing.assert_array_equal(h_loop.n_active, h_scan.n_active)


# --------------------------------------------------------------------------- #
# unsupported configs: honest fallback naming the blocker
# --------------------------------------------------------------------------- #

def test_sim_scan_supported_rejects_oversized_window(make_runner):
    sim = SimSpec(policy=WaitForAll(),
                  latency=tiered_shifted_exponential(N, seed=7),
                  config=SimConfig(max_lookahead_epochs=1 << 24))
    ok, why = sim_scan_supported(make_runner(BiasedFedAvg(), SCENARIOS[0]),
                                 sim)
    assert not ok and "window" in why


def test_run_fl_sim_falls_back_with_warning(tiny_problem):
    """Legacy participation= (no scenario, so no jit-native sampler) must
    fall back to the heap engine under engine='scan', naming the blocker."""
    from repro.core import BernoulliParticipation
    model, batcher = tiny_problem(n_clients=N, n_per_class=60)
    sim = SimSpec(policy=WaitForAll(),
                  latency=tiered_shifted_exponential(N, seed=7),
                  config=CONFIG)
    kw = dict(model=model, algo=BiasedFedAvg(), batcher=batcher,
              schedule=inv_t(1.0), n_rounds=4,
              participation=BernoulliParticipation(np.full(N, 0.6), seed=5),
              sim=sim)
    with pytest.warns(UserWarning, match="scenario"):
        _, hist = run_fl(engine="scan", **kw)
    assert len(hist.sim_seconds) == 4
    with pytest.raises(ValueError, match="scan_strict"):
        run_fl(engine="scan_strict", **kw)


# --------------------------------------------------------------------------- #
# buffered-async (FedBuff-style) semantics
# --------------------------------------------------------------------------- #

def test_buffered_pending_carry_over(make_runner):
    """K-of-N closes on the kth arrival; the stragglers stay in flight and
    are merged in a LATER round with staleness-discounted weight — so some
    round must apply a device whose dispatch round differs."""
    (eng, _), (drv, _) = _run_both(make_runner, BufferedKofN(k=3),
                                   SCENARIOS[0])
    # no late drops under buffering: everything eventually merges or waits
    assert all(r["n_late"] == 0 for r in eng.round_log)
    assert all(r["n_late"] == 0 for r in drv.round_log)
    # pending arrivals from earlier rounds: some round must apply a device
    # it did NOT dispatch (the straggler merged with staleness discount)
    applied = np.stack(drv.applied_log)
    cohort = np.stack(drv.cohort_log)
    assert (applied & ~cohort).any()


def test_buffered_staleness_weights():
    """FedBuffAvg: update = Σ w·u / |contributors| with the weight vector
    passed through as `active` (weight_aware)."""
    import jax.numpy as jnp
    algo = FedBuffAvg()
    assert algo.weight_aware
    params = {"w": jnp.zeros(3)}
    updates = {"w": jnp.asarray([[3.0, 0, 0], [0, 6.0, 0], [0, 0, 9.0]])}
    w = jnp.asarray([1.0, 0.5, 0.0])         # stale device discounted, one out
    st = algo.init_state(params, 3)
    _, new_p, m = algo.round_step(st, params, updates,
                                  jnp.asarray([1.0, 2.0, 3.0]), w, 1.0)
    # contributors = 2 -> mean = (1*u0 + 0.5*u1) / 2
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [-1.5, -1.5, 0.0], rtol=1e-6)
    assert float(m["n_active"]) == 2.0
    assert float(m["loss"]) == pytest.approx(1.5)


def test_buffered_policy_weights_match_staleness(make_runner):
    """The heap engine's buffered weights are 1/sqrt(1+staleness_rounds)."""
    policy = BufferedKofN(k=3)
    pstate = policy.init_pstate(N)
    cohort = np.ones(N, bool)
    avail = np.ones(N, bool)
    arrivals = np.full(N, np.inf, np.float32)
    arrivals[:4] = np.float32([0.5, 1.0, 1.5, 9.0])
    close, applied, w, pstate = policy.resolve_pending(
        pstate, cohort, avail, arrivals, np.float32(0.0), np.float32(4.0), 0)
    assert close == np.float32(1.5)           # kth (k=3) arrival
    assert applied.sum() == 3 and not applied[3]
    np.testing.assert_array_equal(w[:3], 1.0)  # fresh: staleness 0
    assert np.isfinite(pstate["pending"][3])   # straggler still in flight
    # straggler merges next round with discounted weight
    arrivals2 = np.full(N, np.inf, np.float32)
    close2, applied2, w2, pstate = policy.resolve_pending(
        pstate, np.zeros(N, bool), avail, arrivals2, np.float32(1.6),
        np.float32(4.0), 1)
    assert applied2[3] and w2[3] == np.float32(1.0 / np.sqrt(2.0))
    assert not np.isfinite(pstate["pending"][3])


# --------------------------------------------------------------------------- #
# heap engine satellites: LATE records, never-returning counter
# --------------------------------------------------------------------------- #

def test_late_records_preserve_arrival_and_close(make_runner):
    """LATE events are 6-tuples (arrival_t, seq, 'late', client, round,
    close_t): the true arrival time survives, close is separate."""
    algo = BiasedFedAvg()
    r = make_runner(algo, SCENARIOS[0])
    eng = FedSimEngine(r, Deadline(deadline_s=0.5),
                       as_process(SCENARIOS[0]).host_sampler(),
                       tiered_shifted_exponential(N, seed=7), CONFIG, seed=0)
    eng.run(6)
    lates = [e for e in eng.event_log if e[2] == LATE]
    assert lates, "0.5s deadline under a 2.0s-shift slow tier must drop some"
    for ev in lates:
        assert len(ev) == 6
        arrival, _, _, client, rnd, close = ev
        assert arrival > close            # late means arrived after close
        assert 0 <= client < N


def test_never_returning_counter_and_warning(make_runner):
    """A device dark past the lookahead horizon is counted in n_never and
    warned about once, naming SimConfig.max_lookahead_epochs."""
    from repro.core import TraceParticipation
    from repro.sim import TraceLatency
    trace = np.ones((2, N), bool)
    trace[1, 0] = False                       # device 0 dark from epoch 1 on
    part = TraceParticipation(trace)
    lat = TraceLatency(np.full((1, N), 0.5))
    cfg = SimConfig(epoch_s=1.0, max_lookahead_epochs=5)
    algo = BiasedFedAvg()
    r = make_runner(algo, SCENARIOS[0])
    eng = FedSimEngine(r, WaitForAll(), part, lat, cfg, seed=0)
    with pytest.warns(UserWarning, match="max_lookahead_epochs"):
        eng.run(4)
    assert eng.n_never_total > 0
    assert any(rec["n_never"] > 0 for rec in eng.round_log)
    with warnings.catch_warnings():           # warn-once: silent afterwards
        warnings.simplefilter("error")
        eng.run_round(4)


# --------------------------------------------------------------------------- #
# simulated fleet: K lanes ≡ K single runs, mixed policies in one program
# --------------------------------------------------------------------------- #

def _logistic_dim() -> int:
    from repro.configs import get_config
    return get_config("paper_logistic").d_model


def test_sim_fleet_matches_single_runs(tiny_problem):
    model, _ = tiny_problem(n_clients=N, n_per_class=60)
    batcher = JitProceduralBatcher(n_clients=N, dim=_logistic_dim(),
                                   batch_size=8, k_steps=2, seed=3)
    schedule = inv_t(1.0)
    lat = lambda: tiered_shifted_exponential(N, seed=7)
    trials = [
        SimTrial(seed=13, policy=WaitForAll(),
                 scenario=Bernoulli(0.6, n=N, seed=5), latency=lat()),
        SimTrial(seed=14, policy=Deadline(deadline_s=3.0, cohort_size=6),
                 scenario=Bernoulli(0.6, n=N, seed=6), latency=lat()),
        SimTrial(seed=15, policy=BufferedKofN(k=3),
                 scenario=Bernoulli(0.6, n=N, seed=7), latency=lat()),
    ]
    eval_fn = make_fleet_eval(model, batcher.eval_batch(128))
    _, hist = run_sim_fleet(
        model=model, algo=FedBuffAvg(), batcher=batcher, schedule=schedule,
        n_rounds=T, trials=trials, config=CONFIG, scan_chunk=5,
        eval_fn=eval_fn, eval_every=4, batch_fn=batcher.batch_fn())
    st = hist.stacked()
    assert st["sim_seconds"].shape == (3, T)
    for k, tr in enumerate(trials):
        sim = SimSpec(policy=tr.policy, latency=tr.latency, config=CONFIG)
        _, h1 = run_fl(model=model, algo=FedBuffAvg(), batcher=batcher,
                       schedule=schedule, n_rounds=T, scenario=tr.scenario,
                       sim=sim, seed=tr.seed, engine="scan_strict",
                       scan_chunk=5)
        np.testing.assert_array_equal(st["sim_seconds"][k], h1.sim_seconds)
        np.testing.assert_array_equal(st["train_loss"][k], h1.train_loss)


def test_sim_fleet_rejects_mixed_latency_classes(tiny_problem):
    from repro.sim import LognormalLatency
    model, _ = tiny_problem(n_clients=N, n_per_class=60)
    batcher = JitProceduralBatcher(n_clients=N, dim=_logistic_dim(),
                                   batch_size=8, k_steps=2, seed=3)
    trials = [
        SimTrial(seed=1, policy=WaitForAll(),
                 scenario=Bernoulli(0.6, n=N, seed=5),
                 latency=tiered_shifted_exponential(N, seed=7)),
        SimTrial(seed=2, policy=WaitForAll(),
                 scenario=Bernoulli(0.6, n=N, seed=5),
                 latency=LognormalLatency(0.0, 0.5, comm=0.1, n=N, seed=7)),
    ]
    with pytest.raises(ValueError, match="latency"):
        run_sim_fleet(model=model, algo=BiasedFedAvg(), batcher=batcher,
                      schedule=inv_t(1.0), n_rounds=2, trials=trials,
                      config=CONFIG)


# --------------------------------------------------------------------------- #
# jit-native batcher
# --------------------------------------------------------------------------- #

def test_jit_batcher_host_matches_program():
    import jax
    b = JitProceduralBatcher(n_clients=5, dim=4, batch_size=3, k_steps=2,
                             seed=9)
    draw = jax.jit(b.batch_fn())
    for t in (0, 7):
        host = b.sample_round(t)
        prog = {k: np.asarray(v) for k, v in draw(t).items()}
        np.testing.assert_array_equal(host["x"], prog["x"])
        np.testing.assert_array_equal(host["y"], prog["y"])
    assert host["x"].shape == (5, 2, 3, 4)
    assert host["y"].dtype == np.int32
    sub = b.sample_round(0, client_ids=[4, 1])
    np.testing.assert_array_equal(sub["x"], b.sample_round(0)["x"][[4, 1]])
    ev = b.eval_batch(64)
    assert ev["x"].shape == (64, 4) and ev["y"].shape == (64,)


# --------------------------------------------------------------------------- #
# property test: parity holds across the latency-parameter space (CI-only)
# --------------------------------------------------------------------------- #

@pytest.mark.slow
def test_parity_property_over_latency_params(make_runner):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.sim import ShiftedExponentialLatency

    @settings(max_examples=8, deadline=None)
    @given(shift=st.floats(0.01, 3.0), scale=st.floats(0.01, 2.0),
           seed=st.integers(0, 10))
    def check(shift, scale, seed):
        lat = ShiftedExponentialLatency(shift, scale, n=N, seed=seed)
        algo = BiasedFedAvg()
        sim = SimSpec(policy=Deadline(deadline_s=shift + scale),
                      latency=lat, config=CONFIG)
        r_heap = make_runner(algo, SCENARIOS[1])
        eng = FedSimEngine(r_heap, sim.policy,
                           as_process(SCENARIOS[1]).host_sampler(), lat,
                           CONFIG, seed=0)
        eng.run(6)
        r_scan = make_runner(algo, SCENARIOS[1])
        drv = SimScanDriver(r_scan, sim, scan_chunk=3, emit_masks=True)
        drv.run(6)
        assert [rec["t_close"] for rec in eng.round_log] == \
               [rec["t_close"] for rec in drv.round_log]
        np.testing.assert_array_equal(np.stack(eng.applied_log),
                                      np.stack(drv.applied_log))
        np.testing.assert_array_equal(r_heap.hist.train_loss,
                                      r_scan.hist.train_loss)

    check()
