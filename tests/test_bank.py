"""Memory-bank subsystem: backend equivalence, cohort rounds, drivers.

The load-bearing property: fp32 bank cohort rounds are *the same algorithm*
as dense `MIFA(memory="array")` — same parameter trajectory, same history —
while only ever touching O(|A(t)|·d) state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bank import (BankedMIFA, DenseBank, HostBank, Int8PagedBank,
                        MemoryBank, PagedDeviceBank, make_bank)
from repro.configs import get_config
from repro.core import MIFA, BernoulliParticipation, run_fl
from repro.core.runner import RoundRunner, _pow2_bucket
from repro.data import ProceduralBatcher

N = 8


def _tree(rng):
    k1, k2 = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (4, 3)),
            "b": jax.random.normal(k2, (3,))}


def _cohort_updates(rng, ids):
    k1, k2 = jax.random.split(rng)
    c = len(ids)
    return {"w": jax.random.normal(k1, (c, 4, 3)),
            "b": jax.random.normal(k2, (c, 3))}


def _random_rounds(bank: MemoryBank, rounds=6, seed=0, needs_rng=False):
    """Drive a bank and a dense MIFA('array') with identical cohorts."""
    key = jax.random.PRNGKey(seed)
    params = _tree(key)
    mifa = MIFA(memory="array")
    sm = mifa.init_state(params, N)
    bs = bank.init(params, N)
    for t in range(rounds):
        key, k1, k2, k3 = jax.random.split(key, 4)
        active = np.array(jax.random.bernoulli(k2, 0.5, (N,)))
        if t == 0:
            active[:] = True
        ids = np.flatnonzero(active)
        cu = _cohort_updates(k1, ids)
        # dense MIFA sees the same updates scattered into an (N, ...) array
        full = jax.tree.map(
            lambda c, p: jnp.zeros((N,) + p.shape).at[ids].set(c),
            cu, params)
        sm, _, _ = mifa.round_step(sm, params, full, jnp.zeros(N),
                                   jnp.asarray(active), jnp.float32(0.1))
        bs = bank.scatter(bs, ids, cu, rng=(k3 if needs_rng else None))
    dense_mean = jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), 0),
                              sm["G"])
    return bs, dense_mean


# --------------------------------------------------------------------------- #
# backend <-> dense MIFA equivalence
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", ["dense", "host", "paged_device"])
def test_fp32_backends_match_dense_mifa_mean(backend):
    bank = make_bank(backend)
    bs, dense_mean = _random_rounds(bank)
    for a, b in zip(jax.tree.leaves(bank.mean_g(bs)),
                    jax.tree.leaves(dense_mean)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_int8_paged_close_to_dense_mifa_mean():
    bank = Int8PagedBank(page_size=4)
    bs, dense_mean = _random_rounds(bank, needs_rng=True)
    for a, b in zip(jax.tree.leaves(bank.mean_g(bs)),
                    jax.tree.leaves(dense_mean)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


@pytest.mark.parametrize("backend,kwargs",
                         [("dense", {}), ("host", {}),
                          ("int8_paged", {"page_size": 4}),
                          ("paged_device", {"page_size": 4})])
def test_gsum_is_sum_of_rows(backend, kwargs):
    """The delta identity maintains G_sum == Σ_i gather(i) exactly."""
    bank = make_bank(backend, **kwargs)
    bs, _ = _random_rounds(bank, needs_rng=(backend == "int8_paged"))
    rows = bank.gather(bs, np.arange(N))
    mean = bank.mean_g(bs)
    for r, m in zip(jax.tree.leaves(rows), jax.tree.leaves(mean)):
        np.testing.assert_allclose(np.asarray(r).sum(0) / N, np.asarray(m),
                                   rtol=1e-5, atol=1e-6)


def test_scatter_only_touches_cohort_rows():
    key = jax.random.PRNGKey(3)
    params = _tree(key)
    for bank in (DenseBank(), HostBank(), Int8PagedBank(page_size=2),
                 PagedDeviceBank(page_size=2, n_slots=3)):
        bs = bank.init(params, N)
        ids0 = np.array([1, 4])
        bs = bank.scatter(bs, ids0, _cohort_updates(key, ids0),
                          rng=jax.random.fold_in(key, 1))
        before = jax.tree.leaves(bank.gather(bs, np.array([1, 4])))
        ids1 = np.array([0, 5, 6])
        bs = bank.scatter(bs, ids1, _cohort_updates(key, ids1),
                          rng=jax.random.fold_in(key, 2))
        after = jax.tree.leaves(bank.gather(bs, np.array([1, 4])))
        for a, b in zip(before, after):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_padded_slots_are_inert():
    """valid=False slots (dummy row) change neither rows nor G_sum."""
    key = jax.random.PRNGKey(7)
    params = _tree(key)
    ids = np.array([2, 5])
    cu = _cohort_updates(key, ids)
    padded_ids = np.array([2, 5, N, N])
    padded_cu = jax.tree.map(
        lambda c: jnp.concatenate([c, 999.0 * jnp.ones((2,) + c.shape[1:])]),
        cu)
    valid = np.array([True, True, False, False])
    for backend, kwargs in (("dense", {}), ("host", {}),
                            ("int8_paged", {"page_size": 4}),
                            ("paged_device", {"page_size": 4}),
                            ("paged_device", {"page_size": 4,
                                              "dtype": "int8"})):
        rng = jax.random.fold_in(key, 1)
        b1 = make_bank(backend, **kwargs)
        s1 = b1.scatter(b1.init(params, N), ids, cu, rng=rng)
        b2 = make_bank(backend, **kwargs)
        s2 = b2.scatter(b2.init(params, N), padded_ids, padded_cu,
                        valid=valid, rng=rng)
        for a, b in zip(jax.tree.leaves(b1.gather(s1, np.arange(N))),
                        jax.tree.leaves(b2.gather(s2, np.arange(N)))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(b1.mean_g(s1)),
                        jax.tree.leaves(b2.mean_g(s2))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_dense_pallas_path_matches_jnp(dtype):
    key = jax.random.PRNGKey(11)
    params = _tree(key)
    b1 = DenseBank(dtype=dtype, use_pallas=False)
    b2 = DenseBank(dtype=dtype, use_pallas=True)
    s1, s2 = b1.init(params, N), b2.init(params, N)
    for t in range(3):
        key, k = jax.random.split(key)
        ids = np.array([0, 3, 5, N])
        valid = np.array([1, 1, 1, 0], bool)
        cu = _cohort_updates(k, ids)
        s1 = b1.scatter(s1, ids, cu, valid=valid)
        s2 = b2.scatter(s2, ids, cu, valid=valid)
    for a, b in zip(jax.tree.leaves(s1["rows"]), jax.tree.leaves(s2["rows"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    for a, b in zip(jax.tree.leaves(b1.mean_g(s1)),
                    jax.tree.leaves(b2.mean_g(s2))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_int8_paged_lazy_allocation():
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    bank = Int8PagedBank(page_size=2)
    bs = bank.init(params, 100)
    assert bank.n_pages(bs) == 0
    bs = bank.scatter(bs, np.array([0, 1, 50]),
                      _cohort_updates(key, np.arange(3)), rng=key)
    assert bank.n_pages(bs) == 2            # page 0 (rows 0-1) + page 25
    dense_bytes = sum(
        np.prod((100,) + np.shape(leaf)) * 4
        for leaf in jax.tree.leaves(params))
    assert bank.memory_bytes(bs)["host"] < dense_bytes / 4
    # untouched rows read as exact zeros
    for leaf in jax.tree.leaves(bank.gather(bs, np.array([7, 99]))):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_make_bank_rejects_unknown():
    with pytest.raises(ValueError, match="unknown bank backend"):
        make_bank("sqlite")


# --------------------------------------------------------------------------- #
# cohort round path through RoundRunner / run_fl
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", ["dense", "host", "paged_device"])
def test_banked_run_fl_matches_dense_mifa_trajectory(backend, tiny_problem):
    """Acceptance property: same params AND same per-round history."""
    model, batcher = tiny_problem(n_clients=10)
    kw = dict(model=model, batcher=batcher, schedule=lambda t: 0.1 / (1 + t),
              n_rounds=8, seed=0)
    part = lambda: BernoulliParticipation(np.full(10, 0.5), seed=1)
    p1, h1 = run_fl(algo=MIFA(memory="array"), participation=part(), **kw)
    p2, h2 = run_fl(algo=BankedMIFA(make_bank(backend)), participation=part(),
                    **kw)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(h1.train_loss, h2.train_loss,
                               rtol=1e-4, atol=1e-6)
    assert h1.n_active == h2.n_active


def test_step_cohort_skips_mask_work(tiny_problem):
    """Direct cohort stepping: ids in, O(|A|) batch out, same math."""
    model, batcher = tiny_problem(n_clients=10)
    r1 = RoundRunner(model=model, algo=BankedMIFA(DenseBank()),
                     batcher=batcher, schedule=lambda t: 0.1, seed=0)
    r2 = RoundRunner(model=model, algo=BankedMIFA(DenseBank()),
                     batcher=batcher, schedule=lambda t: 0.1, seed=0)
    rng = np.random.default_rng(0)
    for t in range(4):
        ids = np.sort(rng.choice(10, size=4, replace=False))
        mask = np.zeros(10, bool)
        mask[ids] = True
        r1.step(t, mask)
        r2.step_cohort(t, ids)
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert r1.hist.train_loss == r2.hist.train_loss
    assert r2.stats.rounds == 0          # τ stats skipped on the ids path


def test_empty_round_is_noop_for_params_memory(tiny_problem):
    model, batcher = tiny_problem(n_clients=10)
    runner = RoundRunner(model=model, algo=BankedMIFA(DenseBank()),
                         batcher=batcher, schedule=lambda t: 0.1, seed=0)
    runner.step(0, np.ones(10, bool))
    p_before = jax.tree.map(lambda x: np.array(x), runner.params)
    g_before = jax.tree.map(lambda x: np.array(x),
                            runner.state["bank"]["g_sum"])
    runner.step(1, np.zeros(10, bool))   # blackout round
    # memory unchanged; params still move by the memorized mean (MIFA!)
    for a, b in zip(jax.tree.leaves(g_before),
                    jax.tree.leaves(runner.state["bank"]["g_sum"])):
        np.testing.assert_array_equal(a, np.asarray(b))
    moved = any(
        not np.allclose(a, np.asarray(b)) for a, b in
        zip(jax.tree.leaves(p_before), jax.tree.leaves(runner.params)))
    assert moved


def test_pow2_bucketing():
    assert [_pow2_bucket(c) for c in (0, 1, 2, 3, 4, 5, 9)] == \
        [1, 1, 2, 4, 4, 8, 16]


def test_cohort_capacity_bounds_traces(tiny_problem):
    model, batcher = tiny_problem(n_clients=10)
    runner = RoundRunner(model=model, algo=BankedMIFA(DenseBank()),
                         batcher=batcher, schedule=lambda t: 0.1, seed=0,
                         cohort_capacity=8)
    # k=10 overflows the configured capacity: falls back to the pow2 bucket
    # instead of crashing mid-run
    for t, k in enumerate((3, 5, 1, 8, 10)):
        runner.step_cohort(t, np.arange(k))
    assert len(runner.hist.rounds) == 5


def test_duplicate_cohort_ids_rejected(tiny_problem):
    """Duplicates would silently corrupt G_sum — every entry point refuses."""
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    dup = np.array([1, 1, 4])
    cu = _cohort_updates(key, dup)
    for backend, kwargs in (("dense", {}), ("host", {}),
                            ("int8_paged", {"page_size": 4}),
                            ("paged_device", {"page_size": 4})):
        bank = make_bank(backend, **kwargs)
        bs = bank.init(params, N)
        with pytest.raises(ValueError, match="duplicate"):
            bank.scatter(bs, dup, cu, rng=key)
        # duplicates among invalid pad slots are fine (shared dummy row)
        bank.scatter(bs, np.array([1, N, N]), cu,
                     valid=np.array([True, False, False]), rng=key)
    model, batcher = tiny_problem(n_clients=10)
    runner = RoundRunner(model=model, algo=BankedMIFA(DenseBank()),
                         batcher=batcher, schedule=lambda t: 0.1, seed=0)
    with pytest.raises(ValueError, match="unique"):
        runner.step_cohort(0, np.array([2, 2]))


def test_duplicate_check_is_enforced_in_base_scatter():
    """The check lives in MemoryBank.scatter (template method) — backends
    implement `_scatter_rows` and MUST NOT override `scatter`, or they
    silently drift out from under the shared validation."""
    for cls in (DenseBank, HostBank, Int8PagedBank, PagedDeviceBank):
        assert cls.scatter is MemoryBank.scatter, cls
        assert cls._scatter_rows is not MemoryBank._scatter_rows, cls


# --------------------------------------------------------------------------- #
# batchers: compact == full slice
# --------------------------------------------------------------------------- #

def test_client_batcher_compact_matches_full(tiny_problem):
    _, batcher = tiny_problem(n_clients=10)
    full = batcher.sample_round(3)
    ids = np.array([7, 0, 4])
    compact = batcher.sample_round(3, client_ids=ids)
    for k in full:
        np.testing.assert_array_equal(compact[k], full[k][ids])


def test_procedural_batcher_compact_matches_full():
    b = ProceduralBatcher(n_clients=20, dim=6, n_classes=3, batch_size=4,
                          k_steps=2, seed=5)
    full = b.sample_round(2)
    ids = np.array([19, 3, 3, 11])
    compact = b.sample_round(2, client_ids=ids)
    for k in full:
        np.testing.assert_array_equal(compact[k], full[k][ids])
    # labels come from the shared teacher: learnable, multi-class
    assert set(np.unique(full["y"])) <= set(range(3))


def test_procedural_batcher_noniid_shift():
    b = ProceduralBatcher(n_clients=4, dim=8, batch_size=64, k_steps=1,
                          shift=3.0, noise=0.1, seed=0)
    batch = b.sample_round(0)
    means = batch["x"].mean(axis=(1, 2))         # (N, dim) per-client mean
    gaps = np.linalg.norm(means[:, None] - means[None, :], axis=-1)
    assert gaps[np.triu_indices(4, 1)].min() > 1.0


# --------------------------------------------------------------------------- #
# sharded bank rows
# --------------------------------------------------------------------------- #

def test_sharded_dense_bank_smoke():
    from repro.launch.mesh import data_parallel_size, make_host_mesh
    from repro.sharding.rules import padded_bank_rows
    mesh = make_host_mesh(1, 1)
    cfg = get_config("paper_logistic")
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    bank = DenseBank(mesh=mesh, cfg=cfg)
    bs = bank.init(params, N)
    assert bank.n_rows == padded_bank_rows(N, mesh) >= N + 1
    assert bank.n_rows % data_parallel_size(mesh) == 0
    ids = np.array([1, 6])
    bs = bank.scatter(bs, ids, _cohort_updates(key, ids))
    ref = DenseBank()
    rs = ref.scatter(ref.init(params, N), ids, _cohort_updates(key, ids))
    for a, b in zip(jax.tree.leaves(bank.mean_g(bs)),
                    jax.tree.leaves(ref.mean_g(rs))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# --------------------------------------------------------------------------- #
# paged device bank: eviction, determinism, page-table invariants
# --------------------------------------------------------------------------- #

# Cohorts chosen so that, at page_size=2 / n_slots=2, every round fits in the
# slot budget but the sequence as a whole forces evictions and refaults.
_EVICT_COHORTS = [[0, 1], [4, 5], [2, 3], [0, 5], [6, 7], [1, 2], [4], [0, 7]]


def _drive_cohorts(bank, cohorts, seed=3, needs_rng=False):
    """Scatter a fixed cohort sequence; return (state, per-round mean_g)."""
    key = jax.random.PRNGKey(seed)
    params = _tree(key)
    bs = bank.init(params, N)
    means = []
    for t, ids in enumerate(cohorts):
        ids = np.array(ids)
        k = jax.random.fold_in(key, t)
        rng = jax.random.fold_in(k, 1) if needs_rng else None
        bs = bank.scatter(bs, ids, _cohort_updates(k, ids), rng=rng)
        means.append(bank.mean_g(bs))
    return bs, means


def test_paged_eviction_matches_dense():
    """Evicting paged bank is bit-exact vs DenseBank: physical placement is
    invisible because reductions run over the cohort axis, never slots."""
    paged = PagedDeviceBank(page_size=2, n_slots=2)
    dense = DenseBank()
    ps, pm = _drive_cohorts(paged, _EVICT_COHORTS)
    ds, dm = _drive_cohorts(dense, _EVICT_COHORTS)
    assert paged.faults > 0 and paged.evictions > 0
    for a, b in zip(jax.tree.leaves(pm), jax.tree.leaves(dm)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(paged.gather(ps, np.arange(N))),
                    jax.tree.leaves(dense.gather(ds, np.arange(N)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_eviction_refault_determinism():
    """Same cohort sequence twice => identical trajectory AND identical
    fault/eviction counters (deterministic LRU, no tie-break wobble)."""
    runs = []
    for _ in range(2):
        bank = PagedDeviceBank(page_size=2, n_slots=2)
        bs, means = _drive_cohorts(bank, _EVICT_COHORTS)
        runs.append((bank, bank.gather(bs, np.arange(N)), means))
    (b1, g1, m1), (b2, g2, m2) = runs
    assert (b1.faults, b1.evictions) == (b2.faults, b2.evictions)
    assert b1.faults > 0 and b1.evictions > 0
    for a, b in zip(jax.tree.leaves((g1, m1)), jax.tree.leaves((g2, m2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_page_table_invariants_after_eviction():
    """No aliased slots, free-list conservation, dummy page pinned at zero —
    checked after an eviction-heavy sequence."""
    bank = PagedDeviceBank(page_size=2, n_slots=2)
    bs, _ = _drive_cohorts(bank, _EVICT_COHORTS)
    bank.check_invariants(bs)
    assert bank.n_resident() <= 2


def test_paged_working_set_overflow_raises():
    bank = PagedDeviceBank(page_size=2, n_slots=2)
    key = jax.random.PRNGKey(0)
    bs = bank.init(_tree(key), N)
    ids = np.array([0, 2, 4])        # spans 3 pages, only 2 slots
    with pytest.raises(ValueError, match="slots"):
        bank.scatter(bs, ids, _cohort_updates(key, ids))


def test_paged_device_bytes_bounded_by_slots():
    """Device page-pool bytes depend on n_slots, not on n_clients."""
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    small = PagedDeviceBank(page_size=2, n_slots=2)
    big = PagedDeviceBank(page_size=2, n_slots=2)
    ss = small.init(params, N)
    sb = big.init(params, 64 * N)
    assert (small.memory_bytes(ss)["device_pages"]
            == big.memory_bytes(sb)["device_pages"])


def test_paged_pallas_path_matches_jnp():
    b1 = PagedDeviceBank(page_size=2, n_slots=2, use_pallas=False)
    b2 = PagedDeviceBank(page_size=2, n_slots=2, use_pallas=True)
    s1, m1 = _drive_cohorts(b1, _EVICT_COHORTS)
    s2, m2 = _drive_cohorts(b2, _EVICT_COHORTS)
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(b1.gather(s1, np.arange(N))),
                    jax.tree.leaves(b2.gather(s2, np.arange(N)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
