"""Exactness of the perf-loop model variants (EXPERIMENTS.md §Perf):
chunked CE and attention-head padding must be loss- AND grad-equal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model

B, S = 2, 48


def _batch(cfg, rng):
    if cfg.modality == "vision_text":
        return {"tokens": jax.random.randint(rng, (B, S - cfg.n_patches), 0,
                                             cfg.vocab_size),
                "patches": jax.random.normal(
                    rng, (B, cfg.n_patches, cfg.d_model)) * 0.02}
    if cfg.modality == "audio":
        return {"frames": jax.random.normal(rng, (B, S, cfg.d_model)),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


def _loss_and_grads(cfg, params, batch):
    model = build_model(cfg)
    loss, _ = model.loss_fn(params, batch)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    return float(loss), grads


@pytest.mark.parametrize("arch", ["granite_3_8b", "llava_next_34b",
                                  "hubert_xlarge", "gemma3_4b"])
def test_chunked_ce_exact(arch):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32",
                                         param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l0, g0 = _loss_and_grads(cfg, params, batch)
    l1, g1 = _loss_and_grads(cfg.replace(ce_chunk=16), params, batch)
    assert abs(l0 - l1) < 2e-6
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)


@pytest.mark.parametrize("arch,pq,pkv", [("llava_next_34b", 16, 4),
                                         ("gemma3_4b", 8, 4),
                                         ("granite_3_8b", 16, 4)])
def test_head_padding_exact(arch, pq, pkv):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32",
                                         param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l0, g0 = _loss_and_grads(cfg, params, batch)
    l1, g1 = _loss_and_grads(cfg.replace(pad_q_heads=pq, pad_kv_heads=pkv),
                             params, batch)
    assert l0 == l1  # padding is pure layout: bitwise identical
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_head_padding_prefill_decode_consistent():
    """Padded prefill writes unpadded caches; decode stays consistent."""
    cfg = get_smoke_config("gemma3_4b").replace(
        compute_dtype="float32", param_dtype="float32",
        pad_q_heads=8, pad_kv_heads=4)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits_pre, _ = model.prefill(params, {"tokens": tokens},
                                  model.init_cache(B, 64))
    _, cache = model.prefill(params, {"tokens": tokens[:, :-1]},
                             model.init_cache(B, 64))
    logits_dec, _ = model.decode_step(params, tokens[:, -1:],
                                      jnp.int32(S - 1), cache)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_pre), rtol=2e-4, atol=2e-5)
