"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mifa_aggregate import mifa_aggregate
from repro.kernels.ops import mifa_aggregate_tree
from repro.kernels.ref import (flash_attention_ref, mifa_aggregate_ref,
                               ssd_scan_ref)
from repro.kernels.ssd_scan import ssd_scan


# --------------------------------------------------------------------------- #
# mifa_aggregate
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("n,m", [(4, 256), (16, 1024), (7, 512), (100, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mifa_aggregate_sweep(n, m, dtype):
    rng = jax.random.PRNGKey(n * m)
    g = (jax.random.normal(rng, (n, m))).astype(dtype)
    u = jax.random.normal(jax.random.fold_in(rng, 1), (n, m))
    active = jax.random.bernoulli(jax.random.fold_in(rng, 2), 0.5, (n,))
    w = (jax.random.normal(jax.random.fold_in(rng, 3), (m,))).astype(dtype)
    eta = 0.07
    gn, wn = mifa_aggregate(g, u, active, w, eta, block_m=128)
    gr, wr = mifa_aggregate_ref(g, u, active, w, eta)
    np.testing.assert_allclose(np.asarray(gn, np.float32),
                               np.asarray(gr, np.float32), rtol=1e-6)
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(wn, np.float32),
                               np.asarray(wr, np.float32),
                               rtol=tol, atol=tol)


def test_mifa_aggregate_all_inactive_keeps_memory():
    g = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    u = jnp.full((3, 4), 99.0)
    w = jnp.zeros(4)
    gn, wn = mifa_aggregate(g, u, jnp.zeros(3, bool), w, 1.0, block_m=4)
    np.testing.assert_array_equal(np.asarray(gn), np.asarray(g))
    np.testing.assert_allclose(np.asarray(wn), -np.asarray(g).mean(0))


def test_mifa_aggregate_tree_matches_per_leaf():
    rng = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(rng, (17, 9)),
              "b": {"c": jax.random.normal(jax.random.fold_in(rng, 1), (33,))}}
    n = 6
    g = jax.tree.map(lambda p: jnp.zeros((n,) + p.shape), params)
    u = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(rng, 2),
                                    (n,) + p.shape), params)
    active = jnp.array([1, 0, 1, 1, 0, 1], bool)
    g2, p2 = mifa_aggregate_tree(g, u, active, params, 0.1, block_m=64)
    for path in (("a",), ("b", "c")):
        gg = g[path[0]] if len(path) == 1 else g["b"]["c"]
        uu = u[path[0]] if len(path) == 1 else u["b"]["c"]
        pp = params[path[0]] if len(path) == 1 else params["b"]["c"]
        gn = g2[path[0]] if len(path) == 1 else g2["b"]["c"]
        pn = p2[path[0]] if len(path) == 1 else p2["b"]["c"]
        gr, wr = mifa_aggregate_ref(gg.reshape(n, -1), uu.reshape(n, -1),
                                    active, pp.reshape(-1), 0.1)
        np.testing.assert_allclose(np.asarray(gn).reshape(n, -1),
                                   np.asarray(gr), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(pn).reshape(-1),
                                   np.asarray(wr), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("s,h,kv,hd", [(128, 4, 4, 32), (256, 4, 2, 64),
                                       (128, 8, 1, 16)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, kv, hd, causal, dtype):
    rng = jax.random.PRNGKey(s + h)
    B = 2
    q = jax.random.normal(rng, (B, s, h, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, s, kv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, s, kv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_matches_model_blockwise_path():
    """Kernel == the model zoo's jnp blockwise attention (same contraction)."""
    from repro.models.attention import blockwise_attention
    rng = jax.random.PRNGKey(3)
    q = jax.random.normal(rng, (1, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 128, 2, 32))
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    b = blockwise_attention(q, k, v, causal=True, q_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# --------------------------------------------------------------------------- #
# ssd scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("s,h,p,n,chunk", [(64, 2, 8, 16, 16),
                                           (128, 3, 16, 32, 32),
                                           (96, 1, 32, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(s, h, p, n, chunk, dtype):
    rng = jax.random.PRNGKey(s * h)
    b = 2
    x = jax.random.normal(rng, (b, s, h, p)).astype(dtype)
    dA = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 1),
                                            (b, s, h)))
    B = (jax.random.normal(jax.random.fold_in(rng, 2), (b, s, n)) * 0.5)
    C = (jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n)) * 0.5)
    y, hf = ssd_scan(x, dA, B, C, chunk=chunk)
    yr, hr = ssd_scan_ref(x.astype(jnp.float32), dA, B, C)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), atol=tol)


def test_ssd_kernel_matches_model_chunked_path():
    from repro.models.ssm import ssd_chunked
    rng = jax.random.PRNGKey(9)
    b, s, h, p, n = 1, 64, 2, 8, 16
    x = jax.random.normal(rng, (b, s, h, p))
    dA = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 1),
                                            (b, s, h)))
    B = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n)) * 0.5
    y1, h1 = ssd_scan(x, dA, B, C, chunk=16)
    y2, h2 = ssd_chunked(x, dA, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5)


# --------------------------------------------------------------------------- #
# bank_scatter (fused cohort gather/delta/scatter)
# --------------------------------------------------------------------------- #

def _bank_scatter_ref(bank, updates, ids, valid):
    bank = np.array(bank, np.float32)
    dsum = np.zeros(bank.shape[1], np.float32)
    for a in range(len(ids)):
        if valid[a]:
            dsum += np.asarray(updates)[a] - bank[int(ids[a])]
            bank[int(ids[a])] = np.asarray(updates)[a]
    return bank, dsum


@pytest.mark.parametrize("r,c,m", [(9, 4, 256), (33, 8, 512), (5, 2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bank_scatter_sweep(r, c, m, dtype):
    from repro.kernels.bank_scatter import bank_scatter
    rng = jax.random.PRNGKey(r * m + c)
    bank = jax.random.normal(rng, (r, m)).astype(dtype)
    u = jax.random.normal(jax.random.fold_in(rng, 1), (c, m))
    ids = jax.random.choice(jax.random.fold_in(rng, 2), r - 1, (c,),
                            replace=False)
    valid = jax.random.bernoulli(jax.random.fold_in(rng, 3), 0.8, (c,))
    bn, ds = bank_scatter(bank, u, ids, valid, block_m=128)
    # reference applies the same masked writes on the *stored* (dtype-cast)
    # values — the kernel's delta must track what lands in the bank
    u_st = np.asarray(u.astype(dtype), np.float32)
    br, dr = _bank_scatter_ref(np.asarray(bank, np.float32), u_st,
                               np.asarray(ids), np.asarray(valid))
    np.testing.assert_allclose(np.asarray(bn, np.float32), br,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ds), dr, rtol=1e-4, atol=1e-5)


def test_bank_scatter_all_invalid_is_noop():
    from repro.kernels.bank_scatter import bank_scatter
    bank = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    u = jnp.full((3, 4), 99.0)
    ids = jnp.array([5, 5, 5])                 # shared dummy row
    bn, ds = bank_scatter(bank, u, ids, jnp.zeros(3, bool), block_m=4)
    np.testing.assert_array_equal(np.asarray(bn), np.asarray(bank))
    np.testing.assert_array_equal(np.asarray(ds), 0.0)


def test_bank_update_tree_pads_and_matches():
    from repro.kernels.ops import bank_update_tree
    rng = jax.random.PRNGKey(4)
    rows = {"a": jax.random.normal(rng, (7, 5, 3)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (7, 9))}
    u = {"a": jax.random.normal(jax.random.fold_in(rng, 2), (2, 5, 3)),
         "b": jax.random.normal(jax.random.fold_in(rng, 3), (2, 9))}
    ids = jnp.array([1, 6])
    valid = jnp.array([True, False])
    rn, ds = bank_update_tree(rows, u, ids, valid, block_m=8)
    for key_, shape in (("a", (5, 3)), ("b", (9,))):
        br, dr = _bank_scatter_ref(
            np.asarray(rows[key_]).reshape(7, -1),
            np.asarray(u[key_]).reshape(2, -1),
            np.asarray(ids), np.asarray(valid))
        np.testing.assert_allclose(np.asarray(rn[key_]).reshape(7, -1), br,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ds[key_]).reshape(-1), dr,
                                   rtol=1e-5, atol=1e-6)
        assert ds[key_].shape == shape
