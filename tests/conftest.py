"""Shared test scaffolding: import path, device pinning, tiny-problem
fixtures, and the `slow` / `mesh8` markers.

Tier-1 (`pytest -x -q`) deselects tests marked `@pytest.mark.slow`; run
them with `--runslow`. The session-scoped factories below memoise the
small synthetic FL problems that used to be copy-pasted per test file —
one construction per distinct shape, shared by every test that asks.

Multi-device tests (the `mesh8` marker)
---------------------------------------
XLA only honours `--xla_force_host_platform_device_count` if it is set
before the backend initialises, so a multi-device world cannot be opened
inside an already-running pytest process — it must be a SUBPROCESS, the
same mechanism `launch/dryrun.py` uses for its 512-device world. The
pattern:

  * tests that need 8 host devices carry `@pytest.mark.mesh8` and take the
    `mesh8_world` fixture (which builds meshes via
    `launch.mesh.make_host_mesh` and skips cleanly if JAX initialised
    before the flag landed);
  * in a normal tier-1 run (`REPRO_MESH8_WORLD` unset) those tests are
    skipped at collection, and the un-marked proxy
    `tests/test_sharded_scan.py::test_mesh8_subprocess_suite` spawns
    `pytest -m mesh8` in a subprocess with the forced-device environment —
    so tier-1 still exercises the whole multi-device suite, one world per
    run;
  * CI's mesh-smoke step runs `pytest -m mesh8` directly with the same
    environment (see .github/workflows/ci.yml).

The world also sets `JAX_THREEFRY_PARTITIONABLE=1`: the legacy threefry
lowering generates different random bits when operands are sharded, so
sharded-vs-single parity is only well-defined under the partitionable
implementation (docs/architecture.md §13).
"""
import functools
import os
import sys

# Make `src/` importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests and benches must see exactly ONE device (the dry-run sets its own
# XLA_FLAGS in a subprocess); keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

MESH8_WORLD_ENV = "REPRO_MESH8_WORLD"
MESH8_ENV = {
    MESH8_WORLD_ENV: "1",
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "JAX_PLATFORMS": "cpu",
    "JAX_THREEFRY_PARTITIONABLE": "1",
}

if os.environ.get(MESH8_WORLD_ENV):
    # belt-and-braces for a hand-launched world: conftest imports before
    # the test modules touch JAX, so these still land in time unless a
    # plugin initialised the backend first (mesh8_world skips then)
    for _k, _v in MESH8_ENV.items():
        os.environ.setdefault(_k, _v)

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy test, deselected from tier-1 (enable with --runslow)")
    config.addinivalue_line(
        "markers",
        "mesh8: needs 8 forced host devices; runs inside the subprocess "
        "world (REPRO_MESH8_WORLD=1 + XLA_FLAGS, see conftest docstring)")


def pytest_collection_modifyitems(config, items):
    if not os.environ.get(MESH8_WORLD_ENV):
        skip8 = pytest.mark.skip(
            reason="mesh8: runs in the forced-8-device subprocess world "
                   "(driven by test_sharded_scan.py::"
                   "test_mesh8_subprocess_suite)")
        for item in items:
            if "mesh8" in item.keywords:
                item.add_marker(skip8)
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow: tier-1 deselects (--runslow)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def mesh8_world():
    """Gate for `mesh8` tests: asserts this process actually has the 8
    forced host devices, skipping cleanly when JAX initialised before
    XLA_FLAGS could land (e.g. an eager plugin in a hand-launched world)."""
    import jax
    n = len(jax.devices())
    if n < 8:
        pytest.skip(f"mesh8 world has only {n} device(s): JAX initialised "
                    "before --xla_force_host_platform_device_count took "
                    "effect")
    return n


@pytest.fixture(autouse=True)
def _fresh_fallback_warnings():
    """Engine-fallback warnings dedupe once-per-config per process
    (core.runner.warn_engine_fallback); tests asserting on them need each
    test to start with a clean slate."""
    from repro.core.runner import _reset_fallback_warnings
    _reset_fallback_warnings()
    yield


# --------------------------------------------------------------------------- #
# tiny-problem factories (session-scoped, memoised per shape)
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="session")
def tiny_problem():
    """Factory: tiny_problem(n_clients=10, ...) -> (model, batcher).

    The paper's logistic setup shrunk to test size. Memoised — model and
    batcher are stateless after construction, so tests share them freely.
    """
    from repro.configs import get_config
    from repro.data import (ClientBatcher, label_skew_partition,
                            make_classification)
    from repro.models import build_model

    @functools.lru_cache(maxsize=None)
    def make(n_clients=10, seed=0, n_per_class=40, batch_size=8, k_steps=2,
             model_name="paper_logistic"):
        cfg = get_config(model_name).replace(fl_clients=n_clients)
        model = build_model(cfg)
        X, y = make_classification(10, cfg.d_model, n_per_class, noise=1.0,
                                   seed=seed)
        idx, _ = label_skew_partition(y, n_clients, seed=seed)
        batcher = ClientBatcher(X, y, idx, batch_size=batch_size,
                                k_steps=k_steps, seed=seed)
        return model, batcher

    return make


@pytest.fixture(scope="session")
def tiny_runner(tiny_problem):
    """Factory: tiny_runner(algo, n_clients=10, seed=0, **problem_kw) ->
    RoundRunner on the shared tiny problem."""
    def make(algo, *, n_clients=10, seed=0, schedule=None, **problem_kw):
        from repro.core import RoundRunner
        from repro.optim import inv_t
        model, batcher = tiny_problem(n_clients=n_clients, **problem_kw)
        return RoundRunner(model=model, algo=algo, batcher=batcher,
                           schedule=schedule or inv_t(1.0),
                           weight_decay=1e-3, seed=seed)
    return make


@pytest.fixture(scope="session")
def bernoulli_part():
    """Factory: bernoulli_part(n, p=0.5, seed=0) -> BernoulliParticipation."""
    import numpy as np
    from repro.core import BernoulliParticipation

    def make(n, p=0.5, seed=0):
        return BernoulliParticipation(np.full(n, p), seed=seed)
    return make
