"""Shared test scaffolding: import path, device pinning, tiny-problem
fixtures, and the `slow` marker.

Tier-1 (`pytest -x -q`) deselects tests marked `@pytest.mark.slow`; run
them with `--runslow`. The session-scoped factories below memoise the
small synthetic FL problems that used to be copy-pasted per test file —
one construction per distinct shape, shared by every test that asks.
"""
import functools
import os
import sys

# Make `src/` importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests and benches must see exactly ONE device (the dry-run sets its own
# XLA_FLAGS in a subprocess); keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy test, deselected from tier-1 (enable with --runslow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow: tier-1 deselects (--runslow)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _fresh_fallback_warnings():
    """Engine-fallback warnings dedupe once-per-config per process
    (core.runner.warn_engine_fallback); tests asserting on them need each
    test to start with a clean slate."""
    from repro.core.runner import _reset_fallback_warnings
    _reset_fallback_warnings()
    yield


# --------------------------------------------------------------------------- #
# tiny-problem factories (session-scoped, memoised per shape)
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="session")
def tiny_problem():
    """Factory: tiny_problem(n_clients=10, ...) -> (model, batcher).

    The paper's logistic setup shrunk to test size. Memoised — model and
    batcher are stateless after construction, so tests share them freely.
    """
    from repro.configs import get_config
    from repro.data import (ClientBatcher, label_skew_partition,
                            make_classification)
    from repro.models import build_model

    @functools.lru_cache(maxsize=None)
    def make(n_clients=10, seed=0, n_per_class=40, batch_size=8, k_steps=2,
             model_name="paper_logistic"):
        cfg = get_config(model_name).replace(fl_clients=n_clients)
        model = build_model(cfg)
        X, y = make_classification(10, cfg.d_model, n_per_class, noise=1.0,
                                   seed=seed)
        idx, _ = label_skew_partition(y, n_clients, seed=seed)
        batcher = ClientBatcher(X, y, idx, batch_size=batch_size,
                                k_steps=k_steps, seed=seed)
        return model, batcher

    return make


@pytest.fixture(scope="session")
def tiny_runner(tiny_problem):
    """Factory: tiny_runner(algo, n_clients=10, seed=0, **problem_kw) ->
    RoundRunner on the shared tiny problem."""
    def make(algo, *, n_clients=10, seed=0, schedule=None, **problem_kw):
        from repro.core import RoundRunner
        from repro.optim import inv_t
        model, batcher = tiny_problem(n_clients=n_clients, **problem_kw)
        return RoundRunner(model=model, algo=algo, batcher=batcher,
                           schedule=schedule or inv_t(1.0),
                           weight_decay=1e-3, seed=seed)
    return make


@pytest.fixture(scope="session")
def bernoulli_part():
    """Factory: bernoulli_part(n, p=0.5, seed=0) -> BernoulliParticipation."""
    import numpy as np
    from repro.core import BernoulliParticipation

    def make(n, p=0.5, seed=0):
        return BernoulliParticipation(np.full(n, p), seed=seed)
    return make
