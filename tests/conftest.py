import os
import sys

# Make `src/` importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests and benches must see exactly ONE device (the dry-run sets its own
# XLA_FLAGS in a subprocess); keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
