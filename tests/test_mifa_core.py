"""MIFA algorithm semantics (paper Algorithm 1 + §4 delta variant)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MIFA, BiasedFedAvg

N = 6


def _tree(rng, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (4, 3)) * scale,
            "b": jax.random.normal(k2, (3,)) * scale}


def _updates(rng, n=N, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (n, 4, 3)) * scale,
            "b": jax.random.normal(k2, (n, 3)) * scale}


def test_mifa_equals_fedavg_when_all_active():
    """Remark 5.1: with full participation MIFA reduces to FedAvg exactly."""
    rng = jax.random.PRNGKey(0)
    params = _tree(rng)
    algo_m, algo_f = MIFA(memory="array"), BiasedFedAvg()
    sm = algo_m.init_state(params, N)
    sf = algo_f.init_state(params, N)
    pm, pf = params, params
    for t in range(4):
        u = _updates(jax.random.PRNGKey(t + 1))
        losses = jnp.zeros(N)
        active = jnp.ones(N, bool)
        sm, pm, _ = algo_m.round_step(sm, pm, u, losses, active, jnp.float32(0.1))
        sf, pf, _ = algo_f.round_step(sf, pf, u, losses, active, jnp.float32(0.1))
    for a, b in zip(jax.tree.leaves(pm), jax.tree.leaves(pf)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_mifa_reuses_stale_updates():
    """An inactive device's memorized update keeps contributing."""
    params = {"w": jnp.zeros((2,))}
    algo = MIFA(memory="array")
    state = algo.init_state(params, 2)
    # round 1: both active; device 0 pushes +1, device 1 pushes -3
    u1 = {"w": jnp.array([[1.0, 1.0], [-3.0, -3.0]])}
    state, params, _ = algo.round_step(state, params, u1, jnp.zeros(2),
                                       jnp.array([True, True]), jnp.float32(1.0))
    np.testing.assert_allclose(params["w"], [1.0, 1.0])  # -1 * mean([1,-3])
    # round 2: only device 0 active with a fresh update +5; device 1 stale -3
    u2 = {"w": jnp.array([[5.0, 5.0], [999.0, 999.0]])}   # 999 must be ignored
    state, params, _ = algo.round_step(state, params, u2, jnp.zeros(2),
                                       jnp.array([True, False]), jnp.float32(1.0))
    np.testing.assert_allclose(params["w"], [0.0, 0.0])  # 1 - mean([5,-3]) = 0
    np.testing.assert_allclose(state["G"]["w"][1], [-3.0, -3.0])


def test_delta_variant_identical_to_array():
    """§4 'Discussion on implementation': the Ḡ running-mean form is exact."""
    rng = jax.random.PRNGKey(0)
    params = _tree(rng)
    a1, a2 = MIFA(memory="array"), MIFA(memory="delta")
    s1, s2 = a1.init_state(params, N), a2.init_state(params, N)
    p1, p2 = params, params
    key = jax.random.PRNGKey(99)
    for t in range(8):
        key, k1, k2 = jax.random.split(key, 3)
        u = _updates(k1)
        active = jax.random.bernoulli(k2, 0.5, (N,))
        if t == 0:
            active = jnp.ones(N, bool)
        eta = jnp.float32(0.1 / (t + 1))
        s1, p1, _ = a1.round_step(s1, p1, u, jnp.zeros(N), active, eta)
        s2, p2, _ = a2.round_step(s2, p2, u, jnp.zeros(N), active, eta)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)


def test_int8_memory_close_to_float():
    rng = jax.random.PRNGKey(0)
    params = _tree(rng)
    a1, a2 = MIFA(memory="array"), MIFA(memory="int8")
    s1, s2 = a1.init_state(params, N), a2.init_state(params, N)
    p1, p2 = params, params
    key = jax.random.PRNGKey(5)
    for t in range(5):
        key, k1, k2, k3 = jax.random.split(key, 4)
        u = _updates(k1, scale=0.1)
        active = jax.random.bernoulli(k2, 0.6, (N,))
        if t == 0:
            active = jnp.ones(N, bool)
        eta = jnp.float32(0.05)
        s1, p1, _ = a1.round_step(s1, p1, u, jnp.zeros(N), active, eta)
        s2, p2, _ = a2.round_step(s2, p2, u, jnp.zeros(N), active, eta, rng=k3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # int8 quantization error per round <= eta * scale/127 * rounds-ish
        np.testing.assert_allclose(a, b, atol=5e-3)


def test_int8_inactive_entries_bitstable():
    """Inactive devices' stored int8 updates must not drift across rounds."""
    params = {"w": jnp.zeros((3,))}
    algo = MIFA(memory="int8")
    state = algo.init_state(params, 2)
    u = {"w": jnp.array([[0.3, -0.2, 0.1], [1.0, 2.0, -1.0]])}
    key = jax.random.PRNGKey(0)
    state, params, _ = algo.round_step(state, params, u, jnp.zeros(2),
                                       jnp.array([True, True]),
                                       jnp.float32(0.1), rng=key)
    stored = np.asarray(state["G_q"]["w"][1])
    for t in range(3):
        u2 = {"w": jnp.array([[0.5, 0.5, 0.5], [7.0, 7.0, 7.0]])}
        state, params, _ = algo.round_step(state, params, u2, jnp.zeros(2),
                                           jnp.array([True, False]),
                                           jnp.float32(0.1),
                                           rng=jax.random.PRNGKey(t + 1))
        np.testing.assert_array_equal(np.asarray(state["G_q"]["w"][1]), stored)


def test_mifa_jits_and_round_counts():
    params = {"w": jnp.zeros((2,))}
    algo = MIFA(memory="array")
    state = algo.init_state(params, 3)
    step = jax.jit(algo.round_step)
    u = {"w": jnp.ones((3, 2))}
    state, params, m = step(state, params, u, jnp.zeros(3),
                            jnp.array([True, True, False]), jnp.float32(1.0))
    assert int(state["t"]) == 1
    assert float(m["n_active"]) == 2.0
