"""Serving path: prefill -> decode consistency for every cached architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model

DECODE_ARCHS = [a for a in ARCH_IDS if a != "hubert_xlarge"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32",
                                         param_dtype="float32")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    B, S, cache_len = 2, 48, 64
    if cfg.modality == "vision_text":
        tokens = jax.random.randint(rng, (B, S - cfg.n_patches), 0,
                                    cfg.vocab_size)
        extra = {"patches": jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model)) * 0.02}
    else:
        tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        extra = {}

    logits_pre, _ = jax.jit(model.prefill)(
        params, {"tokens": tokens, **extra}, model.init_cache(B, cache_len))
    _, cache = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :-1], **extra},
        model.init_cache(B, cache_len))
    logits_dec, _ = jax.jit(model.decode_step)(
        params, tokens[:, -1:], jnp.int32(S - 1), cache)

    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_pre),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("arch", ["granite_3_8b", "mamba2_1_3b", "gemma3_4b"])
def test_multi_step_decode(arch):
    """Decode 8 tokens autoregressively; logits stay finite, cache advances."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B = 2
    tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab_size)
    cache = model.init_cache(B, 32)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens}, cache)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(8):
        logits, cache = step(params, tok, jnp.int32(8 + i), cache)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        tok = jnp.argmax(logits, -1)[:, None]


def test_sliding_window_cache_is_ring_buffer():
    """gemma3 local layers keep only `window` KV entries."""
    cfg = get_smoke_config("gemma3_4b")
    model = build_model(cfg)
    cache = model.init_cache(batch=2, cache_len=64)
    from repro.models.transformer import build_segments
    segs = build_segments(cfg)
    for seg in segs:
        if seg.kind == "local_attn":
            assert cache[str(seg.index)]["k"].shape[2] == cfg.swa_window
        elif seg.kind == "attn":
            assert cache[str(seg.index)]["k"].shape[2] == 64


def test_long_context_window_decode_consistency():
    """Decode past the window: ring buffer must forget old tokens correctly."""
    cfg = get_smoke_config("gemma3_4b").replace(
        compute_dtype="float32", param_dtype="float32",
        swa_pattern=1_000_000, swa_window=8)  # all layers local, tiny window
    # swa_pattern huge => every layer local
    model = build_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    B, S = 1, 24
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    # reference: full prefill of S tokens (window masking exact in prefill)
    logits_ref, _ = jax.jit(model.prefill)(
        params, {"tokens": tokens}, model.init_cache(B, S))
    # decode path: prefill S-1 then one decode step
    _, cache = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :-1]}, model.init_cache(B, S))
    logits_dec, _ = jax.jit(model.decode_step)(
        params, tokens[:, -1:], jnp.int32(S - 1), cache)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_ref),
                               rtol=2e-4, atol=2e-5)
