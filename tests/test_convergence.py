"""Integration: the paper's §7 claims on the synthetic strongly convex task.

C1 (Fig. 2 ordering): under label-correlated Bernoulli stragglers,
  - MIFA converges and reaches high accuracy,
  - device-sampling FedAvg is much slower (straggler waiting, Eq. 3),
  - biased FedAvg keeps a bias gap,
  - MIFA is competitive with FedAvg-IS (which *knows* the probabilities).
C4 (Remark 5.1): with all devices active MIFA ≡ FedAvg trajectory.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (MIFA, BiasedFedAvg, FedAvgIS, FedAvgSampling,
                        BernoulliParticipation, label_correlated_probs, run_fl)
from repro.data import ClientBatcher, label_skew_partition, make_classification
from repro.models import build_model
from repro.optim import inv_t


@pytest.fixture(scope="module")
def fl_problem():
    cfg = get_config("paper_logistic").replace(fl_clients=30)
    model = build_model(cfg)
    X, y = make_classification(10, cfg.d_model, 300, noise=1.0, seed=0)
    Xte, yte = make_classification(10, cfg.d_model, 40, noise=1.0, seed=9)
    idx, labels = label_skew_partition(y, cfg.fl_clients, seed=0)
    probs = label_correlated_probs(labels, p_min=0.1)
    batcher = ClientBatcher(X, y, idx, batch_size=32, k_steps=5, seed=0)

    def eval_fn(params):
        batch = {"x": jnp.asarray(Xte), "y": jnp.asarray(yte)}
        loss, _ = model.loss_fn(params, batch)
        return loss, model.accuracy(params, batch)

    return cfg, model, batcher, probs, eval_fn


def _run(model, batcher, algo, probs, eval_fn, T=120, seed=3, clock=False):
    part = BernoulliParticipation(probs, seed=seed)
    return run_fl(model=model, algo=algo, participation=part, batcher=batcher,
                  schedule=inv_t(1.0), n_rounds=T, weight_decay=1e-3,
                  seed=0, eval_fn=eval_fn, eval_every=T,
                  uses_update_clock=clock)


def test_mifa_converges_under_stragglers(fl_problem):
    cfg, model, batcher, probs, eval_fn = fl_problem
    _, hist = _run(model, batcher, MIFA(memory="array"), probs, eval_fn)
    assert hist.eval_acc[-1][1] > 0.9
    assert hist.eval_loss[-1][1] < 1.5


def test_mifa_beats_device_sampling(fl_problem):
    cfg, model, batcher, probs, eval_fn = fl_problem
    _, h_mifa = _run(model, batcher, MIFA(memory="array"), probs, eval_fn)
    _, h_samp = _run(model, batcher, FedAvgSampling(s=10), probs, eval_fn,
                     clock=True)
    assert h_mifa.eval_loss[-1][1] < h_samp.eval_loss[-1][1]


def test_mifa_competitive_with_is(fl_problem):
    """MIFA (agnostic) within a modest factor of IS (knows the p_i)."""
    cfg, model, batcher, probs, eval_fn = fl_problem
    _, h_mifa = _run(model, batcher, MIFA(memory="array"), probs, eval_fn)
    _, h_is = _run(model, batcher, FedAvgIS(tuple(probs.tolist())), probs,
                   eval_fn)
    assert h_mifa.eval_loss[-1][1] < 2.0 * h_is.eval_loss[-1][1]


def test_biased_fedavg_retains_bias(fl_problem):
    """Rare devices hold the small labels; biased FedAvg underfits them."""
    cfg, model, batcher, probs, eval_fn = fl_problem
    pm, _ = _run(model, batcher, MIFA(memory="array"), probs, eval_fn)
    pb, _ = _run(model, batcher, BiasedFedAvg(), probs, eval_fn)
    # per-class accuracy on the classes held by stragglers (labels 0/1)
    Xte, yte = make_classification(10, cfg.d_model, 60, noise=1.0, seed=11)
    m = np.isin(yte, [0, 1])
    batch = {"x": jnp.asarray(Xte[m]), "y": jnp.asarray(yte[m])}
    acc_m = float(model.accuracy(pm, batch))
    acc_b = float(model.accuracy(pb, batch))
    assert acc_m >= acc_b - 0.02  # MIFA at least matches on straggler classes
