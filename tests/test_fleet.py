"""Fleet executor: vmapped K-trial runs are bit-exact per trial.

The anchor property for `repro.fleet`: for every algorithm on the vmapped
path, running K trials as one jitted program yields *exactly* (fp32
bit-exact) the parameters and loss history that K sequential `run_fl` calls
produce — so sweep results never depend on which execution path ran them.

The property is enforced twice: on deterministic trace sets covering the
degenerate shapes (all-dark trials, full cohorts, mixed cohort sizes
sharing one padded capacity) which always run, and on hypothesis-generated
traces when hypothesis is installed (CI installs requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bank import BankedMIFA, DenseBank, HostBank, PagedDeviceBank
from repro.core import (MIFA, BiasedFedAvg, FedAvgSampling,
                        TraceParticipation, run_fl)
from repro.fleet import (FleetRunner, Trial, expand_grid, make_fleet_eval,
                         run_fleet)

try:
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:            # tier-1 containers without dev extras
    HAVE_HYPOTHESIS = False

N, T, K = 6, 4, 3

ALGOS = {
    "mifa_array": (lambda: MIFA(memory="array"), False),
    "banked_dense": (lambda: BankedMIFA(DenseBank()), False),
    "banked_paged": (lambda: BankedMIFA(PagedDeviceBank(page_size=2)), False),
    "fedavg": (lambda: BiasedFedAvg(), False),
    "wait_for_s": (lambda: FedAvgSampling(s=3), True),
}


def _run_pair(tiny_problem, algo_factory, traces, clock):
    """(sequential per-trial results, fleet results) for identical trials.

    The cohort capacity is pinned to one shared value on BOTH paths: pad
    slots are mathematically inert, but fp32 reduction grouping depends on
    the padded length, so bit-exact comparison needs matching pad widths
    (run_fl's docstring spells this out).
    """
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher, schedule=lambda t: 0.1 / (1 + t),
              n_rounds=traces.shape[1], weight_decay=1e-3,
              cohort_capacity=8)
    seq = [run_fl(algo=algo_factory(),
                  participation=TraceParticipation(traces[k]), seed=k,
                  uses_update_clock=clock, **kw)
           for k in range(len(traces))]
    trials = [Trial(seed=k, participation=TraceParticipation(traces[k]))
              for k in range(len(traces))]
    fleet = run_fleet(algo=algo_factory(), trials=trials,
                      uses_update_clock=clock, **kw)
    return seq, fleet


def _assert_trial_exact(seq, fleet, k):
    params_k = jax.tree.map(lambda l: l[k], fleet[0])
    for a, b in zip(jax.tree.leaves(params_k), jax.tree.leaves(seq[k][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    hist_k = fleet[1].trial(k)
    assert hist_k.train_loss == seq[k][1].train_loss
    assert hist_k.n_active == seq[k][1].n_active


# --------------------------------------------------------------------------- #
# bit-exact equivalence — deterministic traces, always run
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", list(ALGOS))
def test_fleet_bitexact_vs_sequential(tiny_problem, name):
    algo_factory, clock = ALGOS[name]
    traces = np.random.default_rng(7).random((K, T, N)) < 0.5
    seq, fleet = _run_pair(tiny_problem, algo_factory, traces, clock)
    for k in range(K):
        _assert_trial_exact(seq, fleet, k)


def test_fleet_mixed_cohort_sizes_share_capacity(tiny_problem):
    """Trials with very different |A(t)| (empty / singleton / full) pad to
    one shared capacity; the padding must stay inert per trial."""
    traces = np.zeros((3, T, N), bool)
    traces[0] = True                      # full participation
    traces[1, :, 0] = True                # a single stalwart client
    # trial 2: all dark after round 0 (TraceParticipation forces round 0)
    seq, fleet = _run_pair(tiny_problem, ALGOS["banked_dense"][0], traces,
                           False)
    for k in range(3):
        _assert_trial_exact(seq, fleet, k)


# --------------------------------------------------------------------------- #
# bit-exact equivalence — hypothesis-generated traces (CI)
# --------------------------------------------------------------------------- #

if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("name", ["mifa_array", "banked_dense", "fedavg"])
    @settings(max_examples=3, deadline=None)
    @given(traces=hnp.arrays(np.bool_, (K, T, N)))
    def test_fleet_bitexact_hypothesis(tiny_problem, name, traces):
        algo_factory, clock = ALGOS[name]
        seq, fleet = _run_pair(tiny_problem, algo_factory, traces, clock)
        for k in range(K):
            _assert_trial_exact(seq, fleet, k)


# --------------------------------------------------------------------------- #
# slow: the non-convex model through the same harness
# --------------------------------------------------------------------------- #

@pytest.mark.slow
def test_fleet_bitexact_mlp(tiny_problem):
    """paper_mlp init is rng-dependent — vmapped init must also match."""
    traces = np.random.default_rng(3).random((2, 3, N)) < 0.5
    seq, fleet = _run_pair(
        lambda **kw: tiny_problem(model_name="paper_mlp", **kw),
        ALGOS["mifa_array"][0], traces, False)
    for k in range(2):
        _assert_trial_exact(seq, fleet, k)


# --------------------------------------------------------------------------- #
# eval, history views, spec expansion, exclusions
# --------------------------------------------------------------------------- #

def test_fleet_eval_matches_sequential(tiny_problem):
    model, batcher = tiny_problem(n_clients=N)
    batch = {"x": np.asarray(batcher.Xs[0][:8]),
             "y": np.asarray(batcher.ys[0][:8])}

    def seq_eval(params):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, _ = model.loss_fn(params, b)
        return float(loss), float(model.accuracy(params, b))

    traces = np.ones((2, T, N), bool)
    kw = dict(model=model, batcher=batcher, schedule=lambda t: 0.1,
              n_rounds=T, weight_decay=1e-3)
    seq = [run_fl(algo=MIFA(memory="array"),
                  participation=TraceParticipation(traces[k]), seed=k,
                  eval_fn=seq_eval, eval_every=2, **kw) for k in range(2)]
    trials = [Trial(seed=k, participation=TraceParticipation(traces[k]))
              for k in range(2)]
    _, hist = run_fleet(algo=MIFA(memory="array"), trials=trials,
                        eval_fn=make_fleet_eval(model, batch), eval_every=2,
                        **kw)
    for k in range(2):
        hk = hist.trial(k)
        assert [t for t, _ in hk.eval_loss] == \
            [t for t, _ in seq[k][1].eval_loss]
        np.testing.assert_allclose(
            [v for _, v in hk.eval_loss],
            [v for _, v in seq[k][1].eval_loss], rtol=1e-6, atol=1e-7)
    stacked = hist.stacked()
    assert stacked["train_loss"].shape == (2, T)
    assert stacked["eval_loss"].shape[0] == 2


def test_expand_grid_groups_and_labels():
    part = lambda seed, p=0.5: TraceParticipation(np.ones((2, N), bool))
    specs = expand_grid(
        algos={"mifa": MIFA(memory="array"),
               "is": lambda p: BiasedFedAvg()},     # callable: per-point
        seeds=(0, 1), avail_grid=({"p": 0.1}, {"p": 0.3}),
        make_participation=part, clock=())
    by_name = {s.name: s for s in specs}
    assert by_name["mifa"].n_trials == 4             # seeds x points batch
    assert "is/p0.1" in by_name and by_name["is/p0.1"].n_trials == 2
    assert by_name["mifa"].labels[0] == "mifa/p0.1/seed0"
    assert by_name["mifa"].seeds == (0, 1, 0, 1)


def test_fleet_rejects_host_offloaded_banks(tiny_problem):
    model, batcher = tiny_problem(n_clients=N)
    trials = [Trial(seed=0,
                    participation=TraceParticipation(np.ones((2, N), bool)))]
    with pytest.raises(NotImplementedError, match="host-offloaded|jittable"):
        run_fleet(model=model, batcher=batcher, schedule=lambda t: 0.1,
                  n_rounds=1, algo=BankedMIFA(HostBank()), trials=trials)


def test_fleet_duplicate_cohort_ids_rejected(tiny_problem):
    model, batcher = tiny_problem(n_clients=N)
    runner = FleetRunner(model=model, algo=BankedMIFA(DenseBank()),
                         batcher=batcher, schedule=lambda t: 0.1,
                         seeds=[0, 1])
    with pytest.raises(ValueError, match="duplicate|unique"):
        runner.step_cohort(0, [np.array([1, 1]), np.array([0, 2])])


def test_batched_bank_scatter_kernel_matches_jnp():
    """The grid-axis batched Pallas kernel == vmapped jnp scatter body."""
    from repro.bank.dense import _scatter_jnp
    from repro.kernels.ops import fleet_bank_update_tree
    key = jax.random.PRNGKey(5)
    Kt, R, C, M = 3, 7, 4, 6
    rows = jax.random.normal(key, (Kt, R, M))
    g_sum = jnp.zeros((Kt, M))
    ids = jnp.array([[0, 3, 6, 6], [1, 2, 5, 6], [6, 6, 6, 6]], jnp.int32)
    valid = jnp.array([[1, 1, 0, 0], [1, 1, 1, 0], [0, 0, 0, 0]], bool)
    upd = jax.random.normal(jax.random.fold_in(key, 1), (Kt, C, M))
    r_ref, g_ref = jax.vmap(_scatter_jnp)(rows, g_sum, ids, valid, upd)
    r_ker, ds = fleet_bank_update_tree(rows, upd, ids, valid)
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(g_ref), atol=1e-6)


def test_bank_fleet_surface():
    """gather_fleet == per-trial gathers; host banks refuse the fleet."""
    key = jax.random.PRNGKey(2)
    params = {"w": jax.random.normal(key, (4, 3))}
    bank = DenseBank()
    single = bank.init(params, 5)
    stacked = jax.tree.map(lambda l: jnp.stack([l, l + 1.0]), single)
    ids = jnp.array([[0, 2], [1, 4]], jnp.int32)
    got = bank.gather_fleet(stacked, ids)
    for k in range(2):
        want = bank.gather(jax.tree.map(lambda l: l[k], stacked), ids[k])
        np.testing.assert_array_equal(np.asarray(got["w"][k]),
                                      np.asarray(want["w"]))
    host = HostBank()
    hs = host.init(params, 5)
    with pytest.raises(NotImplementedError, match="host-offloaded"):
        host.scatter_fleet(jax.tree.map(lambda l: np.stack([l, l]), hs),
                           np.array([[0], [1]]),
                           {"w": np.zeros((2, 1, 4, 3), np.float32)})


def test_fleet_trial_axis_sharding_smoke(tiny_problem):
    """Trial axis lands on the mesh data axes and the run still matches."""
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import fleet_axis_specs, fleet_trial_specs
    mesh = make_host_mesh(1, 1)
    cfg = get_config("paper_logistic")
    model, batcher = tiny_problem(n_clients=N)
    traces = np.ones((2, 3, N), bool)
    kw = dict(model=model, batcher=batcher, schedule=lambda t: 0.1,
              n_rounds=3, weight_decay=1e-3)
    ref = run_fleet(algo=MIFA(memory="array"),
                    trials=[Trial(seed=k,
                                  participation=TraceParticipation(traces[k]))
                            for k in range(2)], **kw)
    sh = run_fleet(algo=MIFA(memory="array"),
                   trials=[Trial(seed=k,
                                 participation=TraceParticipation(traces[k]))
                           for k in range(2)], mesh=mesh, cfg=cfg, **kw)
    for a, b in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(sh[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # spec shapes match the stacked trees
    specs = fleet_trial_specs(ref[0], cfg, mesh)
    assert jax.tree.structure(specs) == jax.tree.structure(ref[0])
    gen = fleet_axis_specs({"g": jnp.zeros((4, N, 3))}, mesh)
    assert len(gen["g"]) == 3
