"""The CI perf/loss regression gate fails when metrics regress.

This is the in-repo demonstration the gate's acceptance asks for: a
synthetic perf (speedup below the pinned floor) or loss (final loss off the
pin) regression against benchmarks/baselines/ci_baseline.json makes
`benchmarks/check_regression.py` exit non-zero — including when driven
through the REAL committed baseline — and a benchmark that silently stops
producing its artifact or metric is itself a failure, never a pass.
"""
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

import check_regression as cr  # noqa: E402

REAL_BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "baselines", "ci_baseline.json")


def _write(tmp_path, name, payload):
    path = tmp_path / f"{name}.json"
    path.write_text(json.dumps(payload))
    return str(tmp_path)


BASE = {"metrics": {
    "speedup floor": {"artifact": "bench", "path": "results.speedup",
                      "min": 2.0},
    "loss pin": {"artifact": "bench", "path": "results.final_loss",
                 "value": 1.5, "rtol": 0.02},
    "time cap": {"artifact": "bench", "path": "results.seconds",
                 "max": 10.0},
}}


def _artifact(speedup=3.0, loss=1.5, seconds=5.0):
    return {"results": {"speedup": speedup, "final_loss": loss,
                        "seconds": seconds}}


def test_gate_passes_on_healthy_metrics(tmp_path):
    d = _write(tmp_path, "bench", _artifact())
    assert cr.run_checks(BASE, d) == []


@pytest.mark.parametrize("kw,expected", [
    ({"speedup": 1.1}, "min"),               # perf regression
    ({"loss": 1.7}, "deviates"),             # convergence regression
    ({"loss": 1.2}, "deviates"),             # suspiciously-good counts too
    ({"seconds": 99.0}, "max"),              # perf cap
])
def test_gate_fails_on_synthetic_regressions(tmp_path, kw, expected):
    d = _write(tmp_path, "bench", _artifact(**kw))
    failures = cr.run_checks(BASE, d)
    assert len(failures) == 1 and expected in failures[0]


def test_missing_artifact_and_path_are_failures(tmp_path):
    failures = cr.run_checks(BASE, str(tmp_path))       # nothing generated
    assert len(failures) == 3
    assert all("missing" in f for f in failures)
    d = _write(tmp_path, "bench", {"results": {}})      # metric vanished
    failures = cr.run_checks(BASE, d)
    assert len(failures) == 3 and all("not found" in f for f in failures)


def test_main_exit_codes(tmp_path):
    base_path = tmp_path / "baseline.json"
    base_path.write_text(json.dumps(BASE))
    d = _write(tmp_path, "bench", _artifact())
    assert cr.main(["--baseline", str(base_path), "--artifacts", d]) == 0
    _write(tmp_path, "bench", _artifact(speedup=0.5))
    assert cr.main(["--baseline", str(base_path), "--artifacts", d]) == 1


def test_run_py_rejects_unknown_only():
    """A typo'd ``--only`` must exit non-zero listing the valid names — a
    silent no-op would quietly hollow out the CI smoke steps."""
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(root, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "benchmarks", "run.py"),
         "--only", "not_a_benchmark"],
        capture_output=True, text=True, env=env)
    assert proc.returncode != 0
    assert "valid names" in proc.stderr
    assert "scan_scale" in proc.stderr and "fleet_scale" in proc.stderr


def test_real_baseline_catches_scan_engine_regression(tmp_path):
    """Drive the gate through the committed ci_baseline.json: artifacts
    fabricated exactly at the pins pass; degrading the scan speedup to
    1.0x (the scan engine silently collapsing into the loop) fails."""
    with open(REAL_BASELINE) as f:
        baseline = json.load(f)
    artifacts = {}
    for spec in baseline["metrics"].values():
        art = artifacts.setdefault(spec["artifact"], {})
        if "value" in spec:
            healthy = spec["value"]
        elif "min" in spec and "max" in spec:  # band pin: sit at the middle
            healthy = (spec["min"] + spec["max"]) / 2
        elif "max" in spec:                    # cap-only pin: sit below it
            healthy = spec["max"] / 2
        else:
            healthy = spec.get("min", 0.0) + 1.0
        parts = spec["path"].split(".")
        cur = art
        for a, b in zip(parts[:-1], parts[1:]):
            nxt = [] if b.isdigit() else {}
            if a.isdigit():
                while len(cur) <= int(a):
                    cur.append(nxt if len(cur) == int(a) else None)
                cur = cur[int(a)] if cur[int(a)] is not None else nxt
            else:
                cur = cur.setdefault(a, nxt)
        last = parts[-1]
        if last.isdigit():
            while len(cur) <= int(last):
                cur.append(None)
            cur[int(last)] = healthy
        else:
            cur[last] = healthy
    for name, payload in artifacts.items():
        (tmp_path / f"{name}.json").write_text(json.dumps(payload))
    assert cr.run_checks(baseline, str(tmp_path)) == []

    scan = json.loads((tmp_path / "scan_scale.json").read_text())
    scan["results"]["T64"]["speedup"] = 1.0
    (tmp_path / "scan_scale.json").write_text(json.dumps(scan))
    failures = cr.run_checks(baseline, str(tmp_path))
    assert len(failures) == 1 and "speedup" in failures[0]
