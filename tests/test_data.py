import numpy as np

from repro.data import ClientBatcher, TokenBatcher, label_skew_partition, \
    make_classification


def test_label_skew_two_classes_equal_sizes():
    X, y = make_classification(10, 16, 200, seed=0)
    idx, labels = label_skew_partition(y, n_clients=100, seed=0)
    sizes = [len(i) for i in idx]
    assert max(sizes) - min(sizes) <= 2  # equal up to shard rounding
    for i, ci in enumerate(idx):
        assert len(np.unique(y[ci])) <= 2
        assert set(np.unique(y[ci])) <= set(labels[i])
    # every sample assigned exactly once
    allidx = np.concatenate(idx)
    assert len(allidx) == len(y)
    assert len(np.unique(allidx)) == len(y)


def test_client_batcher_deterministic():
    X, y = make_classification(4, 8, 50, seed=0)
    idx, _ = label_skew_partition(y, n_clients=10, seed=0)
    b1 = ClientBatcher(X, y, idx, batch_size=4, k_steps=3, seed=5)
    b2 = ClientBatcher(X, y, idx, batch_size=4, k_steps=3, seed=5)
    r1, r2 = b1.sample_round(7), b2.sample_round(7)
    np.testing.assert_array_equal(r1["x"], r2["x"])
    np.testing.assert_array_equal(r1["y"], r2["y"])
    assert r1["x"].shape == (10, 3, 4, 8)
    # different rounds differ
    r3 = b1.sample_round(8)
    assert not np.array_equal(r1["x"], r3["x"])


def test_client_batches_come_from_client_data():
    X, y = make_classification(4, 8, 50, seed=0)
    idx, labels = label_skew_partition(y, n_clients=10, seed=0)
    b = ClientBatcher(X, y, idx, batch_size=8, k_steps=2, seed=0)
    r = b.sample_round(0)
    for i in range(10):
        assert set(np.unique(r["y"][i])) <= set(labels[i])


def test_token_batcher_shapes_and_skew():
    tb = TokenBatcher(n_clients=4, vocab=128, seq_len=16, batch_size=2,
                      k_steps=2, stream_len=2048, seed=0)
    r = tb.sample_round(0)
    assert r["tokens"].shape == (4, 2, 2, 16)
    assert r["tokens"].max() < 128
    # non-iid: different clients use shifted vocabularies
    m0 = np.bincount(r["tokens"][0].ravel(), minlength=128).argmax()
    m3 = np.bincount(r["tokens"][3].ravel(), minlength=128).argmax()
    assert m0 != m3


def test_classification_train_test_same_distribution():
    Xtr, ytr = make_classification(4, 8, 100, seed=0)
    Xte, yte = make_classification(4, 8, 100, seed=9)
    # class means should align across splits (shared prototypes)
    for c in range(4):
        mtr = Xtr[ytr == c].mean(0)
        mte = Xte[yte == c].mean(0)
        assert np.linalg.norm(mtr - mte) < 0.5 * np.linalg.norm(mtr) + 0.5
