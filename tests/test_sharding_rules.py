"""Partition-rule unit tests on an abstract 16x16 (and 2x16x16) mesh."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import make_abstract_mesh
from repro.models import build_model
from repro.sharding import rules


def mesh_pod():
    return make_abstract_mesh((16, 16), ("data", "model"))


def mesh_multipod():
    return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _params_sds(arch, full=True):
    cfg = get_config(arch) if full else get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _check_divisibility(specs, params, mesh):
    for spec, leaf in zip(jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(params)):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            n = rules._axis_size(mesh, entry)
            assert dim % n == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_pod(arch):
    cfg, params = _params_sds(arch)
    mesh = mesh_pod()
    specs = rules.param_specs(params, cfg, mesh)
    _check_divisibility(specs, params, mesh)


@pytest.mark.parametrize("arch", ["qwen1_5_110b", "zamba2_7b", "olmoe_1b_7b"])
def test_param_specs_divisible_multipod(arch):
    cfg, params = _params_sds(arch)
    mesh = mesh_multipod()
    specs = rules.param_specs(params, cfg, mesh)
    _check_divisibility(specs, params, mesh)


def test_sanitize_drops_indivisible():
    mesh = mesh_pod()
    assert rules.sanitize(("model",), (49155,), mesh) == (None,)
    assert rules.sanitize(("model",), (49152,), mesh) == ("model",)
    assert rules.sanitize((("data", "model"),), (512,), mesh) == \
        (("data", "model"),)
    assert rules.sanitize((("data", "model"),), (128,), mesh) == (None,)


def test_granite_vocab_replicated_but_dff_sharded():
    cfg, params = _params_sds("granite_3_8b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    assert tuple(specs["lm_head"]) == (None, None)      # 49155 indivisible
    assert "model" in tuple(specs["segments"]["0"]["mlp"]["w1"])


def test_gemma_flat_attention_sharded():
    """8 heads < 16-way axis, but flat H*hd = 2048 shards."""
    cfg, params = _params_sds("gemma3_4b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    wq_spec = tuple(specs["segments"]["0"]["attn"]["wq"])
    assert wq_spec[-1] == "model"


def test_fsdp_two_axis_sharding():
    cfg, params = _params_sds("qwen1_5_110b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    w1 = tuple(specs["segments"]["0"]["mlp"]["w1"])     # (n, d, f)
    assert w1[-2:] == ("data", "model")


def test_moe_expert_parallel():
    cfg, params = _params_sds("olmoe_1b_7b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    w1 = tuple(specs["segments"]["0"]["moe"]["w1"])     # (n, E, d, f)
    assert w1[1] == "model"


def test_client_state_leading_axis():
    cfg, params = _params_sds("granite_3_8b")
    mesh = mesh_pod()
    specs = rules.client_state_specs(params, cfg, mesh, n_clients=16)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert tuple(s)[0] in ("data", ("data",))


def test_client_state_sequential_keeps_2d():
    cfg, params = _params_sds("qwen1_5_110b")
    specs = rules.client_state_specs(params, cfg, mesh_pod(),
                                     sequential_clients=True, n_clients=16)
    w1 = tuple(specs["segments"]["0"]["mlp"]["w1"])     # (N, n, d, f)
    assert w1[0] is None and w1[-2:] == ("data", "model")


def test_cache_specs_kv_fallback_to_seq():
    """granite kv=8 < 16-way model axis: cache seq dim takes `model`."""
    cfg = get_config("granite_3_8b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = rules.cache_specs(cache, cfg, mesh_pod(), 128)
    k = tuple(specs["0"]["k"])          # (n, B, C, KV, hd)
    assert k[1] in ("data", ("data",)) and k[2] == "model"


def test_cache_specs_b1_seq_over_data():
    cfg = get_config("mamba2_1_3b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 1024))
    specs = rules.cache_specs(cache, cfg, mesh_pod(), 1)
    st = tuple(specs["0"]["state"])     # (n, B, H, P, N)
    assert st[1] is None and st[2] == "model"


def test_multipod_client_axis_spans_pods():
    cfg, params = _params_sds("granite_3_8b")
    mesh = mesh_multipod()
    specs = rules.client_state_specs(params, cfg, mesh, n_clients=32)
    lead = tuple(jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))[0])[0]
    assert lead == ("pod", "data")


def test_fleet_trial_specs_shard_trial_axis():
    """Fleet-stacked params: trial axis on data/pod, model dims kept."""
    cfg, params = _params_sds("granite_3_8b")
    K = 32
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((K,) + tuple(l.shape), l.dtype),
        params)
    for mesh, lead_expect in ((mesh_pod(), ("data",)),
                              (mesh_multipod(), ("pod", "data"))):
        specs = rules.fleet_trial_specs(stacked, cfg, mesh)
        _check_divisibility(specs, stacked, mesh)
        w1 = tuple(specs["segments"]["0"]["mlp"]["w1"])   # (K, n, d, f)
        assert w1[0] in (lead_expect, lead_expect[0])
        assert "model" in w1                              # TP preserved


def test_fleet_axis_specs_generic_state():
    """Opaque fleet state: axis 0 over data, everything else replicated;
    indivisible trial counts fall back to full replication."""
    mesh = mesh_pod()
    state = {"G": jax.ShapeDtypeStruct((32, 100, 8), jnp.float32),
             "t": jax.ShapeDtypeStruct((32,), jnp.int32),
             "odd": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    specs = rules.fleet_axis_specs(state, mesh)
    assert tuple(specs["G"])[0] in ("data", ("data",))
    assert all(e is None for e in tuple(specs["G"])[1:])
    assert all(e is None for e in tuple(specs["odd"]))
