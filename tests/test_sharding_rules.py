"""Partition-rule unit tests on an abstract 16x16 (and 2x16x16) mesh."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import make_abstract_mesh
from repro.models import build_model
from repro.sharding import rules


def mesh_pod():
    return make_abstract_mesh((16, 16), ("data", "model"))


def mesh_multipod():
    return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _params_sds(arch, full=True):
    cfg = get_config(arch) if full else get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _check_divisibility(specs, params, mesh):
    for spec, leaf in zip(jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(params)):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            n = rules._axis_size(mesh, entry)
            assert dim % n == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_pod(arch):
    cfg, params = _params_sds(arch)
    mesh = mesh_pod()
    specs = rules.param_specs(params, cfg, mesh)
    _check_divisibility(specs, params, mesh)


@pytest.mark.parametrize("arch", ["qwen1_5_110b", "zamba2_7b", "olmoe_1b_7b"])
def test_param_specs_divisible_multipod(arch):
    cfg, params = _params_sds(arch)
    mesh = mesh_multipod()
    specs = rules.param_specs(params, cfg, mesh)
    _check_divisibility(specs, params, mesh)


def test_sanitize_drops_indivisible():
    mesh = mesh_pod()
    assert rules.sanitize(("model",), (49155,), mesh) == (None,)
    assert rules.sanitize(("model",), (49152,), mesh) == ("model",)
    assert rules.sanitize((("data", "model"),), (512,), mesh) == \
        (("data", "model"),)
    assert rules.sanitize((("data", "model"),), (128,), mesh) == (None,)


def test_granite_vocab_replicated_but_dff_sharded():
    cfg, params = _params_sds("granite_3_8b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    assert tuple(specs["lm_head"]) == (None, None)      # 49155 indivisible
    assert "model" in tuple(specs["segments"]["0"]["mlp"]["w1"])


def test_gemma_flat_attention_sharded():
    """8 heads < 16-way axis, but flat H*hd = 2048 shards."""
    cfg, params = _params_sds("gemma3_4b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    wq_spec = tuple(specs["segments"]["0"]["attn"]["wq"])
    assert wq_spec[-1] == "model"


def test_fsdp_two_axis_sharding():
    cfg, params = _params_sds("qwen1_5_110b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    w1 = tuple(specs["segments"]["0"]["mlp"]["w1"])     # (n, d, f)
    assert w1[-2:] == ("data", "model")


def test_moe_expert_parallel():
    cfg, params = _params_sds("olmoe_1b_7b")
    specs = rules.param_specs(params, cfg, mesh_pod())
    w1 = tuple(specs["segments"]["0"]["moe"]["w1"])     # (n, E, d, f)
    assert w1[1] == "model"


def test_client_state_leading_axis():
    cfg, params = _params_sds("granite_3_8b")
    mesh = mesh_pod()
    specs = rules.client_state_specs(params, cfg, mesh, n_clients=16)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert tuple(s)[0] in ("data", ("data",))


def test_client_state_sequential_keeps_2d():
    cfg, params = _params_sds("qwen1_5_110b")
    specs = rules.client_state_specs(params, cfg, mesh_pod(),
                                     sequential_clients=True, n_clients=16)
    w1 = tuple(specs["segments"]["0"]["mlp"]["w1"])     # (N, n, d, f)
    assert w1[0] is None and w1[-2:] == ("data", "model")


def test_cache_specs_kv_fallback_to_seq():
    """granite kv=8 < 16-way model axis: cache seq dim takes `model`."""
    cfg = get_config("granite_3_8b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = rules.cache_specs(cache, cfg, mesh_pod(), 128)
    k = tuple(specs["0"]["k"])          # (n, B, C, KV, hd)
    assert k[1] in ("data", ("data",)) and k[2] == "model"


def test_cache_specs_b1_seq_over_data():
    cfg = get_config("mamba2_1_3b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 1024))
    specs = rules.cache_specs(cache, cfg, mesh_pod(), 1)
    st = tuple(specs["0"]["state"])     # (n, B, H, P, N)
    assert st[1] is None and st[2] == "model"


def test_multipod_client_axis_spans_pods():
    cfg, params = _params_sds("granite_3_8b")
    mesh = mesh_multipod()
    specs = rules.client_state_specs(params, cfg, mesh, n_clients=32)
    lead = tuple(jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))[0])[0]
    assert lead == ("pod", "data")


def test_fleet_trial_specs_shard_trial_axis():
    """Fleet-stacked params: trial axis on data/pod, model dims kept."""
    cfg, params = _params_sds("granite_3_8b")
    K = 32
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((K,) + tuple(l.shape), l.dtype),
        params)
    for mesh, lead_expect in ((mesh_pod(), ("data",)),
                              (mesh_multipod(), ("pod", "data"))):
        specs = rules.fleet_trial_specs(stacked, cfg, mesh)
        _check_divisibility(specs, stacked, mesh)
        w1 = tuple(specs["segments"]["0"]["mlp"]["w1"])   # (K, n, d, f)
        assert w1[0] in (lead_expect, lead_expect[0])
        assert "model" in w1                              # TP preserved


def test_fleet_axis_specs_generic_state():
    """Opaque fleet state: axis 0 over data, everything else replicated;
    indivisible trial counts fall back to full replication."""
    mesh = mesh_pod()
    state = {"G": jax.ShapeDtypeStruct((32, 100, 8), jnp.float32),
             "t": jax.ShapeDtypeStruct((32,), jnp.int32),
             "odd": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    specs = rules.fleet_axis_specs(state, mesh)
    assert tuple(specs["G"])[0] in ("data", ("data",))
    assert all(e is None for e in tuple(specs["G"])[1:])
    assert all(e is None for e in tuple(specs["odd"]))


# --------------------------------------------------------------------------- #
# mesh-construction validation (launch/mesh.py)
# --------------------------------------------------------------------------- #

def test_mesh_rejects_duplicate_axis_names():
    """JAX's AbstractMesh silently shadows the first of two same-named axes
    in `.shape`; the builders must refuse, naming the duplicated axis."""
    with pytest.raises(ValueError, match=r"duplicate mesh axis name 'data'"):
        make_abstract_mesh((4, 4), ("data", "data"))


@pytest.mark.parametrize("bad", [0, -2, 3.0])
def test_mesh_rejects_non_positive_or_non_int_sizes(bad):
    with pytest.raises(ValueError, match=r"axis 'model'.*non-positive"):
        make_abstract_mesh((4, bad), ("data", "model"))


def test_mesh_rejects_shape_axes_length_mismatch():
    with pytest.raises(ValueError, match="differ"):
        make_abstract_mesh((4, 4, 2), ("data", "model"))


def test_make_host_mesh_validates():
    """Concrete builders share the same validation; an over-device request
    names the XLA_FLAGS remedy instead of an opaque assert."""
    from repro.launch.mesh import make_host_mesh
    with pytest.raises(ValueError, match="duplicate mesh axis name"):
        from repro.launch.mesh import _make_mesh
        _make_mesh((1, 1), ("data", "data"))
    n = len(jax.devices())
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_host_mesh(n + 1, 1)


# --------------------------------------------------------------------------- #
# hypothesis properties: sanitize / padded_bank_rows / fleet_axis_specs
# (CI installs requirements-dev.txt; containers without hypothesis keep the
# deterministic tests above)
# --------------------------------------------------------------------------- #

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # tier-1 containers without dev extras
    HAVE_HYPOTHESIS = False


def _entry_axes(entry):
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)


if HAVE_HYPOTHESIS:
    _MESHES = [make_abstract_mesh((2, 2), ("data", "model")),
               make_abstract_mesh((16, 16), ("data", "model")),
               make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))]
    _ENTRIES = [None, "data", "model", "pod", ("data", "model"),
                ("pod", "data"), ("pod", "data", "model")]

    @settings(max_examples=50, deadline=None)
    @given(st.sampled_from(_MESHES),
           st.lists(st.tuples(st.sampled_from(_ENTRIES),
                              st.integers(1, 4096)),
                    min_size=1, max_size=4))
    def test_sanitize_properties(mesh, dims):
        """sanitize never emits an axis absent from the mesh, every kept
        entry divides its dim evenly, dropped entries become None
        (shape-preserving), and the result is a fixed point (idempotence)."""
        spec = tuple(e for e, _ in dims)
        shape = tuple(d for _, d in dims)
        out = rules.sanitize(spec, shape, mesh)
        assert len(out) == len(shape)
        for dim, entry in zip(shape, out):
            if entry is None:
                continue
            axes = _entry_axes(entry)
            assert axes and all(ax in mesh.axis_names for ax in axes)
            assert dim % rules._axis_size(mesh, entry) == 0
        assert rules.sanitize(out, shape, mesh) == out

    @settings(max_examples=50, deadline=None)
    @given(st.sampled_from(_MESHES), st.integers(1, 10**6))
    def test_padded_bank_rows_properties(mesh, n_clients):
        """Padded row count always (a) fits N real rows + the dummy row,
        (b) divides the mesh's data extent exactly (so `sanitize` never
        silently replicates the bank), and (c) is minimal — one fewer
        data-extent multiple could not hold N+1 rows."""
        d = rules.data_axis_size(mesh)
        rows = rules.padded_bank_rows(n_clients, mesh)
        assert rows >= n_clients + 1
        assert rows % d == 0
        assert rows - d < n_clients + 1

    _leaf = st.lists(st.integers(1, 48), min_size=0, max_size=3).map(
        lambda s: jax.ShapeDtypeStruct(tuple(s), jnp.float32))
    _tree = st.recursive(
        _leaf,
        lambda kids: st.one_of(
            st.lists(kids, min_size=1, max_size=3).map(tuple),
            st.dictionaries(st.sampled_from(["a", "b", "c", "G", "rows"]),
                            kids, min_size=1, max_size=3)),
        max_leaves=6)

    @settings(max_examples=50, deadline=None)
    @given(st.sampled_from(_MESHES), _tree)
    def test_fleet_axis_specs_roundtrip_property(mesh, tree):
        """fleet_axis_specs round-trips arbitrary pytrees: the spec tree
        has the SAME treedef as the input (so `jax.tree.map(device_put,
        tree, specs)` is well-formed), each spec has one entry per leaf
        dim, axis 0 is the only possibly-sharded dim, and it shards exactly
        when the mesh's data extent divides it."""
        specs = rules.fleet_axis_specs(tree, mesh)
        assert (jax.tree.structure(tree)
                == jax.tree.structure(
                    specs, is_leaf=lambda x: isinstance(x, P)))
        d = rules.data_axis_size(mesh)
        dax = rules.data_axes(mesh)
        lead = dax if len(dax) > 1 else dax[0]
        for leaf, spec in zip(
                jax.tree.leaves(tree),
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            spec = tuple(spec)
            assert len(spec) <= leaf.ndim
            assert all(e is None for e in spec[1:])
            if leaf.ndim and leaf.shape[0] % d == 0 and d > 1:
                assert spec[0] == lead
            elif leaf.ndim and spec:
                assert spec[0] is None
