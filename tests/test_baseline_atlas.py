"""Competing-baseline atlas invariants (benchmarks/scenario_atlas.py).

Anchors for the FedAR / CA-Fed additions and the algorithm registry:

  * EVERY registered algorithm runs under EVERY registered scenario
    process on both engines (loop and scan) and stays finite — the atlas
    benchmark must never discover an unrunnable cell in CI;
  * the new baselines are bit-exact fleet-vs-sequential under
    `engine="scan"` (the acceptance bar MIFA already clears): vmapping a
    trial axis and scanning rounds must not change a single bit;
  * `tau_bound()` / `stationary_rate()` classifications of the atlas
    scenario axis are pinned (the Assumption 4 taxonomy the atlas's
    winner table is read against);
  * the `assumes` tags (docs/scenarios.md, "Algorithm taxonomy") are
    pinned per algorithm;
  * engine-fallback warnings dedupe once per distinct config
    (core.runner.warn_engine_fallback) — a 30-cell sweep must not print
    30 copies of the same fallback notice.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core import (algorithm_assumes, algorithm_names, make_algorithm,
                        run_fl)
from repro.core.runner import _reset_fallback_warnings, warn_engine_fallback
from repro.fleet import Trial, run_fleet
from repro.scenarios import make_scenario, scenario_names

N = 6


def _scen(name, seed=0):
    # tiny kwargs where a scenario needs them to be interesting at N=6
    kw = {"staged_blackout": {"stage_len": 2},
          "cluster": {"n_clusters": 2}}.get(name, {})
    return make_scenario(name, n=N, seed=7 + seed, **kw)


# --------------------------------------------------------------------------- #
# every algorithm × every scenario × both engines
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("engine", ["loop", "scan"])
@pytest.mark.parametrize("scenario", scenario_names())
@pytest.mark.parametrize("algo_name", algorithm_names())
def test_every_algorithm_runs_every_scenario(tiny_problem, algo_name,
                                             scenario, engine):
    model, batcher = tiny_problem(n_clients=N)
    algo = make_algorithm(algo_name, n=N)
    params, hist = run_fl(algo=algo, model=model, batcher=batcher,
                          schedule=lambda t: 0.1 / (1 + t), n_rounds=3,
                          weight_decay=1e-3, scenario=_scen(scenario),
                          seed=0, engine=engine)
    assert all(np.isfinite(x) for x in hist.train_loss)
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree.leaves(params))


# --------------------------------------------------------------------------- #
# fleet-vs-sequential bit-exactness for the new baselines (scan engine)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("algo_name", ["fedar", "ca_fed"])
def test_new_baselines_fleet_scan_bitexact_vs_sequential(tiny_problem,
                                                         algo_name):
    """fp32 bit-exact: K seeds of FedAR / CA-Fed as one jit(scan(vmap))
    program reproduce the sequential per-seed `run_fl` runs exactly."""
    model, batcher = tiny_problem(n_clients=N)
    algo = make_algorithm(algo_name, n=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=5,
              weight_decay=1e-3)

    def ge(k):
        return make_scenario("gilbert_elliott", n=N, seed=100 + k,
                             rate=0.5, burst=3.0)

    seq = [run_fl(algo=algo, scenario=ge(k), seed=k, engine="scan", **kw)
           for k in range(3)]
    fleet = run_fleet(algo=algo,
                      trials=[Trial(seed=k, scenario=ge(k))
                              for k in range(3)],
                      engine="scan", **kw)
    for k in range(3):
        params_k = jax.tree.map(lambda leaf: leaf[k], fleet[0])
        for a, b in zip(jax.tree.leaves(params_k),
                        jax.tree.leaves(seq[k][0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert fleet[1].trial(k).train_loss == seq[k][1].train_loss
        assert fleet[1].trial(k).n_active == seq[k][1].n_active


# --------------------------------------------------------------------------- #
# atlas scenario-axis theory pins (Assumption 4 taxonomy)
# --------------------------------------------------------------------------- #

def _atlas_axis():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scenario_grid import scenario_axis
    return scenario_axis(stage_len=6)


def test_atlas_axis_tau_classifications():
    """The axis orders by correlation/non-stationarity; `tau_bound()` must
    agree: geometric τ under iid, growing E[τ] with burst length, a
    DETERMINISTIC bound for the staged blackout (Assumption 4 holds), and
    an unbounded/unknown τ for cluster outages."""
    tb = {label: make_scenario(name, n=8, seed=0, **kw).process.tau_bound()
          for label, name, kw in _atlas_axis()}
    assert not tb["iid"].deterministic
    assert tb["iid"].expected_tau == pytest.approx(1.0)
    assert tb["ge_burst4"].expected_tau == pytest.approx(2.0)
    assert tb["ge_burst16"].expected_tau == pytest.approx(8.0)
    assert tb["ge_burst16"].expected_tau > tb["ge_burst4"].expected_tau
    assert tb["staged_blackout"].deterministic
    assert np.isfinite(tb["staged_blackout"].t0)
    assert not tb["cluster"].deterministic
    assert np.isinf(tb["cluster"].t0)
    assert np.isnan(tb["cluster"].expected_tau)


def test_atlas_axis_calibrated_to_half_rate():
    """The stochastic cells share a ≈0.5 stationary rate — the axis varies
    correlation structure, not the participation budget."""
    for label, name, kw in _atlas_axis():
        if label == "staged_blackout":
            continue  # non-stationary by construction
        rate = make_scenario(name, n=8, seed=0,
                             **kw).process.stationary_rate().mean()
        assert rate == pytest.approx(0.5, abs=0.05), label


def test_algorithm_assumes_tags():
    """docs/scenarios.md 'Algorithm taxonomy' pins."""
    want = {"mifa": "arbitrary", "banked_mifa": "arbitrary",
            "fedar": "arbitrary", "fedavg": "none",
            "fedavg_is": "iid_known_probs", "ca_fed": "stationary_mixing"}
    got = {name: algorithm_assumes(name, n=4) for name in algorithm_names()}
    assert got == want


def test_make_algorithm_unknown_name():
    with pytest.raises(KeyError, match="fedsgd"):
        make_algorithm("fedsgd", n=4)


# --------------------------------------------------------------------------- #
# engine-fallback warning dedupe
# --------------------------------------------------------------------------- #

def test_fallback_warns_once_per_distinct_message():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        warn_engine_fallback("config A unsupported")
        warn_engine_fallback("config A unsupported")
        warn_engine_fallback("config B unsupported")
        warn_engine_fallback("config A unsupported")
    msgs = [str(x.message) for x in w]
    assert msgs == ["config A unsupported", "config B unsupported"]
    # a reset (new test, new sweep) re-arms the warning
    _reset_fallback_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        warn_engine_fallback("config A unsupported")
    assert len(w) == 1


def test_repeated_fallback_runs_warn_once(tiny_problem):
    """A sweep hitting the same unsupported scan config repeatedly emits
    ONE warning, not one per run_fl call."""
    from repro.bank import BankedMIFA, HostBank
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=2,
              weight_decay=1e-3, seed=0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for _ in range(3):
            run_fl(algo=BankedMIFA(HostBank()), engine="scan",
                   scenario=_scen("gilbert_elliott"), **kw)
    fallback = [x for x in w if "falling back" in str(x.message)]
    assert len(fallback) == 1
    # the warning points at the caller (stacklevel through the helper),
    # not at runner.py internals
    assert fallback[0].filename == __file__
