"""Sharded scan engine: multi-device parity, pinned in the mesh8 world.

Anchor properties for `run_fl(engine="scan", mesh=...)` (and the fleet's
trial-axis sharding) on 1x1 / 2x2 / 8x1 host-device meshes built by
`launch.mesh.make_host_mesh` under forced 8 host devices:

  * the 1x1 mesh is fp32 BIT-EXACT against the unsharded scan — placing
    the carry on a one-device mesh must not perturb a single ulp;
  * >1-device meshes match to reduction-order tolerance: the client-axis
    mean reduces per-device partial sums and all-reduces them, so fp32
    rounding GROUPS differently than the single-device sequential
    reduction — same math, different parenthesisation. Integer-derived
    quantities (availability masks, n_active, τ statistics) stay exact;
  * chunking and mesh shape are execution details: scan_chunk ∈ {1, 4, T}
    on the same mesh is bit-exact, 2x2 vs 8x1 agree to the same tolerance
    and draw identical masks (the partitionable threefry RNG the world
    enables is sharding-invariant — the legacy lowering is NOT, which is
    why the world pins JAX_THREEFRY_PARTITIONABLE=1; conftest docstring,
    docs/architecture.md §13).

Everything here except the subprocess proxy is `@pytest.mark.mesh8`: in a
plain tier-1 run those tests skip at collection and
`test_mesh8_subprocess_suite` re-runs them in the forced-device world.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

N, T = 8, 9          # N divides both data extents exercised below (2 and 8)
SHAPES = [(1, 1), (2, 2), (8, 1)]

mesh8 = pytest.mark.mesh8


def _algos():
    from repro.bank import BankedMIFA, DenseBank
    from repro.core import MIFA, BiasedFedAvg
    return {
        "mifa_array": lambda: MIFA(memory="array"),
        "banked_dense": lambda: BankedMIFA(DenseBank()),
        "fedavg": lambda: BiasedFedAvg(),
    }


def _ge(seed=0):
    from repro.scenarios import GilbertElliott
    return GilbertElliott.from_rate_and_burst(0.5, 3.0, n=N, seed=100 + seed)


def _kw(tiny_problem, **over):
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=T,
              weight_decay=1e-3, seed=0, cohort_capacity=8)
    kw.update(over)
    return kw


def _assert_close(run_ref, run_got, *, exact):
    """exact=True pins bitwise equality; otherwise fp32 reduction-order
    tolerance (see module docstring). Mask-derived integers are always
    exact — a mismatch there means the RNG diverged, not the arithmetic."""
    import jax
    (pa, ha), (pb, hb) = run_ref, run_got
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        a, b = np.asarray(a), np.asarray(b)
        if exact:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)
    if exact:
        assert ha.train_loss == hb.train_loss
    else:
        np.testing.assert_allclose(ha.train_loss, hb.train_loss,
                                   rtol=2e-5, atol=1e-6)
    assert ha.rounds == hb.rounds
    assert ha.n_active == hb.n_active
    assert (ha.tau_bar, ha.tau_max) == (hb.tau_bar, hb.tau_max)


@pytest.fixture(scope="session")
def single_scan_runs(mesh8_world, tiny_problem):
    """Unsharded scan trajectories, one per algorithm — the parity
    reference every mesh shape is compared against."""
    from repro.core import run_fl
    return {name: run_fl(algo=mk(), engine="scan", scan_chunk=4,
                         scenario=_ge(), **_kw(tiny_problem))
            for name, mk in _algos().items()}


# --------------------------------------------------------------------------- #
# sharded-vs-single-device parity
# --------------------------------------------------------------------------- #

@mesh8
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: f"{s[0]}x{s[1]}")
@pytest.mark.parametrize("name", ["mifa_array", "banked_dense", "fedavg"])
def test_sharded_scan_matches_single_device(mesh8_world, tiny_problem,
                                            single_scan_runs, name, shape):
    from repro.core import run_fl
    from repro.launch.mesh import make_host_mesh
    got = run_fl(algo=_algos()[name](), engine="scan", scan_chunk=4,
                 scenario=_ge(), mesh=make_host_mesh(*shape),
                 **_kw(tiny_problem))
    _assert_close(single_scan_runs[name], got, exact=(shape == (1, 1)))


@mesh8
@pytest.mark.parametrize("chunk", [1, 4, T])
def test_chunk_invariance_on_mesh(mesh8_world, tiny_problem, chunk):
    """On ONE mesh the per-round program is identical whatever the chunk
    length, so scan_chunk stays bit-exact even sharded."""
    from repro.core import MIFA, run_fl
    from repro.launch.mesh import make_host_mesh
    kw = _kw(tiny_problem)
    ref = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=4,
                 scenario=_ge(), mesh=make_host_mesh(2, 2), **kw)
    got = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=chunk,
                 scenario=_ge(), mesh=make_host_mesh(2, 2), **kw)
    _assert_close(ref, got, exact=True)


@mesh8
def test_mesh_shape_invariance(mesh8_world, tiny_problem):
    """2x2 and 8x1 draw IDENTICAL masks (partitionable threefry) and agree
    on the trajectory to reduction-order tolerance."""
    from repro.core import MIFA, run_fl
    from repro.launch.mesh import make_host_mesh
    kw = _kw(tiny_problem)
    a = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=4,
               scenario=_ge(), mesh=make_host_mesh(2, 2), **kw)
    b = run_fl(algo=MIFA(memory="array"), engine="scan", scan_chunk=4,
               scenario=_ge(), mesh=make_host_mesh(8, 1), **kw)
    _assert_close(a, b, exact=False)


# --------------------------------------------------------------------------- #
# fleet trial-axis sharding
# --------------------------------------------------------------------------- #

@mesh8
def test_fleet_trial_sharding_matches_sequential(mesh8_world, tiny_problem):
    """K=8 scenario trials sharded over the 8x1 data axis reproduce the
    sequential per-seed `run_fl` trajectories (reduction-order tolerance;
    per-trial masks and n_active exact)."""
    import jax
    from repro.core import MIFA, run_fl
    from repro.fleet import Trial, run_fleet
    from repro.launch.mesh import make_host_mesh
    kw = _kw(tiny_problem)
    trials = [Trial(seed=s, scenario=_ge(s)) for s in range(8)]
    pf, hf = run_fleet(model=kw["model"], batcher=kw["batcher"],
                       schedule=kw["schedule"], n_rounds=T,
                       algo=MIFA(memory="array"), trials=trials,
                       weight_decay=kw["weight_decay"], engine="scan",
                       scan_chunk=4, mesh=make_host_mesh(8, 1))
    for k in range(8):
        ps, hs = run_fl(algo=MIFA(memory="array"), engine="scan",
                        scan_chunk=4, scenario=_ge(k), **{**kw, "seed": k})
        for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pf)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b)[k],
                                       rtol=2e-5, atol=1e-6)
        ht = hf.trial(k)
        np.testing.assert_allclose(ht.train_loss, hs.train_loss,
                                   rtol=2e-5, atol=1e-6)
        assert ht.n_active == hs.n_active


# --------------------------------------------------------------------------- #
# bank layout + kernel safety under the mesh
# --------------------------------------------------------------------------- #

@mesh8
def test_bank_rows_pad_and_shard(mesh8_world, tiny_problem):
    """A DenseBank inheriting the run's mesh pads its rows so the client
    axis divides the data extent, lays them out row-sharded, and refuses
    the (single-device-program) Pallas kernel path even when forced."""
    import jax.numpy as jnp
    from repro.bank import DenseBank
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import data_axis_size, padded_bank_rows
    mesh = make_host_mesh(8, 1)
    bank = DenseBank(use_pallas=True, mesh=mesh)
    state = bank.init({"w": jnp.zeros((4, 3))}, n_clients=N)
    assert bank.n_rows == padded_bank_rows(N, mesh) == 16
    rows = state["rows"]["w"]
    assert rows.shape[0] == 16
    assert len(rows.sharding.device_set) == data_axis_size(mesh) == 8
    assert bank._pallas() is False


@mesh8
def test_run_fl_wires_mesh_into_bank(mesh8_world, tiny_problem):
    from repro.bank import BankedMIFA, DenseBank
    from repro.core import run_fl
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(2, 2)
    algo = BankedMIFA(DenseBank())
    run_fl(algo=algo, engine="scan", scan_chunk=4, scenario=_ge(),
           mesh=mesh, **_kw(tiny_problem))
    assert algo.bank.mesh is mesh
    assert algo.bank.n_rows == 10      # N+1=9 padded up to divide d=2


# --------------------------------------------------------------------------- #
# the subprocess proxy — the only test here that runs in plain tier-1
# --------------------------------------------------------------------------- #

def test_mesh8_subprocess_suite():
    """Drive the whole `mesh8` suite in a forced-8-device subprocess.

    The parent pytest process owns a single-device JAX backend, so the
    multi-device world has to be a fresh interpreter with XLA_FLAGS set
    before JAX initialises (conftest docstring). `-m mesh8` deselects this
    proxy inside the world, so there is no recursion.
    """
    if os.environ.get("REPRO_MESH8_WORLD"):
        pytest.skip("already inside the mesh8 world")
    from conftest import MESH8_ENV
    repo = pathlib.Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "mesh8",
         str(pathlib.Path(__file__).resolve())],
        env={**os.environ, **MESH8_ENV}, cwd=repo,
        capture_output=True, text=True, timeout=1500)
    tail = proc.stdout[-4000:] + proc.stderr[-2000:]
    assert proc.returncode == 0, tail
    assert " passed" in proc.stdout, tail
    assert " failed" not in proc.stdout, tail
