"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and absence of NaNs (assignment requirement)."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model

B, S = 2, 64


def _batch(cfg, rng):
    if cfg.modality == "vision_text":
        return {"tokens": jax.random.randint(rng, (B, S - cfg.n_patches), 0,
                                             cfg.vocab_size),
                "patches": jax.random.normal(
                    rng, (B, cfg.n_patches, cfg.d_model)) * 0.02}
    if cfg.modality == "audio":
        return {"frames": jax.random.normal(rng, (B, S, cfg.d_model)),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
        "mamba2_1_3b": (48, 2048, 0, 0, 0, 50280),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152064),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    loss, metrics = jax.jit(model.loss_fn)(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    """One SGD step decreases nothing NaN-ish and produces finite grads."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, batch)
        p2 = jax.tree.map(lambda w, gg: w - 0.01 * gg.astype(w.dtype), p, g)
        return l, p2, g

    loss, params2, grads = step(params)
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))
    loss2, _, _ = step(params2)
    assert np.isfinite(float(loss2))


def test_qwen_has_qkv_bias():
    cfg = get_smoke_config("qwen1_5_110b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "bq" in params["segments"]["0"]["attn"]


def test_gemma_swa_pattern():
    cfg = get_config("gemma3_4b")
    kinds = cfg.layer_kinds()
    assert kinds[:6] == ["local_attn"] * 5 + ["attn"]
    assert sum(k == "attn" for k in kinds) == 5   # 34 layers: 5 globals
    assert sum(k == "local_attn" for k in kinds) == 29


def test_zamba_shared_attention_is_shared():
    cfg = get_smoke_config("zamba2_7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "shared_attn" in params
    kinds = cfg.layer_kinds()
    assert "shared_attn" in kinds and "ssm" in kinds


def test_deepseek_mla_cache_is_compressed():
    cfg = get_smoke_config("deepseek_v2_lite_16b")
    model = build_model(cfg)
    cache = model.init_cache(batch=2, cache_len=32)
    # MLA cache stores (c_kv, k_pe), not per-head K/V
    seg_keys = {k for entry in cache.values() for k in entry.keys()}
    assert "c" in seg_keys and "pe" in seg_keys


def test_hubert_is_encoder_only():
    cfg = get_config("hubert_xlarge")
    assert cfg.encoder_only and not cfg.supports_decode
    assert not cfg.causal
