"""Scenario subsystem: host-vs-jit equivalence, theory pins, fleet in-jit.

The anchor properties:
  * the host (NumPy) and jit-native surfaces of EVERY registered scenario
    draw bit-identical masks at a fixed seed;
  * a fleet grid over scenario trials samples availability INSIDE the
    jitted round — no host sampling, no (T, N) trace — and is bit-exact
    per trial against sequential `run_fl(scenario=...)` runs;
  * the Gilbert–Elliott τ statistics match their closed forms
    (E[τ] = p_f/(p_r·(p_f+p_r))), and `tau_bound()` classifications are
    consistent with simulated traces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MIFA, BiasedFedAvg, run_fl, tau_matrix
from repro.core.participation import TauStats
from repro.fleet import FleetRunner, Trial, expand_grid, run_fleet
from repro.scenarios import (Bernoulli, GilbertElliott, HostSampler,
                             Scenario, make_scenario, register,
                             scenario_names)
from repro.scenarios.base import as_process

N = 6


# --------------------------------------------------------------------------- #
# host-vs-jit equivalence, round-0 convention, rates — every scenario
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", scenario_names())
def test_host_vs_jit_masks_identical(name):
    proc = make_scenario(name, n=12, seed=3).process
    sample = jax.jit(proc.sample_fn())
    state = proc.init_state()
    host = proc.host_sampler()
    for t in range(50):
        mask_jit, state = sample(proc.key, jnp.int32(t), state)
        mask_host = host.sample(t)
        assert mask_host.shape == (12,) and mask_host.dtype == bool
        np.testing.assert_array_equal(np.asarray(mask_jit), mask_host,
                                      err_msg=f"{name} diverges at t={t}")


@pytest.mark.parametrize("name", scenario_names())
def test_round_zero_all_active(name):
    proc = make_scenario(name, n=9, seed=0).process
    host0 = proc.host_sampler().sample(0)
    mask, _ = proc.sample_fn()(proc.key, jnp.int32(0), proc.init_state())
    if proc.round0_all_active:
        assert host0.all()
        assert bool(np.asarray(mask).all())
    else:
        # elastic: round 0 is every PRESENT client (the documented
        # Definition 5.2(1) deviation) — and some client must be present
        present = (proc.join <= 0) & (0 < proc.leave)
        np.testing.assert_array_equal(host0, present)
        np.testing.assert_array_equal(np.asarray(mask), present)
        assert present.any() and not present.all()


@pytest.mark.parametrize("name", scenario_names())
def test_stationary_rate_matches_empirical(name):
    proc = make_scenario(name, n=24, seed=1).process
    host = proc.host_sampler()
    # trace replay is empirical over the RECORDED horizon; past the end
    # the clamp repeats the last row, which would drown the comparison
    T = proc.trace.n_rounds if hasattr(proc, "trace") else 4000
    masks = np.stack([host.sample(t) for t in range(T)])
    want = proc.stationary_rate()
    assert want.shape == (24,)
    if name == "bernoulli_drift":   # limiting rate: compare the tail only
        got = masks[T // 2:].mean(0)
    else:
        got = masks[1:].mean(0)     # drop the forced round 0
    np.testing.assert_allclose(got.mean(), want.mean(), atol=0.05)


# --------------------------------------------------------------------------- #
# τ theory pins
# --------------------------------------------------------------------------- #

def test_gilbert_elliott_tau_matches_closed_form():
    """τ̄ over a long run == p_f/(p_r(p_f+p_r)), and the τ histogram matches
    P(τ=k) = π_up·p_f·(1−p_r)^(k−1) — the Markov-scenario pin."""
    proc = GilbertElliott.from_rate_and_burst(0.5, 4.0, n=48, seed=7)
    host = proc.host_sampler()
    T = 20000
    masks = np.stack([host.sample(t) for t in range(T)])
    tm = tau_matrix(masks)
    np.testing.assert_allclose(tm.mean(), proc.expected_tau(), rtol=0.05)
    # distribution head: P(τ=k), k = 0..3
    pf = float(proc.p_fail[0])
    pr = float(proc.p_recover[0])
    pi_up = pr / (pf + pr)
    emp = [(tm == k).mean() for k in range(4)]
    want = [pi_up] + [pi_up * pf * (1 - pr) ** (k - 1) for k in (1, 2, 3)]
    np.testing.assert_allclose(emp, want, atol=0.01)


def test_gilbert_elliott_burst_parametrisation():
    proc = GilbertElliott.from_rate_and_burst(0.5, 8.0, n=4, seed=0)
    np.testing.assert_allclose(proc.stationary_rate(), 0.5, atol=1e-5)
    np.testing.assert_allclose(1.0 / proc.p_recover, 8.0, rtol=1e-5)
    assert not proc.tau_bound().deterministic
    # infeasible pairs raise instead of silently clipping the rate
    with pytest.raises(ValueError, match="infeasible"):
        GilbertElliott.from_rate_and_burst(0.2, 2.0, n=4)
    with pytest.raises(ValueError, match="burst"):
        GilbertElliott.from_rate_and_burst(0.5, 0.5, n=4)


@pytest.mark.parametrize("name,kw", [
    ("adversarial", {"periods": 8, "offs": 3}),
    ("staged_blackout", {"dark_frac": 0.5, "stage_len": 10}),
])
def test_deterministic_tau_bounds_hold_on_traces(name, kw):
    proc = make_scenario(name, n=10, seed=2, **kw).process
    tb = proc.tau_bound()
    assert tb.deterministic and np.isfinite(tb.t0)
    host = proc.host_sampler()
    masks = np.stack([host.sample(t) for t in range(400)])
    assert tau_matrix(masks).max() <= tb.t0
    assert tb.holds(tb.t0) and not tb.holds(tb.t0 - 1)


def test_stochastic_tau_bound_classification():
    assert not Bernoulli(np.full(4, 0.5)).tau_bound().deterministic
    b = Bernoulli(np.full(4, 0.5)).tau_bound()
    np.testing.assert_allclose(b.expected_tau, 1.0)  # (1-p)/p at p=0.5


# --------------------------------------------------------------------------- #
# fleet: in-jit sampling, bit-exactness, grid expansion
# --------------------------------------------------------------------------- #

def _ge(seed, burst=3.0):
    return GilbertElliott.from_rate_and_burst(0.5, burst, n=N,
                                              seed=100 + seed)


def test_fleet_bitexact_vs_sequential_jit_native(tiny_problem):
    """K trials under a jit-native Gilbert–Elliott scenario: the vmapped
    fleet reproduces sequential `run_fl(scenario=...)` bit-for-bit."""
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher,
              schedule=lambda t: 0.1 / (1 + t), n_rounds=4,
              weight_decay=1e-3)
    seq = [run_fl(algo=MIFA(memory="array"), scenario=_ge(k), seed=k, **kw)
           for k in range(3)]
    fleet = run_fleet(algo=MIFA(memory="array"),
                      trials=[Trial(seed=k, scenario=_ge(k))
                              for k in range(3)], **kw)
    for k in range(3):
        params_k = jax.tree.map(lambda l: l[k], fleet[0])
        for a, b in zip(jax.tree.leaves(params_k),
                        jax.tree.leaves(seq[k][0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert fleet[1].trial(k).train_loss == seq[k][1].train_loss
        assert fleet[1].trial(k).n_active == seq[k][1].n_active


def test_fleet_grid_three_scenario_types_sample_in_jit(tiny_problem,
                                                       monkeypatch):
    """A FleetSpec grid over >= 3 scenario types runs with availability
    sampled inside the jitted round: the host surface is NEVER queried and
    no (T, N) trace exists anywhere (trials carry no participation)."""
    model, batcher = tiny_problem(n_clients=N)

    def boom(self, t):
        raise AssertionError("host surface queried during a dense fleet "
                             "run — sampling must happen inside jit")
    monkeypatch.setattr(HostSampler, "sample", boom)

    points = [
        ("gilbert_elliott", {"rate": 0.5, "burst": 4.0}),
        ("cluster", {"n_clusters": 3, "q_fail": 0.2, "q_recover": 0.3}),
        ("staged_blackout", {"dark_frac": 0.5, "stage_len": 2}),
        ("diurnal", {"period": 6.0}),
    ]
    for name, kw in points:
        specs = expand_grid(
            algos={"mifa": MIFA(memory="array"),
                   "fedavg": BiasedFedAvg()},
            seeds=(0, 1),
            make_scenario=lambda seed, _n=name, _kw=kw: make_scenario(
                _n, n=N, seed=seed, **_kw).process)
        for spec in specs:
            assert all(tr.participation is None for tr in spec.trials)
            _, hist = run_fleet(spec=spec, model=model, batcher=batcher,
                                schedule=lambda t: 0.1, n_rounds=3,
                                weight_decay=1e-3)
            assert len(hist.train_loss) == 3
            assert np.isfinite(np.stack(hist.train_loss)).all()


def test_fleet_rejects_mixed_scenario_types(tiny_problem):
    model, batcher = tiny_problem(n_clients=N)
    with pytest.raises(ValueError, match="share a scenario type"):
        FleetRunner(model=model, algo=MIFA(memory="array"), batcher=batcher,
                    schedule=lambda t: 0.1, seeds=[0, 1],
                    scenarios=[_ge(0),
                               Bernoulli(np.full(N, 0.5), seed=1)])


def test_cohort_algo_uses_host_surface_same_masks(tiny_problem):
    """BankedMIFA (cohort) under a scenario draws the SAME masks the dense
    in-jit path draws — n_active histories match round for round."""
    from repro.bank import BankedMIFA, DenseBank
    model, batcher = tiny_problem(n_clients=N)
    kw = dict(model=model, batcher=batcher, schedule=lambda t: 0.1,
              n_rounds=6, weight_decay=1e-3, seed=0, cohort_capacity=8)
    _, dense = run_fl(algo=MIFA(memory="array"), scenario=_ge(0), **kw)
    _, banked = run_fl(algo=BankedMIFA(DenseBank()), scenario=_ge(0), **kw)
    assert dense.n_active == banked.n_active


def test_run_fl_requires_exactly_one_availability_source(tiny_problem):
    model, batcher = tiny_problem(n_clients=N)
    with pytest.raises(ValueError, match="exactly one"):
        run_fl(model=model, algo=MIFA(memory="array"), batcher=batcher,
               schedule=lambda t: 0.1, n_rounds=1)


def test_trial_requires_exactly_one_availability_source():
    with pytest.raises(ValueError, match="exactly one"):
        Trial(seed=0)
    with pytest.raises(ValueError, match="exactly one"):
        Trial(seed=0, participation=object(), scenario=object())


# --------------------------------------------------------------------------- #
# registry, samplers, composition
# --------------------------------------------------------------------------- #

def test_registry_roundtrip_and_errors():
    assert "gilbert_elliott" in scenario_names()
    with pytest.raises(KeyError, match="unknown scenario"):
        make_scenario("nope", n=4)
    with pytest.raises(ValueError, match="already registered"):
        register("bernoulli", lambda **kw: None)
    scen = make_scenario("gilbert_elliott", n=4, seed=9, rate=0.5, burst=2.0)
    assert scen.name == "gilbert_elliott/burst=2.0,rate=0.5/seed9"
    assert scen.n == 4


def test_scenario_sim_inputs_composition():
    from repro.sim import ShiftedExponentialLatency
    proc = Bernoulli(np.full(4, 0.5), seed=0)
    lat = ShiftedExponentialLatency(0.5, 0.1, n=4, seed=0)
    part, latency = Scenario(proc, latency=lat, name="x").sim_inputs()
    assert part.sample(0).all() and latency is lat
    with pytest.raises(ValueError, match="no latency"):
        Scenario(proc, name="x").sim_inputs()
    assert as_process(Scenario(proc)) is proc and as_process(proc) is proc


def test_stateful_host_sampler_enforces_round_order():
    proc = _ge(0)
    host = proc.host_sampler()
    host.sample(0)
    with pytest.raises(ValueError, match="in order"):
        host.sample(5)
    # stateless processes accept arbitrary t
    b = Bernoulli(np.full(N, 0.5), seed=0).host_sampler()
    b.sample(7)
    b.sample(2)


# --------------------------------------------------------------------------- #
# TauStats / tau_matrix round-0 strictness (the satellite bugfix)
# --------------------------------------------------------------------------- #

def test_tau_matrix_raises_on_round0_violation():
    masks = np.ones((4, 3), bool)
    masks[0, 1] = False
    with pytest.raises(ValueError, match="round 0"):
        tau_matrix(masks)
    tm = tau_matrix(masks, strict=False)     # init convention: τ(0,i)=1
    assert tm[0, 1] == 1 and tm[0, 0] == 0


def test_tau_stats_raises_on_round0_violation():
    st = TauStats(3)
    with pytest.raises(ValueError, match="round 0"):
        st.update(np.array([True, False, True]))
    lax = TauStats(3, strict=False)
    lax.update(np.array([True, False, True]))
    assert lax.tau.tolist() == [0, 1, 0]
    # only the FIRST round is checked; later gaps are the normal case
    ok = TauStats(3)
    ok.update(np.ones(3, bool))
    ok.update(np.array([True, False, True]))
    assert ok.tau_max == 1
