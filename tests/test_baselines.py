"""FedAvg-variant baselines (paper Algorithm 2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BiasedFedAvg, FedAvgIS, FedAvgSampling, SCAFFOLDSampling

N = 5


def test_biased_averages_active_only():
    params = {"w": jnp.zeros((2,))}
    algo = BiasedFedAvg()
    state = algo.init_state(params, 3)
    u = {"w": jnp.array([[3.0, 3.0], [1.0, 1.0], [100.0, 100.0]])}
    active = jnp.array([True, True, False])
    _, params, _ = algo.round_step(state, params, u, jnp.zeros(3), active,
                                   jnp.float32(1.0))
    np.testing.assert_allclose(params["w"], [-2.0, -2.0])  # mean of active


def test_is_weights_by_inverse_probability():
    params = {"w": jnp.zeros((1,))}
    probs = (0.5, 0.25)
    algo = FedAvgIS(probs)
    state = algo.init_state(params, 2)
    u = {"w": jnp.array([[1.0], [1.0]])}
    _, p_act, _ = algo.round_step(state, params, u, jnp.zeros(2),
                                  jnp.array([True, True]), jnp.float32(1.0))
    # update = mean_i(u_i/p_i) = (1/0.5 + 1/0.25)/2 = 3
    np.testing.assert_allclose(p_act["w"], [-3.0])


def test_is_unbiased_over_rounds():
    """E[IS update] equals the all-active mean update."""
    rng = np.random.default_rng(0)
    probs = np.array([0.2, 0.5, 0.9])
    algo = FedAvgIS(tuple(probs))
    params = {"w": jnp.zeros((1,))}
    u = {"w": jnp.array([[1.0], [2.0], [3.0]])}
    total = np.zeros(1)
    T = 4000
    for t in range(T):
        active = jnp.asarray(rng.random(3) < probs)
        state = algo.init_state(params, 3)
        _, p_new, _ = algo.round_step(state, params, u, jnp.zeros(3), active,
                                      jnp.float32(1.0))
        total += -np.asarray(p_new["w"])
    np.testing.assert_allclose(total / T, [2.0], atol=0.1)  # mean(1,2,3)


def test_sampling_waits_for_cohort():
    """Params must stay frozen until every selected device has responded."""
    params = {"w": jnp.zeros((1,))}
    algo = FedAvgSampling(s=2)
    state = algo.init_state(params, 4)
    rng = jax.random.PRNGKey(0)
    u = {"w": jnp.ones((4, 1))}
    # nobody active: no update possible
    state, p1, m = algo.round_step(state, params, u, jnp.zeros(4),
                                   jnp.zeros(4, bool), jnp.float32(1.0), rng)
    np.testing.assert_allclose(p1["w"], params["w"])
    assert int(state["t_updates"]) == 0
    sel = np.asarray(state["selected"])
    assert sel.sum() == 2
    # only selected devices active: cohort completes, update applied
    state, p2, m = algo.round_step(state, p1, u, jnp.zeros(4),
                                   jnp.asarray(sel), jnp.float32(1.0), rng)
    assert int(state["t_updates"]) == 1
    np.testing.assert_allclose(p2["w"], [-1.0])
    assert bool(state["need_resample"])


def test_sampling_counts_updates_under_stragglers():
    """With a straggler in the pool, global updates accrue slowly (Eq. 3)."""
    rng_np = np.random.default_rng(0)
    probs = np.array([0.05] + [0.9] * 7)
    params = {"w": jnp.zeros((1,))}
    algo = FedAvgSampling(s=4)
    state = algo.init_state(params, 8)
    key = jax.random.PRNGKey(1)
    u = {"w": jnp.ones((8, 1))}
    T = 200
    for t in range(T):
        key, sub = jax.random.split(key)
        active = jnp.asarray(rng_np.random(8) < probs) if t else jnp.ones(8, bool)
        state, params, _ = algo.round_step(state, params, u, jnp.zeros(8),
                                           active, jnp.float32(0.1), sub)
    # far fewer global updates than rounds
    assert int(state["t_updates"]) < T // 2


def test_scaffold_runs_and_updates():
    params = {"w": jnp.zeros((2,))}
    algo = SCAFFOLDSampling(s=2, k_steps=1)
    state = algo.init_state(params, 4)
    key = jax.random.PRNGKey(0)
    u = {"w": jnp.ones((4, 2))}
    for t in range(6):
        key, sub = jax.random.split(key)
        state, params, _ = algo.round_step(state, params, u, jnp.zeros(4),
                                           jnp.ones(4, bool), jnp.float32(0.1),
                                           sub)
    assert int(state["t_updates"]) == 6
    assert np.all(np.isfinite(np.asarray(params["w"])))
