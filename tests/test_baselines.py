"""FedAvg-variant baselines (paper Algorithm 2) + competing fixes
(FedAR, CA-Fed) from the related work."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MIFA, BiasedFedAvg, CAFed, FedAR, FedAvgIS,
                        FedAvgSampling, SCAFFOLDSampling)

N = 5


def test_biased_averages_active_only():
    params = {"w": jnp.zeros((2,))}
    algo = BiasedFedAvg()
    state = algo.init_state(params, 3)
    u = {"w": jnp.array([[3.0, 3.0], [1.0, 1.0], [100.0, 100.0]])}
    active = jnp.array([True, True, False])
    _, params, _ = algo.round_step(state, params, u, jnp.zeros(3), active,
                                   jnp.float32(1.0))
    np.testing.assert_allclose(params["w"], [-2.0, -2.0])  # mean of active


def test_is_weights_by_inverse_probability():
    params = {"w": jnp.zeros((1,))}
    probs = (0.5, 0.25)
    algo = FedAvgIS(probs)
    state = algo.init_state(params, 2)
    u = {"w": jnp.array([[1.0], [1.0]])}
    _, p_act, _ = algo.round_step(state, params, u, jnp.zeros(2),
                                  jnp.array([True, True]), jnp.float32(1.0))
    # update = mean_i(u_i/p_i) = (1/0.5 + 1/0.25)/2 = 3
    np.testing.assert_allclose(p_act["w"], [-3.0])


def test_is_unbiased_over_rounds():
    """E[IS update] equals the all-active mean update."""
    rng = np.random.default_rng(0)
    probs = np.array([0.2, 0.5, 0.9])
    algo = FedAvgIS(tuple(probs))
    params = {"w": jnp.zeros((1,))}
    u = {"w": jnp.array([[1.0], [2.0], [3.0]])}
    total = np.zeros(1)
    T = 4000
    for t in range(T):
        active = jnp.asarray(rng.random(3) < probs)
        state = algo.init_state(params, 3)
        _, p_new, _ = algo.round_step(state, params, u, jnp.zeros(3), active,
                                      jnp.float32(1.0))
        total += -np.asarray(p_new["w"])
    np.testing.assert_allclose(total / T, [2.0], atol=0.1)  # mean(1,2,3)


def test_is_zero_prob_client_is_excluded_finite():
    """p_i = 0 must not produce inf/nan: the unguarded `act / p` division
    used to poison params the moment a zero-prob client appeared active
    (e.g. a scenario whose stationary rate underflows)."""
    params = {"w": jnp.zeros((1,))}
    algo = FedAvgIS((0.5, 0.0, 1.0))
    state = algo.init_state(params, 3)
    u = {"w": jnp.array([[1.0], [1.0], [1.0]])}
    active = jnp.array([True, True, False])
    _, p_new, m = algo.round_step(state, params, u, jnp.zeros(3), active,
                                  jnp.float32(1.0))
    assert np.all(np.isfinite(np.asarray(p_new["w"])))
    assert np.isfinite(float(m["loss"]))
    # the zero-prob client contributes weight 0, not inf: (1/0.5 + 0 + 0)/3
    np.testing.assert_allclose(p_new["w"], [-2.0 / 3.0])


def test_is_probs_live_in_state_not_statics():
    """Regression: `probs` used to sit on the hashable dataclass as a jit
    static, so every distinct probability vector retraced the round. The
    fix moves them into the algorithm state pytree — one trace must serve
    two different probs vectors (and still produce their different
    outputs)."""
    params = {"w": jnp.zeros((1,))}
    u = {"w": jnp.array([[1.0], [1.0]])}
    active = jnp.ones(2, bool)
    traces = []

    @jax.jit
    def step(state, params):
        traces.append(1)  # python side effect: runs once per trace
        algo = FedAvgIS((1.0, 1.0))  # dummy probs; real ones ride `state`
        return algo.round_step(state, params, u, jnp.zeros(2), active,
                               jnp.float32(1.0))

    s_half = FedAvgIS((0.5, 0.5)).init_state(params, 2)
    s_quarter = FedAvgIS((0.25, 0.25)).init_state(params, 2)
    _, p_half, _ = step(s_half, params)
    _, p_quarter, _ = step(s_quarter, params)
    assert len(traces) == 1, "distinct probs vectors must NOT retrace"
    np.testing.assert_allclose(p_half["w"], [-2.0])
    np.testing.assert_allclose(p_quarter["w"], [-4.0])


def _one_round(algo, params, u, active, n):
    state = algo.init_state(params, n)
    return algo.round_step(state, params, u, jnp.zeros(n),
                           jnp.asarray(active), jnp.float32(1.0))


def test_fedar_decay_one_equals_mifa():
    """decay=1 keeps every surrogate at full weight — exactly MIFA."""
    params = {"w": jnp.zeros((2,))}
    u = {"w": jnp.array([[3.0, 3.0], [1.0, 1.0], [2.0, 2.0]])}
    active = [True, False, True]
    _, p_ar, _ = _one_round(FedAR(decay=1.0), params, u, active, 3)
    _, p_mifa, _ = _one_round(MIFA(), params, u, active, 3)
    # Σα·U/Σα vs MIFA's Σ(U/n): same mean up to fp association order
    np.testing.assert_allclose(np.asarray(p_ar["w"]),
                               np.asarray(p_mifa["w"]), rtol=1e-6)


def test_fedar_decay_zero_equals_biased_fedavg():
    """decay=0 zeroes every stale surrogate — exactly active-mean FedAvg
    (up to the denominator: α sums to the active count)."""
    params = {"w": jnp.zeros((2,))}
    u = {"w": jnp.array([[3.0, 3.0], [1.0, 1.0], [100.0, 100.0]])}
    active = [True, True, False]
    _, p_ar, _ = _one_round(FedAR(decay=0.0), params, u, active, 3)
    _, p_avg, _ = _one_round(BiasedFedAvg(), params, u, active, 3)
    np.testing.assert_allclose(np.asarray(p_ar["w"]), np.asarray(p_avg["w"]))


def test_fedar_rectification_discounts_staleness():
    """A surrogate unrefreshed for τ rounds enters the average with weight
    decay**τ, and α re-normalises the mean."""
    decay = 0.5
    algo = FedAR(decay=decay)
    params = {"w": jnp.zeros((1,))}
    state = algo.init_state(params, 2)
    u1 = {"w": jnp.array([[1.0], [5.0]])}
    # round 0: both active -> surrogates {1, 5}, τ = {0, 0}
    state, params, _ = algo.round_step(state, params, u1, jnp.zeros(2),
                                       jnp.ones(2, bool), jnp.float32(0.0))
    # rounds 1..2: client 1 inactive -> its τ grows to 2
    u2 = {"w": jnp.array([[1.0], [999.0]])}  # 999 must be masked out
    for _ in range(2):
        state, params, _ = algo.round_step(
            state, params, u2, jnp.zeros(2),
            jnp.array([True, False]), jnp.float32(0.0))
    assert state["tau"].tolist() == [0, 2]
    # η=1 step: client 1 misses a third round (τ -> 3 inside the step),
    # so g = (1·1 + 0.125·5) / (1 + 0.125)
    state, p_new, _ = algo.round_step(state, params, u2, jnp.zeros(2),
                                      jnp.array([True, False]),
                                      jnp.float32(1.0))
    want = (1.0 * 1.0 + decay**3 * 5.0) / (1.0 + decay**3)
    np.testing.assert_allclose(np.asarray(p_new["w"]), [-want], rtol=1e-6)


def test_cafed_estimates_converge_to_chain_stats():
    """The EWMA trackers recover (π, P(act|act), P(inact|inact)) of the
    availability process. A deterministic periodic pattern keeps the test
    exact: [1,1,1,0,0] repeating has π = 0.6, P(act|act) = 2/3,
    P(inact|inact) = 1/2, and a small-ρ EWMA settles into a tight orbit
    around those values."""
    pattern = [True, True, True, False, False]
    algo = CAFed(rho=0.05)
    params = {"w": jnp.zeros((1,))}
    state = algo.init_state(params, 1)
    u = {"w": jnp.zeros((1, 1))}
    for t in range(600):
        state, params, _ = algo.round_step(
            state, params, u, jnp.zeros(1),
            jnp.array([pattern[t % 5]]), jnp.float32(0.0))
    assert abs(float(state["pi_hat"][0]) - 0.6) < 0.15
    assert abs(float(state["stay_up"][0]) - 2 / 3) < 0.15
    assert abs(float(state["stay_dn"][0]) - 1 / 2) < 0.15


def test_cafed_excludes_long_burst_clients():
    """A client whose inactive bursts are long (stay_dn > d_max) is
    excluded from the average once its estimate crosses the threshold."""
    algo = CAFed(rho=0.1, d_max=0.8)
    params = {"w": jnp.zeros((1,))}
    state = algo.init_state(params, 2)
    u = {"w": jnp.array([[1.0], [50.0]])}
    # client 1 flaps off after round 0 and stays dark -> stay_dn -> 1
    state, params, _ = algo.round_step(state, params, u, jnp.zeros(2),
                                       jnp.ones(2, bool), jnp.float32(0.0))
    for _ in range(20):
        state, params, _ = algo.round_step(
            state, params, u, jnp.zeros(2),
            jnp.array([True, False]), jnp.float32(0.0))
    assert float(state["stay_dn"][1]) > 0.9
    # client 1 reappears for one round: an i.i.d.-style IS correction
    # would up-weight it by 1/π; CA-Fed excludes it instead
    state, p_new, _ = algo.round_step(state, params, u, jnp.zeros(2),
                                      jnp.ones(2, bool), jnp.float32(1.0))
    w0 = float(np.asarray(p_new["w"])[0])
    # only client 0's update (weight 1/π̂₀ ≈ 1) enters; 50 never does
    assert -3.0 < w0 < 0.0


def test_cafed_all_excluded_falls_back_to_everyone():
    """If the threshold would empty the cohort, CA-Fed must include
    everyone rather than freeze the model on a zero denominator."""
    algo = CAFed(rho=1.0, d_max=0.0)  # instant estimates, exclude on any
    params = {"w": jnp.zeros((1,))}
    state = algo.init_state(params, 2)
    u = {"w": jnp.array([[1.0], [1.0]])}
    # one all-dark round drives every stay_dn above d_max=0
    state, params, _ = algo.round_step(state, params, u, jnp.zeros(2),
                                       jnp.zeros(2, bool), jnp.float32(1.0))
    state, p_new, m = algo.round_step(state, params, u, jnp.zeros(2),
                                      jnp.ones(2, bool), jnp.float32(1.0))
    assert np.all(np.isfinite(np.asarray(p_new["w"])))
    assert float(np.asarray(p_new["w"])[0]) < 0.0  # the step still moved


@pytest.mark.parametrize("algo_fn", [
    lambda: FedAR(decay=0.5), lambda: CAFed()], ids=["fedar", "cafed"])
def test_new_baselines_are_scan_compatible_pure_fns(algo_fn):
    """round_step must be jit-pure with a fixed state structure: same
    treedef/shapes/dtypes out as in (the scan-carry contract)."""
    algo = algo_fn()
    params = {"w": jnp.zeros((3,))}
    state = algo.init_state(params, 4)
    u = {"w": jnp.ones((4, 3))}
    stepped = jax.jit(algo.round_step)(state, params, u, jnp.zeros(4),
                                       jnp.ones(4, bool), jnp.float32(0.1))
    new_state, new_params, metrics = stepped
    assert (jax.tree.structure(new_state) == jax.tree.structure(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert set(metrics) >= {"loss", "n_active"}


def test_sampling_waits_for_cohort():
    """Params must stay frozen until every selected device has responded."""
    params = {"w": jnp.zeros((1,))}
    algo = FedAvgSampling(s=2)
    state = algo.init_state(params, 4)
    rng = jax.random.PRNGKey(0)
    u = {"w": jnp.ones((4, 1))}
    # nobody active: no update possible
    state, p1, m = algo.round_step(state, params, u, jnp.zeros(4),
                                   jnp.zeros(4, bool), jnp.float32(1.0), rng)
    np.testing.assert_allclose(p1["w"], params["w"])
    assert int(state["t_updates"]) == 0
    sel = np.asarray(state["selected"])
    assert sel.sum() == 2
    # only selected devices active: cohort completes, update applied
    state, p2, m = algo.round_step(state, p1, u, jnp.zeros(4),
                                   jnp.asarray(sel), jnp.float32(1.0), rng)
    assert int(state["t_updates"]) == 1
    np.testing.assert_allclose(p2["w"], [-1.0])
    assert bool(state["need_resample"])


def test_sampling_counts_updates_under_stragglers():
    """With a straggler in the pool, global updates accrue slowly (Eq. 3)."""
    rng_np = np.random.default_rng(0)
    probs = np.array([0.05] + [0.9] * 7)
    params = {"w": jnp.zeros((1,))}
    algo = FedAvgSampling(s=4)
    state = algo.init_state(params, 8)
    key = jax.random.PRNGKey(1)
    u = {"w": jnp.ones((8, 1))}
    T = 200
    for t in range(T):
        key, sub = jax.random.split(key)
        active = jnp.asarray(rng_np.random(8) < probs) if t else jnp.ones(8, bool)
        state, params, _ = algo.round_step(state, params, u, jnp.zeros(8),
                                           active, jnp.float32(0.1), sub)
    # far fewer global updates than rounds
    assert int(state["t_updates"]) < T // 2


def test_scaffold_runs_and_updates():
    params = {"w": jnp.zeros((2,))}
    algo = SCAFFOLDSampling(s=2, k_steps=1)
    state = algo.init_state(params, 4)
    key = jax.random.PRNGKey(0)
    u = {"w": jnp.ones((4, 2))}
    for t in range(6):
        key, sub = jax.random.split(key)
        state, params, _ = algo.round_step(state, params, u, jnp.zeros(4),
                                           jnp.ones(4, bool), jnp.float32(0.1),
                                           sub)
    assert int(state["t_updates"]) == 6
    assert np.all(np.isfinite(np.asarray(params["w"])))
