import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.models import build_model


def test_roundtrip_simple(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nested": {"b": jnp.ones(4), "c": [jnp.zeros(2), jnp.ones(1)]}}
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree)
    back = load_pytree(p)
    assert isinstance(back["nested"]["c"], list)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_model_params(tmp_path):
    """Numeric-string dict keys (segment indices) must stay dicts."""
    cfg = get_smoke_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p = str(tmp_path / "model.npz")
    save_pytree(p, params)
    back = load_pytree(p)
    assert isinstance(back["segments"], dict)
    assert set(back["segments"].keys()) == set(params["segments"].keys())
    lo, lb = jax.tree.leaves(params), jax.tree.leaves(back)
    assert len(lo) == len(lb)
    for a, b in zip(lo, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored params run
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    l1, _ = model.loss_fn(params, batch)
    l2, _ = model.loss_fn(back, batch)
    assert float(l1) == float(l2)


def test_roundtrip_mifa_state(tmp_path):
    from repro.core import MIFA
    params = {"w": jnp.ones((3, 2))}
    st = MIFA(memory="int8").init_state(params, 4)
    p = str(tmp_path / "state.npz")
    save_pytree(p, st)
    back = load_pytree(p)
    assert back["G_q"]["w"].dtype == jnp.int8
    assert back["G_q"]["w"].shape == (4, 3, 2)


def test_save_appends_npz_suffix(tmp_path):
    p = save_pytree(str(tmp_path / "bare"), {"a": jnp.ones(2)})
    assert p == str(tmp_path / "bare.npz") and os.path.exists(p)


def test_atomic_save_survives_torn_write(tmp_path, monkeypatch):
    """A crash mid-write (np.savez dies after emitting partial bytes) must
    leave the PREVIOUS snapshot intact and no temp litter behind — the
    durability contract `checkpoint.run_state` resumes on."""
    p = str(tmp_path / "ck.npz")
    save_pytree(p, {"a": jnp.arange(3)})

    def torn_savez(f, **arrays):
        f.write(b"PK\x03\x04 partial garbage")
        raise OSError("disk gone")
    monkeypatch.setattr(np, "savez", torn_savez)
    with pytest.raises(OSError, match="disk gone"):
        save_pytree(p, {"a": jnp.arange(3) * 100})
    monkeypatch.undo()
    back = load_pytree(p)                     # old snapshot still loads
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(3))
    assert os.listdir(tmp_path) == ["ck.npz"]  # no tmp files left
