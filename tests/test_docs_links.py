"""Docs integrity: intra-repo markdown links must resolve.

Scans README.md, the root markdown files, and docs/**.md for markdown
links `[text](target)`; every relative target (optionally with a #anchor)
must exist on disk, resolved against the file that contains it. External
(http/https/mailto) links are skipped — CI must not depend on the network.
The CI `docs` job runs exactly this file.
"""
import os
import re

import pytest

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")


def _markdown_files():
    files = [os.path.join(REPO, f) for f in os.listdir(REPO)
             if f.endswith(".md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        for root, _dirs, names in os.walk(docs):
            files += [os.path.join(root, f) for f in names
                      if f.endswith(".md")]
    return sorted(files)


def _broken_links(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    broken = []
    for target in _LINK.findall(text):
        if target.startswith(_SKIP) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            broken.append(target)
    return broken


@pytest.mark.parametrize(
    "path", _markdown_files(),
    ids=[os.path.relpath(p, REPO) for p in _markdown_files()])
def test_intra_repo_markdown_links_resolve(path):
    broken = _broken_links(path)
    assert not broken, (
        f"{os.path.relpath(path, REPO)} has broken intra-repo links: "
        f"{broken}")


def test_docs_tree_exists():
    """The durable reference tree README points at must be present."""
    for f in ("architecture.md", "scenarios.md", "benchmarks.md",
              "operations.md"):
        assert os.path.isfile(os.path.join(REPO, "docs", f)), f
