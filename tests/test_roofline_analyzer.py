"""Loop-aware HLO analyzer unit tests against a hand-built HLO fixture."""
import pytest

from repro.roofline.analysis import (HW, _analyze_computation, parse_hlo,
                                     roofline_terms)

FIXTURE = """HloModule jit_f, num_partitions=8

%body (p: (s32[], f32[16,32])) -> (s32[], f32[16,32]) {
  %p = (s32[], f32[16,32]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,32]{1,0} get-tuple-element(%p), index=1
  %w = f32[32,32]{1,0} constant({...})
  %dot.1 = f32[16,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce.1 = f32[16,32]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add_comp
  %one = s32[] constant(1)
  %next = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[16,32]) tuple(%next, %all-reduce.1)
}

%cond (p2: (s32[], f32[16,32])) -> pred[] {
  %p2 = (s32[], f32[16,32]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (arg: f32[16,32]) -> f32[16,32] {
  %arg = f32[16,32]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,32]) tuple(%zero, %arg)
  %while.1 = (s32[], f32[16,32]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[16,64]{1,0} all-gather(%arg), channel_id=2, replica_groups={{0,1},{2,3},{4,5},{6,7}}, dimensions={1}
  ROOT %out = f32[16,32]{1,0} get-tuple-element(%while.1), index=1
}
"""


@pytest.fixture()
def analyzed():
    comps = parse_hlo(FIXTURE)
    symtab = {op.name: op.type_str for c in comps.values() for op in c.ops}
    return _analyze_computation(comps["__entry__"], symtab, comps, {})


def test_trip_count_multiplies_flops(analyzed):
    flops, _, _, _ = analyzed
    # dot: 2*16*32*32 = 32768 per iteration, 10 iterations
    assert flops == pytest.approx(10 * 2 * 16 * 32 * 32)


def test_collective_operand_bytes(analyzed):
    _, _, _, coll = analyzed
    # all-reduce in loop: result 16*32*4 B = 2048, x10
    assert coll["all-reduce"] == pytest.approx(10 * 2048)
    # all-gather at top: result 16*64*4 = 4096, group size 2 => operand 2048
    assert coll["all-gather"] == pytest.approx(2048)


def test_bytes_scale_with_trip(analyzed):
    _, nbytes, _, _ = analyzed
    assert nbytes > 10 * 2048  # at least the loop's dot traffic


def test_roofline_terms_pick_bottleneck():
    analysis = {
        "hlo_flops_parsed": 1e12, "cost_analysis_flops": 0.0,
        "hlo_bytes_parsed": 1e9, "cost_analysis_bytes": 0.0,
        "collective_bytes_total": 1e6,
    }
    t = roofline_terms(analysis)
    # 1e12/197e12 ≈ 5ms; 1e9/819e9 ≈ 1.2ms; 1e6/50e9 = 0.02ms
    assert t["bottleneck"] == "compute"
    assert t["step_time_lower_bound_s"] == pytest.approx(1e12 / HW["peak_flops"])


def test_parse_handles_tuple_types():
    comps = parse_hlo(FIXTURE)
    body = comps["body"]
    opcodes = {o.opcode for o in body.ops}
    assert "dot" in opcodes and "all-reduce" in opcodes
