"""Theorems 5.2 / 5.3 validation: τ(t,i) statistics under i.i.d. Bernoulli
participation.

Thm 5.2: τ(t,i) = O((log(Nt/δ)+1)/p_i) w.h.p.; Assumption 4 holds.
Thm 5.3: τ̄_T <= avg(1/p_i) * O(1 + log 1/δ) w.h.p. (expectation ≈ avg(1/p_i)-1).
"""
from __future__ import annotations

import time

import numpy as np
from common import emit, save_artifact

from repro.core import BernoulliParticipation, TauStats, tau_matrix


def main(fast: bool = False) -> None:
    N = 100
    T = 2_000 if fast else 10_000
    rng = np.random.default_rng(0)
    probs = np.clip(rng.uniform(0.05, 1.0, N), 0.05, 1.0)
    part = BernoulliParticipation(probs, seed=1)

    t0 = time.time()
    masks = np.stack([part.sample(t) for t in range(T)])
    tm = tau_matrix(masks)
    wall = (time.time() - t0) * 1e6

    stats = TauStats(N)
    for t in range(T):
        stats.update(masks[t])

    # Thm 5.3: empirical tau_bar vs avg(1/p) (E[tau] = (1-p)/p per device)
    avg_inv_p = float(np.mean(1.0 / probs))
    expected_tau_bar = float(np.mean((1 - probs) / probs))
    tau_bar = stats.tau_bar

    # Thm 5.2: per-device max tau vs (log(NT)+1)/p_i — compute the max ratio
    bound = (np.log(N * T / 0.01) + 1) / probs
    ratio = float((tm.max(0) / bound).max())

    # tau_max growth in t: fit tau_running_max(t) against log t
    run_max = np.maximum.accumulate(tm.max(1))
    ts = np.arange(1, T + 1)
    corr = float(np.corrcoef(np.log(ts[10:]), run_max[10:])[0, 1])

    payload = {
        "N": N, "T": T,
        "tau_bar_empirical": tau_bar,
        "tau_bar_theory_mean": expected_tau_bar,
        "avg_inv_p": avg_inv_p,
        "thm52_max_ratio_to_bound": ratio,    # should be < 1
        "tau_max": stats.tau_max,
        "log_t_growth_corr": corr,            # should be high (log growth)
        "d_bar": stats.d_bar,
    }
    save_artifact("tau_stats", payload)
    emit("tau_stats/thm53_tau_bar", wall,
         f"empirical={tau_bar:.3f};theory={expected_tau_bar:.3f}")
    emit("tau_stats/thm52_bound_ratio", wall, f"{ratio:.3f}<1")
    emit("tau_stats/tau_max_loggrowth_corr", wall, f"{corr:.3f}")
    assert ratio < 1.0, "Thm 5.2 bound violated"
    assert abs(tau_bar - expected_tau_bar) < 0.25 * expected_tau_bar + 0.1


if __name__ == "__main__":
    main()
