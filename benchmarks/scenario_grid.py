"""Scenario convergence grid: algorithm × availability-scenario fleet sweep.

The paper's theory makes no distributional assumption on A(t), but the
related work (docs/scenarios.md) shows WHERE that generality pays:
correlated and non-stationary availability is what breaks FedAvg-style
baselines. This benchmark sweeps a `FleetSpec` grid of
`seed × scenario × algorithm` — with availability sampled INSIDE the jitted
round for dense algorithms (jit-native scenario surface; no (T, N) trace is
ever materialised) — over scenarios ordered by increasing correlation /
non-stationarity:

    iid Bernoulli  →  Gilbert–Elliott (short bursts)  →  Gilbert–Elliott
    (long bursts)  →  staged hard blackouts (non-stationary, but
    Assumption 4 holds: deterministic bounded τ)  →  cluster-correlated
    regional outages (correlated ACROSS devices, unbounded τ)

The stochastic scenarios are calibrated to a ~0.5 mean activity rate so
what varies along the axis is the correlation structure. Per cell we record
final eval loss/accuracy (mean over seeds), rounds-to-target
(time-to-accuracy in rounds), and per scenario the empirical τ statistics
plus the `tau_bound()` theory classification. Gap columns are DERIVED from
the configured algorithm list (every algorithm minus the `mifa` reference),
so extending the list — `benchmarks/scenario_atlas.py` runs the full
six-algorithm competing-baseline atlas through this same sweep — can never
KeyError the benchmark. The headline table in
benchmarks/artifacts/scenario_grid.md tracks the MIFA-vs-FedAvg gap as the
scenario axis hardens.
"""
from __future__ import annotations

import os
import time

import numpy as np
from common import ARTIFACTS, emit, paper_problem, save_artifact

from repro.core import make_algorithm, tau_matrix
from repro.fleet import Trial, make_fleet_eval, run_fleet
from repro.optim import inv_t
from repro.scenarios import make_scenario

GRID_ALGOS = ("mifa", "banked_mifa", "fedavg", "fedavg_is")
GAP_REF = "mifa"


def scenario_axis(stage_len: int) -> list[tuple[str, str, dict]]:
    """(label, registry name, kwargs) ordered by correlation strength.

    All points are calibrated to ≈0.5 stationary activity so the axis
    varies correlation/non-stationarity, not the participation budget.
    """
    return [
        ("iid", "bernoulli", {"probs": 0.5}),
        ("ge_burst4", "gilbert_elliott", {"rate": 0.5, "burst": 4.0}),
        ("ge_burst16", "gilbert_elliott", {"rate": 0.5, "burst": 16.0}),
        ("staged_blackout", "staged_blackout",
         {"dark_frac": 0.5, "stage_len": stage_len}),
        ("cluster", "cluster",
         {"n_clusters": 4, "q_fail": 0.08, "q_recover": 0.08,
          "p_device": 1.0}),
    ]


def gap_pairs(algo_names, ref: str = GAP_REF) -> list[tuple[str, str]]:
    """(minuend, subtrahend) gap columns derived from the configured algo
    list: every non-reference algorithm minus the memorisation reference
    (`mifa`, else the first algorithm). Positive gap = the reference ends
    at a lower loss. Deriving the pairs here — instead of hardcoding
    cell["algorithms"]["fedavg"]/["mifa"] lookups — is what lets the atlas
    grow the algorithm list without KeyErroring the benchmark."""
    names = list(algo_names)
    if ref not in names:
        ref = names[0]
    return [(a, ref) for a in names if a != ref]


def scenario_tau_stats(scen, n_rounds: int) -> dict:
    """Empirical τ statistics from the host surface + theory classification."""
    sampler = scen.process.host_sampler()
    masks = np.stack([sampler.sample(t) for t in range(n_rounds)])
    # elastic fleets legitimately violate Definition 5.2(1) at round 0
    # (un-arrived clients); their τ counts from the virtual round −1
    tm = tau_matrix(masks, strict=scen.process.round0_all_active)
    tb = scen.process.tau_bound()
    return {
        "rate_empirical": float(masks.mean()),
        "rate_stationary": float(scen.process.stationary_rate().mean()),
        "tau_bar": float(tm.mean()),
        "tau_max": int(tm.max()),
        "assumption4_deterministic": bool(tb.deterministic),
        "assumption4_t0": float(tb.t0),
        "expected_tau": float(tb.expected_tau),
        "tau_note": tb.note,
    }


def build_algorithms(names, n_clients: int, scen0) -> dict:
    """Instantiate the registry algorithms for one scenario cell.

    FedAvg-IS is told the STATIONARY marginals — the best any
    i.i.d.-assuming correction can do under correlated availability;
    everything else is default-constructed (CA-Fed estimates its own
    availability statistics in-state)."""
    is_probs = np.clip(scen0.process.stationary_rate(), 0.05, 1.0)
    kw = {"fedavg_is": {"probs": is_probs}}
    return {name: make_algorithm(name, n=n_clients, **kw.get(name, {}))
            for name in names}


def sweep_cells(*, algo_names, n_clients: int, n_rounds: int, seeds,
                stage_len: int, engine: str = "loop",
                emit_prefix: str = "scenario_grid",
                n_per_class: int = 500, axis=None) -> dict:
    """Run the algorithm × scenario × seed sweep; returns the results dict.

    Each (scenario, algorithm) cell runs its seeds as ONE fleet program —
    `engine="scan"` compiles the whole cell into jit(scan(vmap)) chunks
    (the atlas path); "loop" dispatches one vmapped program per round.
    `axis` overrides the scenario axis — a list of (label, registry name,
    kwargs) cells; default `scenario_axis(stage_len)`. The atlas appends a
    trace-replay cell; benchmarks/trace_replay.py sweeps a pure
    trace/elastic axis over the committed fixture.
    """
    if axis is None:
        axis = scenario_axis(stage_len)
    model, batcher, _probs, _mp, eval_fn = paper_problem(
        "paper_logistic", n_clients=n_clients, n_per_class=n_per_class)
    fleet_eval = make_fleet_eval(model, eval_fn.eval_batch)
    kw = dict(model=model, batcher=batcher, schedule=inv_t(1.0),
              n_rounds=n_rounds, weight_decay=1e-3,
              eval_every=max(n_rounds // 10, 1), eval_fn=fleet_eval,
              cohort_capacity=None, engine=engine)

    results: dict = {"n_clients": n_clients, "n_rounds": n_rounds,
                     "seeds": list(seeds), "engine": engine,
                     "algorithms": list(algo_names), "cells": []}
    for label, name, kwargs in axis:
        scen0 = make_scenario(name, n=n_clients, seed=0, **kwargs)
        tau = scenario_tau_stats(scen0, n_rounds)
        algos = build_algorithms(algo_names, n_clients, scen0)
        cell = {"scenario": label, "registry": name, "kwargs": kwargs,
                "tau": tau, "algorithms": {}}
        for aname, algo in algos.items():
            trials = [Trial(seed=s,
                            scenario=make_scenario(name, n=n_clients,
                                                   seed=1000 + 17 * s,
                                                   **kwargs),
                            label=f"{label}/{aname}/seed{s}")
                      for s in seeds]
            t0 = time.time()
            _, hist = run_fleet(algo=algo, trials=trials, **kw)
            wall = time.time() - t0
            losses = np.asarray(hist.eval_loss[-1][1], np.float64)
            accs = np.asarray(hist.eval_acc[-1][1], np.float64)
            cell["algorithms"][aname] = {
                "final_loss_mean": float(losses.mean()),
                "final_acc_mean": float(accs.mean()),
                "final_loss_all": losses.tolist(),
                "eval_curve_mean": [
                    (int(t), float(np.mean(np.asarray(v))))
                    for t, v in hist.eval_loss],
                "wall_s": wall,
            }
            emit(f"{emit_prefix}/{label}/{aname}",
                 wall / len(seeds) / n_rounds * 1e6,
                 f"loss={losses.mean():.4f};acc={accs.mean():.4f}")
        # rounds-to-target: the weakest algorithm's final loss — every
        # stronger algorithm reaches it strictly earlier, so the column
        # reads as "rounds to match the laggard's end state"
        target = max(a["final_loss_mean"]
                     for a in cell["algorithms"].values())
        cell["target_loss"] = target
        for aname, a in cell["algorithms"].items():
            r = None
            for t, loss in a["eval_curve_mean"]:
                if loss <= target:
                    r = t
                    break
            a["rounds_to_target"] = r
        cell["gaps"] = {
            f"{a}_minus_{b}":
                (cell["algorithms"][a]["final_loss_mean"]
                 - cell["algorithms"][b]["final_loss_mean"])
            for a, b in gap_pairs(algo_names)}
        cell["winner"] = min(cell["algorithms"],
                             key=lambda a:
                             cell["algorithms"][a]["final_loss_mean"])
        results["cells"].append(cell)
    return results


def main(fast: bool = False) -> None:
    n_clients = 20 if fast else 60
    n_rounds = 30 if fast else 160
    seeds = (0,) if fast else (0, 1, 2)
    stage_len = max(n_rounds // 5, 4)

    results = sweep_cells(algo_names=GRID_ALGOS, n_clients=n_clients,
                          n_rounds=n_rounds, seeds=seeds,
                          stage_len=stage_len,
                          n_per_class=120 if fast else 500)
    save_artifact("scenario_grid", results)
    if not fast:
        # the committed .md is the full-scale headline table; a --fast
        # (CI smoke) run must never clobber it with toy-problem numbers
        write_md(results)


def write_md(results: dict) -> None:
    """benchmarks/artifacts/scenario_grid.md — the headline table."""
    cells = results["cells"]
    lines = [
        "# Scenario grid: MIFA vs baselines under correlated / "
        "non-stationary availability",
        "",
        f"Fleet sweep (`repro.fleet` + `repro.scenarios`): "
        f"N={results['n_clients']} clients, T={results['n_rounds']} rounds, "
        f"seeds={results['seeds']}, logistic model on synthetic non-iid "
        "data. Scenarios are ordered by increasing correlation / "
        "non-stationarity and calibrated to ≈0.5 mean activity, so the "
        "availability *budget* is constant along the axis — only its "
        "structure changes. Dense algorithms sample availability inside "
        "the jitted round (jit-native scenario surface); `banked_mifa` "
        "uses the scenarios' host surface (identical masks). Regenerate "
        "with `PYTHONPATH=src python benchmarks/run.py --only "
        "scenario_grid` (see docs/benchmarks.md). The full six-algorithm "
        "competing-baseline table lives in scenario_atlas.md.",
        "",
        "| scenario | rate | τ̄ | τ_max | A4 regime | mifa loss | "
        "banked loss | fedavg loss | fedavg-IS loss | fedavg−mifa gap |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        t = c["tau"]
        a = c["algorithms"]
        regime = ("deterministic τ≤" + f"{t['assumption4_t0']:.0f}"
                  if t["assumption4_deterministic"] else "stochastic")
        lines.append(
            f"| {c['scenario']} | {t['rate_empirical']:.2f} | "
            f"{t['tau_bar']:.2f} | {t['tau_max']} | {regime} | "
            f"{a['mifa']['final_loss_mean']:.4f} | "
            f"{a['banked_mifa']['final_loss_mean']:.4f} | "
            f"{a['fedavg']['final_loss_mean']:.4f} | "
            f"{a['fedavg_is']['final_loss_mean']:.4f} | "
            f"{c['gaps']['fedavg_minus_mifa']:+.4f} |")
    lines += [
        "",
        "## Rounds to target loss (time-to-accuracy)",
        "",
        "Target per scenario = the weakest algorithm's final loss (rounds "
        "to match the laggard's end state); `—` = never reached within "
        "the round budget.",
        "",
        "| scenario | " + " | ".join(results["algorithms"]) + " |",
        "|---|" + "---|" * len(results["algorithms"]),
    ]
    for c in cells:
        row = [c["scenario"]]
        for aname in results["algorithms"]:
            r = c["algorithms"][aname]["rounds_to_target"]
            row.append("—" if r is None else str(r))
        lines.append("| " + " | ".join(row) + " |")
    gaps = [c["gaps"]["fedavg_minus_mifa"] for c in cells]
    widened = gaps[-1] > gaps[0]
    lines += [
        "",
        "## Reading the axis",
        "",
        f"The FedAvg−MIFA final-loss gap moves from {gaps[0]:+.4f} (iid) "
        f"to {gaps[-1]:+.4f} (cluster-correlated outages) across the axis "
        f"({'widening' if widened else 'NOT widening — investigate'} with "
        "correlation/non-stationarity). Under iid availability every "
        "device reappears quickly (geometric τ with small mean), so "
        "averaging the active cohort is nearly unbiased and MIFA's memory "
        "buys little. As bursts lengthen (Gilbert–Elliott), a fixed "
        "subpopulation is blacked out for entire stages "
        "(staged_blackout), or whole clusters vanish for unbounded "
        "stretches (cluster), the active cohort becomes a biased sample "
        "of the fleet for many consecutive rounds; FedAvg drifts toward "
        "the available clients' optimum while MIFA keeps every device's "
        "last update in the average. The staged cell sits below cluster "
        "in the ordering because its τ is deterministic and bounded "
        "(Assumption 4 holds) and its recovery stage lets FedAvg "
        "re-average the whole fleet; cluster outages are both "
        "cross-device correlated and unbounded. FedAvg-IS re-weights by "
        "the *stationary* marginals: with a correct oracle (every "
        "stationary cell) its 1/p up-weighting both unbiases the average "
        "and roughly doubles the effective step on this convex problem, "
        "so it ends lowest — but on the non-stationary staged blackout "
        "the oracle marginals are simply wrong (the process's stationary "
        "rate is its all-on final stage) and it finishes worst in the "
        "row. How the competing memorisation/reweighting mechanisms "
        "(FedAR, CA-Fed) split these regimes is the scenario atlas's "
        "question (scenario_atlas.md).",
        "",
    ]
    path = os.path.join(ARTIFACTS, "scenario_grid.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
