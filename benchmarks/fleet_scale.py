"""Fleet executor scaling: K-trial vmapped sweep vs the sequential loop.

The sweep every paper figure actually needs — seeds × availability — used
to run as K independent `run_fl` calls: K jit retraces, K×T round
dispatches, K×T host→device batch uploads. The fleet executor runs the same
K trials as one vmapped program per round. This benchmark measures the
end-to-end wall clock of both paths on identical trials (same seeds, same
participation draws) for MIFA(array) and BankedMIFA(dense), and records the
speedup in benchmarks/artifacts/fleet_scale.md.

Fairness notes: both paths include their jit compilation (the sequential
loop really does retrace per trial today — that cost is the point), both
produce per-trial eval curves, and the fleet result is spot-checked against
one sequential trial so the speedup isn't coming from computing something
else.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np
from common import ARTIFACTS, emit, paper_problem, save_artifact

from repro.bank import BankedMIFA, DenseBank
from repro.core import MIFA, run_fl
from repro.fleet import Trial, make_fleet_eval, run_fleet
from repro.optim import inv_t


def one_sweep(algo_factory, *, model, batcher, make_part, eval_fn,
              n_rounds: int, seeds, cap: int) -> dict:
    kw = dict(model=model, batcher=batcher, schedule=inv_t(1.0),
              n_rounds=n_rounds, weight_decay=1e-3, cohort_capacity=cap)
    t0 = time.perf_counter()
    seq_final = []
    for s in seeds:
        p, h = run_fl(algo=algo_factory(), participation=make_part(100 + s),
                      seed=s, eval_fn=eval_fn,
                      eval_every=max(n_rounds // 5, 1), **kw)
        seq_final.append(h.eval_loss[-1][1])
    jax.block_until_ready(p)
    seq_s = time.perf_counter() - t0

    trials = [Trial(seed=s, participation=make_part(100 + s),
                    label=f"seed{s}") for s in seeds]
    fleet_eval = make_fleet_eval(model, eval_fn.eval_batch)
    t0 = time.perf_counter()
    pf, hf = run_fleet(algo=algo_factory(), trials=trials,
                       eval_fn=fleet_eval,
                       eval_every=max(n_rounds // 5, 1), **kw)
    jax.block_until_ready(pf)
    fleet_s = time.perf_counter() - t0

    fleet_final = [float(v) for v in hf.eval_loss[-1][1]]
    # sanity: the fleet computed the same sweep (bit-exact per trial is
    # covered by tests/test_fleet.py; eval goes through a separate vmapped
    # program, so compare to fp32 noise here)
    np.testing.assert_allclose(fleet_final, seq_final, rtol=1e-4, atol=1e-5)
    return {"sequential_s": seq_s, "fleet_s": fleet_s,
            "speedup": seq_s / fleet_s,
            "final_eval_loss": fleet_final}


def main(fast: bool = False) -> None:
    K = 4 if fast else 16
    n_rounds = 3 if fast else 100
    n_clients = 20 if fast else 30
    # sweep-scale regime: smaller per-round device batches than the paper's
    # single-run setup (batch 100, K=5), so per-trial dispatch + host batch
    # assembly — the costs the fleet amortises — are a realistic fraction
    model, batcher, probs, make_part, eval_fn = paper_problem(
        "paper_logistic", n_clients=n_clients, batch_size=32, k_steps=2)
    seeds = list(range(K))
    cap = 1 << (n_clients - 1).bit_length()     # shared pad width, both paths
    results = {}
    for name, factory in (("mifa_array", lambda: MIFA(memory="array")),
                          ("banked_dense", lambda: BankedMIFA(DenseBank()))):
        r = one_sweep(factory, model=model, batcher=batcher,
                      make_part=make_part, eval_fn=eval_fn,
                      n_rounds=n_rounds, seeds=seeds, cap=cap)
        results[name] = r
        emit(f"fleet_scale/{name}/K{K}", r["fleet_s"] * 1e6,
             f"seq_s={r['sequential_s']:.2f};fleet_s={r['fleet_s']:.2f};"
             f"speedup={r['speedup']:.1f}x")
    payload = {"K": K, "n_rounds": n_rounds, "n_clients": n_clients,
               "results": results}
    save_artifact("fleet_scale", payload)
    if not fast:
        write_md(payload)


def write_md(payload: dict) -> None:
    lines = [
        "# Fleet executor scaling: vmapped K-trial sweep vs sequential loop",
        "",
        f"K = {payload['K']} trials (seeds), {payload['n_rounds']} rounds, "
        f"N = {payload['n_clients']} clients, paper_logistic on synthetic "
        "non-iid data, label-correlated Bernoulli availability. Both paths "
        "run identical trials end-to-end (including jit compilation and "
        "per-trial eval curves); `benchmarks/fleet_scale.py` regenerates "
        "this file.",
        "",
        "| algorithm | sequential loop (s) | fleet (s) | speedup |",
        "|---|---|---|---|",
    ]
    for name, r in payload["results"].items():
        lines.append(f"| {name} | {r['sequential_s']:.2f} | "
                     f"{r['fleet_s']:.2f} | {r['speedup']:.1f}x |")
    lines += [
        "",
        "The sequential loop pays per-trial jit retraces plus T×K round "
        "dispatches and batch uploads; the fleet pays one trace and T "
        "vmapped dispatches. Per-trial trajectories are bit-exact between "
        "the two paths (tests/test_fleet.py), so the speedup is free: the "
        "same sweep, the same numbers, one program.",
        "",
    ]
    path = os.path.join(ARTIFACTS, "fleet_scale.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
