"""Emit the §Dry-run and §Roofline markdown tables from dryrun artifacts.

    PYTHONPATH=src python benchmarks/make_experiments_tables.py
"""
from __future__ import annotations

import glob
import json
import os
import sys

DRYRUN = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh):
    out = {}
    for f in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_gb(x):
    return f"{x / 1e9:.1f}"


def dryrun_table():
    pod = load("pod")
    mp = load("multipod")
    print("| arch | shape | pod: mem/chip (GB) | pod compile (s) | "
          "multipod: mem/chip (GB) | multipod compile (s) | status |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(pod):
        r, r2 = pod[key], mp.get(key)
        if r["status"] == "skip":
            print(f"| {key[0]} | {key[1]} | — | — | — | — | "
                  f"skip: {r['reason'][:58]} |")
            continue
        m = r["analysis"]["memory"]["peak_estimate_bytes"]
        m2 = r2["analysis"]["memory"]["peak_estimate_bytes"] if r2 else 0
        print(f"| {key[0]} | {key[1]} | {fmt_gb(m)} | {r['compile_s']} | "
              f"{fmt_gb(m2)} | {r2 and r2['compile_s']} | ok |")


def roofline_table():
    pod = load("pod")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          "bottleneck | 6ND/2ND model TF | useful ratio | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(pod):
        r = pod[key]
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        note = ""
        meta = r.get("meta", {})
        if meta.get("sequential"):
            note = "seq-clients"
        print(f"| {key[0]} | {key[1]} | {t['compute_s'] * 1e3:.1f} | "
              f"{t['memory_s'] * 1e3:.1f} | {t['collective_s'] * 1e3:.1f} | "
              f"**{t['bottleneck']}** | {r['model_flops'] / 1e12:.1f} | "
              f"{r['useful_flops_ratio']:.3f} | {note} |")


def collective_mix():
    pod = load("pod")
    print("| arch | shape | all-reduce GB | all-gather GB | "
          "reduce-scatter GB | all-to-all GB | permute GB |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(pod):
        r = pod[key]
        if r["status"] != "ok":
            continue
        c = r["analysis"]["collective_bytes"]
        print(f"| {key[0]} | {key[1]} | "
              f"{c.get('all-reduce', 0) / 1e9:.2f} | "
              f"{c.get('all-gather', 0) / 1e9:.2f} | "
              f"{c.get('reduce-scatter', 0) / 1e9:.2f} | "
              f"{c.get('all-to-all', 0) / 1e9:.2f} | "
              f"{c.get('collective-permute', 0) / 1e9:.2f} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        dryrun_table()
    if which in ("all", "roofline"):
        print("\n### Roofline (single pod)\n")
        roofline_table()
    if which in ("all", "collectives"):
        print("\n### Collective mix (single pod)\n")
        collective_mix()
