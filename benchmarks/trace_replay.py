"""Trace-replay benchmark: recorded availability + checkpoint/resume.

Two sections, both over the COMMITTED fixture trace
(benchmarks/fixtures/device_trace_n20_t64.npy — 20 devices, 64 rounds,
Gilbert–Elliott bursts with 10% permanent churn; docs/operations.md has
the recipe that generated it):

  * **Convergence cells** — the scenario-grid algorithms over a
    trace-driven axis through `sweep_cells`: the bare replayed trace and
    the same trace under an elastic fleet (staged arrivals + departures
    folded into the mask). Availability comes off disk in windows
    (`TraceReplay`), never as a (T, N) matrix; every cell runs as one
    jit(scan(vmap)) fleet program. The full (non `--fast`) run adds a
    synthesized N=60 trace cell at grid scale.
  * **Resume exactness** — the PR's durability acceptance gate as a
    measured artifact: a checkpointed run killed mid-horizon and resumed
    from its latest snapshot must match the uninterrupted run fp32
    bit-exactly. `resume.max_abs_diff` is pinned to 0.0 in
    benchmarks/baselines/ci_baseline.json — any drift (a leaf missing
    from the snapshot, a replayed sampler off by a round) fails CI.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax
import numpy as np
from common import ARTIFACTS, emit, paper_problem, save_artifact
from scenario_grid import GRID_ALGOS, sweep_cells

from repro.checkpoint import CheckpointSpec, latest_checkpoint
from repro.core import MIFA, run_fl
from repro.optim import inv_t
from repro.scenarios import Scenario, TraceReplay

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "device_trace_n20_t64.npy")


def fixture_axis() -> list[tuple[str, str, dict]]:
    """(label, registry, kwargs) cells over the committed fixture trace."""
    return [
        ("trace_fixture", "trace_replay", {"path": FIXTURE}),
        ("trace_elastic", "elastic",
         {"inner": "trace_replay", "inner_kwargs": {"path": FIXTURE},
          "n_initial": 10, "arrive_every": 8, "depart_frac": 0.1,
          "depart_at": 40}),
    ]


def resume_section(fast: bool) -> dict:
    """Kill a checkpointed run mid-horizon, resume, measure the deviation
    from the uninterrupted run (0.0 == bit-exact, the pinned value)."""
    T = 24 if fast else 64
    kill, every, chunk, window = T // 2, T // 4, 8, 16
    model, batcher, _probs, _mp, _eval = paper_problem(
        "paper_logistic", n_clients=20, n_per_class=120 if fast else 500,
        batch_size=20, k_steps=2)
    scen = lambda: Scenario(TraceReplay(FIXTURE, window=window),
                            name="fixture")
    kw = dict(model=model, batcher=batcher, schedule=inv_t(1.0),
              weight_decay=1e-3, seed=0, eval_every=T,
              engine="scan_strict", scan_chunk=chunk)
    work = tempfile.mkdtemp(prefix="trace_replay_ck_")
    try:
        spec = lambda d, resume=False: CheckpointSpec(
            every=every, dir=os.path.join(work, d), resume=resume)
        t0 = time.time()
        params_full, hist_full = run_fl(algo=MIFA(memory="array"),
                                        scenario=scen(), n_rounds=T,
                                        checkpoint=spec("full"), **kw)
        wall_full = time.time() - t0
        run_fl(algo=MIFA(memory="array"), scenario=scen(), n_rounds=kill,
               checkpoint=spec("killed"), **kw)
        t0 = time.time()
        params_res, hist_res = run_fl(algo=MIFA(memory="array"),
                                      scenario=scen(), n_rounds=T,
                                      checkpoint=spec("killed", resume=True),
                                      **kw)
        wall_resumed = time.time() - t0
        diffs = [np.abs(np.asarray(a, np.float64) - np.asarray(b, np.float64))
                 for a, b in zip(jax.tree.leaves(params_full),
                                 jax.tree.leaves(params_res))]
        max_diff = float(max(d.max() for d in diffs))
        loss_diff = float(np.max(np.abs(
            np.asarray(hist_full.train_loss, np.float64)
            - np.asarray(hist_res.train_loss, np.float64))))
        snap = latest_checkpoint(os.path.join(work, "killed"))
        out = {"n_rounds": T, "kill_at": kill, "every": every,
               "max_abs_diff": max_diff, "train_loss_max_diff": loss_diff,
               "snapshot_bytes": os.path.getsize(snap),
               "wall_full_s": wall_full, "wall_resumed_s": wall_resumed}
        emit("trace_replay/resume", wall_resumed / max(T - kill, 1) * 1e6,
             f"max_abs_diff={max_diff:g};snapshot_kb="
             f"{out['snapshot_bytes'] / 1024:.0f}")
        return out
    finally:
        shutil.rmtree(work, ignore_errors=True)


def main(fast: bool = False) -> None:
    n_rounds = 24 if fast else 64          # fixture records 64 rounds
    seeds = (0,) if fast else (0, 1, 2)
    results = sweep_cells(algo_names=GRID_ALGOS, n_clients=20,
                          n_rounds=n_rounds, seeds=seeds, stage_len=8,
                          engine="scan", emit_prefix="trace_replay",
                          n_per_class=120 if fast else 500,
                          axis=fixture_axis())
    if not fast:
        # grid-scale synthesized trace (cached under the tempdir; each
        # seed records its own trace, matching the atlas cell's recipe)
        synth = sweep_cells(
            algo_names=GRID_ALGOS, n_clients=60, n_rounds=160,
            seeds=seeds, stage_len=8, engine="scan",
            emit_prefix="trace_replay", n_per_class=500,
            axis=[("trace_synth_n60", "trace_replay",
                   {"horizon": 160, "rate": 0.5, "burst": 6.0,
                    "churn": 0.1})])
        results["cells"] += synth["cells"]
    results["resume"] = resume_section(fast)
    save_artifact("trace_replay", results)
    if not fast:
        # committed .md is the full-scale table; --fast must not clobber it
        write_md(results)


def write_md(results: dict) -> None:
    """benchmarks/artifacts/trace_replay.md — trace cells + resume gate."""
    lines = [
        "# Trace replay: recorded availability, elastic fleets, and "
        "checkpoint/resume",
        "",
        f"Fleet sweep over the committed fixture trace "
        f"(benchmarks/fixtures/device_trace_n20_t64.npy: N=20 devices, "
        f"64 recorded rounds, Gilbert–Elliott bursts + 10% permanent "
        f"churn), seeds={results['seeds']}, plus a synthesized N=60 / "
        "T=160 trace at grid scale. Availability streams off disk in "
        "windows (`repro.scenarios.trace_replay`) — no (T, N) mask matrix "
        "exists at any point. Regenerate with `PYTHONPATH=src python "
        "benchmarks/run.py --only trace_replay` (docs/benchmarks.md); the "
        "trace format and checkpoint runbook live in docs/operations.md.",
        "",
        "## Final eval loss (mean over seeds)",
        "",
        "| cell | rate | τ̄ | τ_max | A4 regime | "
        + " | ".join(results["algorithms"]) + " | winner |",
        "|---|---|---|---|---|" + "---|" * (len(results["algorithms"]) + 1),
    ]
    for c in results["cells"]:
        t = c["tau"]
        regime = ("deterministic τ≤" + f"{t['assumption4_t0']:.0f}"
                  if t["assumption4_deterministic"] else "arbitrary")
        row = [c["scenario"], f"{t['rate_empirical']:.2f}",
               f"{t['tau_bar']:.2f}", str(t["tau_max"]), regime]
        for name in results["algorithms"]:
            v = c["algorithms"][name]["final_loss_mean"]
            row.append(f"**{v:.4f}**" if name == c["winner"]
                       else f"{v:.4f}")
        row.append(c["winner"])
        lines.append("| " + " | ".join(row) + " |")
    r = results["resume"]
    lines += [
        "",
        "## Checkpoint/resume exactness (the durability gate)",
        "",
        f"A checkpointed MIFA run (T={r['n_rounds']}, snapshot every "
        f"{r['every']} rounds) killed after round {r['kill_at']} and "
        "resumed from its latest snapshot, vs the uninterrupted run:",
        "",
        "| metric | value |",
        "|---|---|",
        f"| max abs param diff | {r['max_abs_diff']:g} |",
        f"| max abs train-loss diff | {r['train_loss_max_diff']:g} |",
        f"| snapshot size | {r['snapshot_bytes'] / 1024:.0f} KiB |",
        f"| uninterrupted wall | {r['wall_full_s']:.2f} s |",
        f"| resumed-half wall | {r['wall_resumed_s']:.2f} s |",
        "",
        "Both diffs must be exactly 0.0 (fp32 bit-exact) — pinned in "
        "benchmarks/baselines/ci_baseline.json and property-tested across "
        "algorithms (dense MIFA, banked dense, banked paged) in "
        "tests/test_trace_replay.py.",
        "",
        "## Reading the table",
        "",
        "The trace cells are the arbitrary-unavailability regime on "
        "recorded data: churned devices never return, so no availability "
        "law exists for any algorithm to assume. The informative column "
        "pair is mifa vs fedavg (`fedavg_is` ends lowest everywhere for "
        "the step-size reason the atlas documents — its 1/p weights "
        "roughly double the effective step on this convex problem — so "
        "its raw lead is not a like-for-like read). On the bare recording "
        "memorisation is ahead (fixture: bursty correlated absence WITH "
        "eventual return is exactly the biased-cohort case its memory "
        "corrects), and at N=60 synth scale the two tie. The elastic cell "
        "flips the sign: once staged departures remove devices "
        "permanently, MIFA keeps averaging their frozen updates with "
        "uniform weight forever — surrogate gradients whose staleness "
        "grows linearly — and plain FedAvg, which simply forgets the "
        "departed, ends well below it. That boundary is the point of the "
        "benchmark: memorisation's guarantee prices bounded staleness "
        "(Assumption 4 with b > 1); a fleet that shrinks for good "
        "delivers τ = t − t_depart, the b = 1 edge where the memory "
        "turns from correction into anchor.",
        "",
    ]
    path = os.path.join(ARTIFACTS, "trace_replay.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
