"""§Roofline table: read the dry-run artifacts and print/emit the three-term
roofline per (arch x shape) on the single-pod mesh."""
from __future__ import annotations

import glob
import json
import os

from common import ARTIFACTS, emit, save_artifact

DRYRUN_DIR = os.path.join(ARTIFACTS, "dryrun")


def load_records(mesh: str = "pod") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main(fast: bool = False) -> None:
    recs = load_records("pod")
    rows = []
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skip":
            emit(tag, 0.0, f"skip:{r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            emit(tag, 0.0, f"ERROR:{r.get('error', '?')[:80]}")
            continue
        t = r["roofline"]
        mem = r["analysis"]["memory"]
        row = {
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": t["bottleneck"],
            "mem_gb_per_chip": mem["peak_estimate_bytes"] / 1e9,
            "useful_flops_ratio": r["useful_flops_ratio"],
            "model_flops": r["model_flops"],
        }
        rows.append(row)
        emit(tag, t["step_time_lower_bound_s"] * 1e6,
             f"bottleneck={t['bottleneck']};"
             f"mem={row['mem_gb_per_chip']:.1f}GB;"
             f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}")
    save_artifact("roofline_table", {"rows": rows})


if __name__ == "__main__":
    main()
