"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout). Artifacts land in
benchmarks/artifacts/*.json. Pass --fast for a reduced sweep (CI-scale).

  adversarial      : non-stationary/adversarial availability (paper §1/§5)
  fig2_convergence : paper Fig. 2 (4 algorithms x p_min, convex + non-convex)
  case_study       : §5.1 rounds-to-ε vs p_min (Eq. 2 vs Eq. 3)
  tau_stats        : Thm 5.2/5.3 τ statistics validation
  agg_throughput   : MIFA fused-aggregation traffic + kernel check
  roofline_bench   : §Roofline table from the dry-run artifacts
  time_to_accuracy : simulated wall-clock to target loss, MIFA vs.
                     straggler-bound round policies (repro.sim)
  bank_scale       : memory-bank cohort rounds flat in N up to 10⁶ clients
                     (repro.bank), vs the O(N·d) dense round
  fleet_scale      : vmapped K-trial sweep (repro.fleet) vs the sequential
                     run_fl loop — same trials, one program
  scenario_grid    : algorithm × availability-scenario convergence grid
                     (repro.scenarios): MIFA-vs-FedAvg gap under
                     correlated / non-stationary availability
  scenario_atlas   : competing-baseline atlas — every registered
                     algorithm (incl. FedAR, CA-Fed) × scenario × seed
                     as jit(scan(vmap)) fleet programs, with per-scenario
                     winner table
  scan_scale       : whole-run scan engine (core.scan_engine) vs the
                     per-round dispatch loop — rounds/sec across T
  trace_replay     : recorded-trace availability (repro.scenarios
                     .trace_replay) + elastic fleets over the committed
                     fixture, and the checkpoint/kill/resume exactness
                     gate (repro.checkpoint)
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sweep for CI")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name (see module list)")
    args = ap.parse_args()

    names = ("tau_stats", "agg_throughput", "adversarial", "case_study",
             "fig2_convergence", "roofline_bench", "time_to_accuracy",
             "bank_scale", "fleet_scale", "scenario_grid", "scenario_atlas",
             "scan_scale", "trace_replay")
    # validate BEFORE any benchmark module imports: a typo'd --only must
    # not silently run *nothing* (hollow CI smoke steps), and it must not
    # die on some unrelated module's import error either
    if args.only is not None and args.only not in names:
        print(f"unknown benchmark {args.only!r}; valid names: "
              f"{', '.join(names)}", file=sys.stderr)
        raise SystemExit(2)
    selected = names if args.only is None else (args.only,)

    import importlib
    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            importlib.import_module(name).main(fast=args.fast)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
