"""CI perf/loss regression gate: compare fast-bench artifacts to a baseline.

The fast benchmark suite (``python benchmarks/run.py --fast --only <name>``)
writes benchmarks/artifacts/<name>.json. This script compares those
artifacts against the *committed* pins in
benchmarks/baselines/ci_baseline.json and exits non-zero on any regression,
so the tier1 CI job fails instead of silently shipping a slower or
less-convergent engine.

Baseline schema — ``metrics`` maps a human-readable metric name to a spec:

    {"artifact": "scan_scale",          # benchmarks/artifacts/<artifact>.json
     "path": "results.T64.speedup",     # dotted path; ints index lists
     "min": 1.3}                        # and ONE OF the comparators:

  * ``min`` / ``max``   — perf bounds (floor on speedups, cap on times).
    Perf pins are deliberately generous: CI runners vary several-fold in
    absolute speed, but engine-relative ratios (scan vs loop, fleet vs
    sequential) survive machine changes — a ratio collapsing toward 1.0
    means the optimisation itself broke (e.g. the scan path silently
    falling back to the loop).
  * ``value`` + ``rtol`` — convergence pins: |got − want| ≤ rtol·|want|.
    Final losses are deterministic per jax version; the tolerance absorbs
    cross-version fp drift while still catching trajectory corruption.

A missing artifact or path is itself a FAILURE — a benchmark that silently
stopped producing the metric must not read as "no regression".

Refreshing the baseline is an explicit, reviewed act: regenerate the fast
artifacts locally, update the pinned numbers, and commit the diff with the
reason (see docs/benchmarks.md, "Refreshing the CI baseline").
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baselines", "ci_baseline.json")
DEFAULT_ARTIFACTS = os.path.join(HERE, "artifacts")


def extract(obj, path: str):
    """Walk a dotted `path` through dicts (keys) and lists (int indices)."""
    cur = obj
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


def check_metric(name: str, spec: dict, artifacts_dir: str) -> str | None:
    """Returns an error string on regression/missing data, None on pass."""
    art_path = os.path.join(artifacts_dir, spec["artifact"] + ".json")
    if not os.path.exists(art_path):
        return (f"{name}: artifact {spec['artifact']}.json missing from "
                f"{artifacts_dir} (did the benchmark run?)")
    with open(art_path) as f:
        artifact = json.load(f)
    try:
        got = float(extract(artifact, spec["path"]))
    except (KeyError, IndexError, TypeError, ValueError) as e:
        return (f"{name}: path {spec['path']!r} not found in "
                f"{spec['artifact']}.json ({type(e).__name__}: {e})")
    if "min" in spec and got < spec["min"]:
        return (f"{name}: {got:.4g} < min {spec['min']:.4g} "
                f"({spec['artifact']}.json:{spec['path']})")
    if "max" in spec and got > spec["max"]:
        return (f"{name}: {got:.4g} > max {spec['max']:.4g} "
                f"({spec['artifact']}.json:{spec['path']})")
    if "value" in spec:
        want, rtol = float(spec["value"]), float(spec.get("rtol", 1e-3))
        if abs(got - want) > rtol * abs(want):
            return (f"{name}: {got:.6g} deviates from pinned {want:.6g} "
                    f"by more than rtol={rtol} "
                    f"({spec['artifact']}.json:{spec['path']})")
    return None


def run_checks(baseline: dict, artifacts_dir: str) -> list[str]:
    """Check every baseline metric; returns the list of failure messages."""
    failures = []
    for name, spec in baseline["metrics"].items():
        err = check_metric(name, spec, artifacts_dir)
        if err is None:
            print(f"PASS {name}")
        else:
            print(f"FAIL {err}")
            failures.append(err)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="pinned-metric file (ci_baseline.json)")
    ap.add_argument("--artifacts", default=DEFAULT_ARTIFACTS,
                    help="directory of freshly generated artifact JSONs")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = run_checks(baseline, args.artifacts)
    if failures:
        print(f"\n{len(failures)} regression(s) against "
              f"{os.path.relpath(args.baseline)}; if intentional, refresh "
              "the baseline in an explicit reviewed commit "
              "(docs/benchmarks.md).", file=sys.stderr)
        return 1
    print(f"\nall {len(baseline['metrics'])} baseline metrics hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
