"""Paper Figure 2 reproduction: training loss / test accuracy for MIFA vs
{biased FedAvg, FedAvg device-sampling S=50/100, FedAvg-IS} on non-iid data
with label-correlated Bernoulli availability, p_min in {0.1, 0.2}.

Strongly convex run = logistic model (paper: MNIST/logistic);
non-convex run = 2-layer MLP (paper: CIFAR-10/LeNet-5). Synthetic stand-ins —
see docs/architecture.md §6 for why and what transfers.

Each algorithm's seed sweep runs through the vmapped fleet executor
(`repro.fleet`) as ONE program instead of a Python loop over `run_fl` —
per-trial results are bit-exact either way (tests/test_fleet.py), the fleet
is just ~5-6x faster end-to-end (benchmarks/artifacts/fleet_scale.md), so
the same budget buys more seeds/scenarios.
"""
from __future__ import annotations

import time

from common import emit, paper_problem, save_artifact

from repro.core import MIFA, BiasedFedAvg, FedAvgIS, FedAvgSampling
from repro.fleet import Trial, make_fleet_eval, run_fleet
from repro.optim import inv_t


def run(model_name: str, p_min: float, *, n_rounds: int, n_clients: int,
        seeds=(0, 1, 2)) -> dict:
    out: dict = {"model": model_name, "p_min": p_min, "rounds": n_rounds,
                 "algorithms": {}}
    model, batcher, probs, make_part, eval_fn = paper_problem(
        model_name, n_clients=n_clients, p_min=p_min)
    fleet_eval = make_fleet_eval(model, eval_fn.eval_batch)
    algos = {
        "mifa": MIFA(memory="array"),
        "biased_fedavg": BiasedFedAvg(),
        "fedavg_s50": FedAvgSampling(s=n_clients // 2),
        "fedavg_s100": FedAvgSampling(s=n_clients),
        "fedavg_is": FedAvgIS(tuple(probs.tolist())),
    }
    for name, algo in algos.items():
        trials = [Trial(seed=s, participation=make_part(s + 100),
                        label=f"{name}/seed{s}") for s in seeds]
        t0 = time.time()
        _, hist = run_fleet(
            model=model, algo=algo, batcher=batcher, schedule=inv_t(1.0),
            n_rounds=n_rounds, weight_decay=1e-3, trials=trials,
            eval_fn=fleet_eval, eval_every=max(n_rounds // 10, 1),
            uses_update_clock=name.startswith("fedavg_s"))
        wall = time.time() - t0
        losses = [float(v) for v in hist.eval_loss[-1][1]]
        accs = [float(v) for v in hist.eval_acc[-1][1]]
        curve0 = hist.trial(0).train_loss
        out["algorithms"][name] = {
            "final_eval_loss_mean": sum(losses) / len(losses),
            "final_eval_acc_mean": sum(accs) / len(accs),
            "final_eval_loss_all": losses,
            "train_curve_seed0": curve0[:: max(n_rounds // 100, 1)],
            "wall_s": wall,
        }
        emit(f"fig2/{model_name}/pmin{p_min}/{name}",
             wall / len(seeds) / n_rounds * 1e6,
             f"loss={out['algorithms'][name]['final_eval_loss_mean']:.4f};"
             f"acc={out['algorithms'][name]['final_eval_acc_mean']:.4f}")
    return out


def main(fast: bool = False) -> None:
    # fleet-sized sweep: the vmapped executor makes 2-3 seeds per algorithm
    # affordable where the old sequential loop ran 1-2
    rounds = 120 if fast else 160
    clients = 30 if fast else 60
    seeds = (0,) if fast else (0, 1, 2)
    results = []
    for p_min in (0.1, 0.2):
        results.append(run("paper_logistic", p_min, n_rounds=rounds,
                           n_clients=clients, seeds=seeds))
    # non-convex run (smaller round budget — MLP is slower)
    results.append(run("paper_mlp", 0.1, n_rounds=rounds // 2,
                       n_clients=clients, seeds=seeds[:2]))
    save_artifact("fig2_convergence", {"results": results})


if __name__ == "__main__":
    main()
