"""Simulated wall-clock time-to-accuracy under the compiled runtime simulator.

The paper's headline is about *time*, not rounds: the server "efficiently
avoids excessive latency induced by inactive devices". This benchmark has
two sections, both on the simulated-seconds axis:

Section A — engine speedup. The SAME simulated run (Impatient + MIFA under
jit-native Bernoulli availability and tiered shifted-exponential latency)
through the discrete-event heap engine (`repro.sim.engine`, one Python
event loop + one jitted dispatch per round) and the compiled simulator
(`repro.sim.compiled`, the whole event flow — clock, epoch window, policy
resolve — inside jit(scan)). Trajectories are asserted BIT-EXACT (same f32
close times, same losses), so the recorded speedup buys nothing but wall
clock. Steady-state methodology as in scan_scale.py: median per-round
(heap) vs median per-chunk (compiled) with compile time reported
separately. The fast variant feeds the CI regression gate
(benchmarks/baselines/ci_baseline.json pins the speedup floor and the
deterministic final loss).

Section B — the time-to-accuracy sweep the subsystem exists for: seeds ×
server policies (wait_for_all, wait_for_s, deadline, impatient, buffered
K-of-N) as ONE jit(scan(vmap(body))) program per scenario family
(`repro.fleet.run_sim_fleet`), under staged-blackout and cluster-correlated
outage availability. Batches are drawn IN-program
(`JitProceduralBatcher.batch_fn`), so the full mode runs N=10⁵ devices per
lane without the host ever materialising a batch stack. Reports simulated
seconds to the target eval loss per policy (median across seeds).

Artifacts: benchmarks/artifacts/time_to_accuracy.{json,md}.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from common import ARTIFACTS, emit, save_artifact

from repro.core import MIFA, FedBuffAvg, RoundRunner
from repro.data import JitProceduralBatcher
from repro.fleet import SimTrial, make_fleet_eval, run_sim_fleet
from repro.models.layers import softmax_cross_entropy
from repro.scenarios import Bernoulli, ClusterCorrelated, StagedBlackout
from repro.sim import (BufferedKofN, Deadline, FedSimEngine, Impatient,
                       SimConfig, SimScanDriver, SimSpec, WaitForAll,
                       WaitForS, tiered_shifted_exponential)
from repro.sim.compiled import init_sim_carry

DIM, CLASSES = 16, 2
TARGET_LOSS = 0.42
EPOCH_S = 4.0


class TinyLogistic:
    """Minimal model shim (init/loss_fn/accuracy) on DIM→CLASSES logits."""

    def init(self, rng):
        return {"w": jnp.zeros((DIM, CLASSES), jnp.float32),
                "b": jnp.zeros((CLASSES,), jnp.float32)}

    def loss_fn(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return softmax_cross_entropy(logits, batch["y"]), {}

    def accuracy(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return jnp.mean(jnp.argmax(logits, -1) == batch["y"])


def _batcher(n: int, seed: int = 0) -> JitProceduralBatcher:
    return JitProceduralBatcher(n_clients=n, dim=DIM, n_classes=CLASSES,
                                batch_size=8, k_steps=2, noise=2.5,
                                seed=seed)


# --------------------------------------------------------------------------- #
# Section A: heap engine vs compiled simulator, bit-exact, steady-state
# --------------------------------------------------------------------------- #

def engine_speedup(fast: bool) -> dict:
    n = 32 if fast else 256
    rounds = 48 if fast else 192
    chunk = 12 if fast else 32
    config = SimConfig(epoch_s=EPOCH_S, max_lookahead_epochs=50)
    batcher = _batcher(n)
    scen = Bernoulli(0.6, n=n, seed=5)
    lat = tiered_shifted_exponential(n, seed=7)
    sim = SimSpec(policy=Impatient(), latency=lat, config=config)
    make_runner = lambda: RoundRunner(
        model=TinyLogistic(), algo=MIFA(memory="array"), batcher=batcher,
        schedule=lambda t: 0.1, seed=0, scenario=scen)

    # heap: round 0 carries the jit trace of the round step; steady state
    # is the median per-round wall time of the Python event loop + dispatch
    rh = make_runner()
    eng = FedSimEngine(rh, sim.policy, scen.host_sampler(), lat, config,
                       seed=0)
    t0 = time.perf_counter()
    eng.run_round(0)
    jax.block_until_ready(rh.params)
    heap_compile_s = time.perf_counter() - t0
    round_times = []
    for t in range(1, rounds):
        t0 = time.perf_counter()
        eng.run_round(t)
        round_times.append(time.perf_counter() - t0)
    jax.block_until_ready(rh.params)
    heap_steady_s = float(np.sum(round_times))

    # compiled: first chunk carries the scan program's compile; the rest is
    # the pipelined chunk path (build xs + deferred flush + dispatch)
    rs = make_runner()
    drv = SimScanDriver(rs, sim, scan_chunk=chunk)
    carry = init_sim_carry(rs, sim)
    t0 = time.perf_counter()
    xs = drv._build_xs(0, chunk)
    carry, ys = drv._chunk_fn(carry, xs)
    drv._writeback(carry)
    drv._flush(0, chunk, ys, carry)
    scan_compile_s = time.perf_counter() - t0
    chunk_times, chunk_lens = [], []
    pending = None
    for c0 in range(chunk, rounds, chunk):
        c1 = min(c0 + chunk, rounds)
        t0 = time.perf_counter()
        xs = drv._build_xs(c0, c1)
        if pending is not None:
            drv._flush(*pending)
        carry, ys = drv._chunk_fn(carry, xs)
        drv._writeback(carry)
        pending = (c0, c1, ys, carry)
        chunk_times.append(time.perf_counter() - t0)
        chunk_lens.append(c1 - c0)
    t0 = time.perf_counter()
    if pending is not None:
        drv._flush(*pending)
    jax.block_until_ready(rs.params)
    drain_s = time.perf_counter() - t0
    scan_steady_s = float(np.sum(chunk_times)) + drain_s

    # same simulation, not just similar timings: bit-exact close times,
    # applied counts, and training losses
    assert rh.hist.sim_seconds == rs.hist.sim_seconds
    assert rh.hist.train_loss == rs.hist.train_loss
    assert [r["n_applied"] for r in eng.round_log] == \
           [r["n_applied"] for r in drv.round_log]

    heap_rps = 1.0 / float(np.median(round_times))
    full = [dt for dt, ln in zip(chunk_times, chunk_lens) if ln == chunk]
    scan_rps = (chunk / float(np.median(full)) if full
                else chunk / scan_compile_s)
    return {"n_clients": n, "rounds": rounds, "scan_chunk": chunk,
            "heap_compile_s": heap_compile_s,
            "scan_compile_s": scan_compile_s,
            "heap_total_s": heap_compile_s + heap_steady_s,
            "scan_total_s": scan_compile_s + scan_steady_s,
            "heap_rounds_per_s": heap_rps,
            "scan_rounds_per_s": scan_rps,
            "speedup": scan_rps / heap_rps,
            "final_train_loss": rs.hist.train_loss[-1]}


# --------------------------------------------------------------------------- #
# Section B: seeds × policies as one compiled program per scenario family
# --------------------------------------------------------------------------- #

def _policies(n: int, seed: int) -> list[tuple[str, object]]:
    return [
        ("wait_for_all", WaitForAll()),
        ("wait_for_s", WaitForS(s=max(2, n // 3), sel_seed=seed)),
        ("deadline", Deadline(deadline_s=3.0, sel_seed=seed)),
        ("impatient", Impatient()),
        ("buffered", BufferedKofN(k=max(2, n // 4))),
    ]


def _scenario(kind: str, n: int, seed: int):
    if kind == "blackout":
        # staged rates sharpening mid-run: lively -> deep blackout -> partial
        # recovery; the slow third is hit hardest in the blackout stage
        stage = np.full((3, n), 0.85, np.float32)
        stage[1] = 0.15
        stage[1, : n // 3] = 0.05
        stage[2] = 0.6
        return StagedBlackout(stage, bounds=[8, 20], n=n, seed=seed)
    if kind == "cluster":
        return ClusterCorrelated(n, 8, q_fail=0.25, q_recover=0.4,
                                 p_device=0.9, seed=seed)
    raise ValueError(kind)


def seconds_to_target_loss(hist, target: float) -> float | None:
    """First simulated second at which eval loss reaches `target`."""
    for sim_t, loss, _ in hist.eval_curve():
        if loss <= target:
            return sim_t
    return None


def sweep(kind: str, *, n: int, rounds: int, seeds, chunk: int,
          config: SimConfig, batcher, eval_fn) -> dict:
    trials, names = [], []
    for seed in seeds:
        for name, policy in _policies(n, seed):
            trials.append(SimTrial(
                seed=seed, policy=policy,
                scenario=_scenario(kind, n, 100 + seed),
                latency=tiered_shifted_exponential(n, seed=7 + seed),
                label=f"{name}/seed{seed}"))
            names.append((name, seed))
    t0 = time.perf_counter()
    _, hist = run_sim_fleet(
        model=TinyLogistic(), algo=FedBuffAvg(), batcher=batcher,
        schedule=lambda t: 0.008, n_rounds=rounds, trials=trials,
        config=config, scan_chunk=chunk, eval_fn=eval_fn, eval_every=5,
        batch_fn=batcher.batch_fn())
    host_s = time.perf_counter() - t0

    lanes = {}
    for k, (name, seed) in enumerate(names):
        h = hist.trial(k)
        lanes[f"{name}/seed{seed}"] = {
            "policy": name, "seed": seed,
            "sim_seconds_total": h.sim_seconds[-1],
            "seconds_to_target": seconds_to_target_loss(h, TARGET_LOSS),
            "final_eval_acc": h.eval_acc[-1][1],
            "final_eval_loss": h.eval_loss[-1][1],
            "eval_curve": h.eval_curve()}
    by_policy = {}
    for name, _ in _policies(n, 0):
        tts = [lanes[f"{name}/seed{s}"]["seconds_to_target"] for s in seeds]
        reached = [t for t in tts if t is not None]
        by_policy[name] = {
            "seconds_to_target_median": (float(np.median(reached))
                                         if len(reached) == len(tts)
                                         else None),
            "reached": len(reached), "of": len(tts)}
    return {"kind": kind, "n_clients": n, "rounds": rounds,
            "k_lanes": len(trials), "host_seconds": host_s,
            "by_policy": by_policy, "lanes": lanes}


# --------------------------------------------------------------------------- #

def main(fast: bool = False) -> None:
    sec_a = engine_speedup(fast)
    emit("time_to_accuracy/engine_speedup", sec_a["scan_total_s"] * 1e6,
         f"heap_rps={sec_a['heap_rounds_per_s']:.0f};"
         f"scan_rps={sec_a['scan_rounds_per_s']:.0f};"
         f"speedup={sec_a['speedup']:.1f}x;"
         f"loss={sec_a['final_train_loss']:.4f}")

    n = 96 if fast else 100_000
    rounds = 40 if fast else 60
    chunk = 10 if fast else 20
    seeds = (0, 1, 2)
    config = SimConfig(epoch_s=EPOCH_S, max_lookahead_epochs=64)
    batcher = _batcher(n)
    eval_fn = make_fleet_eval(TinyLogistic(), batcher.eval_batch(1024))
    sweeps = {}
    for kind in ("blackout", "cluster"):
        sweeps[kind] = sweep(kind, n=n, rounds=rounds, seeds=seeds,
                             chunk=chunk, config=config, batcher=batcher,
                             eval_fn=eval_fn)
        for name, rec in sweeps[kind]["by_policy"].items():
            tt = rec["seconds_to_target_median"]
            emit(f"time_to_accuracy/{kind}/{name}",
                 sweeps[kind]["host_seconds"] / rounds * 1e6,
                 f"to_target={'%.0f' % tt if tt is not None else 'never'};"
                 f"reached={rec['reached']}/{rec['of']}")

    payload = {"target_loss": TARGET_LOSS, "epoch_s": EPOCH_S,
               "seeds": list(seeds), "section_a": sec_a, "sweeps": sweeps}
    save_artifact("time_to_accuracy", payload)
    write_md(payload)

    # headline: under both correlated-outage families, closing rounds
    # without waiting on stragglers (impatient / buffered) must reach the
    # target eval loss in no more simulated time than blocking on every
    # device — and never fail to reach it when wait_for_all does.
    for kind, sw in sweeps.items():
        bp = sw["by_policy"]
        tt_imp = bp["impatient"]["seconds_to_target_median"]
        tt_all = bp["wait_for_all"]["seconds_to_target_median"]
        assert tt_imp is not None, f"{kind}: impatient never hit target"
        assert tt_all is None or tt_imp <= tt_all, (kind, tt_imp, tt_all)


def write_md(payload: dict) -> None:
    a = payload["section_a"]
    lines = [
        "# Simulated wall-clock time-to-accuracy (compiled runtime simulator)",
        "",
        "## Engine speedup: compiled jit(scan) vs discrete-event heap",
        "",
        f"Impatient + MIFA(array) at N = {a['n_clients']} clients, "
        f"T = {a['rounds']} simulated rounds, jit-native Bernoulli "
        "availability, tiered shifted-exponential latency. Same simulation "
        "bit-for-bit (f32 close times, losses asserted equal); rounds/sec "
        "are steady-state medians with compile time reported separately. "
        "`benchmarks/time_to_accuracy.py` regenerates this file.",
        "",
        "| engine | rounds/s | compile (s) | total (s) |",
        "|---|---|---|---|",
        f"| event heap (`sim.engine`) | {a['heap_rounds_per_s']:.0f} | "
        f"{a['heap_compile_s']:.2f} | {a['heap_total_s']:.2f} |",
        f"| compiled (`sim.compiled`) | {a['scan_rounds_per_s']:.0f} | "
        f"{a['scan_compile_s']:.2f} | {a['scan_total_s']:.2f} |",
        "",
        f"**Steady-state speedup: {a['speedup']:.1f}x** "
        f"(final train loss {a['final_train_loss']:.6f}, identical on both "
        "engines).",
        "",
        "## Time to target eval loss: seeds × policies, one program per "
        "scenario",
        "",
        f"Median simulated seconds to eval loss {payload['target_loss']} "
        f"across seeds {payload['seeds']}; each scenario family "
        "runs every (seed, policy) lane in ONE jit(scan(vmap)) program "
        "via `repro.fleet.run_sim_fleet`, batches drawn in-program by "
        "`JitProceduralBatcher`.",
        "",
    ]
    for kind, sw in payload["sweeps"].items():
        lines += [
            f"### {kind} (N = {sw['n_clients']:,} devices, "
            f"{sw['k_lanes']} lanes, {sw['rounds']} rounds, "
            f"{sw['host_seconds']:.1f}s host)",
            "",
            "| policy | sim-seconds to target (median) | reached |",
            "|---|---|---|",
        ]
        for name, rec in sw["by_policy"].items():
            tt = rec["seconds_to_target_median"]
            lines.append(
                f"| {name} | "
                f"{'%.0f' % tt if tt is not None else '—'} | "
                f"{rec['reached']}/{rec['of']} |")
        lines.append("")
    lines += [
        "Waiting for every device (`wait_for_all`) pays for stragglers and "
        "blackouts in simulated seconds; the impatient and buffered-async "
        "servers close rounds on whoever arrives and convert the same "
        "gradient work into target accuracy sooner. The buffered K-of-N "
        "lanes merge stragglers later with staleness-discounted weight "
        "(`FedBuffAvg`) instead of dropping them.",
        "",
    ]
    path = os.path.join(ARTIFACTS, "time_to_accuracy.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
