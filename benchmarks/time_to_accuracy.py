"""Wall-clock time-to-accuracy: MIFA's impatient server vs. straggler-bound
round policies, on the discrete-event runtime simulator (repro.sim).

The paper's headline is about *time*, not rounds: the server "efficiently
avoids excessive latency induced by inactive devices". Here every client gets
a tiered shifted-exponential round-trip latency and an availability process,
and we measure simulated seconds to a target eval loss under four server
policies:

  wait_for_all    broadcast, block for every device (incl. blacked-out ones)
  wait_for_s      paper Eq. 3: sample S, block until all S respond
  deadline        broadcast, fixed deadline, drop late responders (biased)
  impatient_mifa  MIFA: close with whoever is available; memory de-biases

plus `impatient_biased` (impatient server WITHOUT memory) to isolate the
memory contribution. Availability: Bernoulli (label-correlated), adversarial
periodic blackouts, and a sticky-Markov trace replay.

Artifact: benchmarks/artifacts/time_to_accuracy.json with per-policy eval
curves on the simulated-seconds axis and seconds-to-target per process.
"""
from __future__ import annotations

import time

import numpy as np
from adversarial import make_adversarial
from common import emit, paper_problem, save_artifact

from repro.core import (MIFA, BernoulliParticipation, BiasedFedAvg,
                        RoundRunner, TraceParticipation)
from repro.optim import inv_t
from repro.sim import (Deadline, FedSimEngine, Impatient, SimConfig,
                       WaitForAll, WaitForS, tiered_shifted_exponential)

TARGET_LOSS = 1.30          # logistic 10-class starts near ln(10) ≈ 2.30


def markov_trace(n: int, rounds: int, *, p_drop=0.15, p_return=0.35,
                 seed: int = 0) -> np.ndarray:
    """Sticky on/off Markov availability — the non-stationary trace regime.
    Slow third drops more and returns less (correlated with the latency tiers)."""
    rng = np.random.default_rng(seed)
    drop = np.full(n, p_drop)
    ret = np.full(n, p_return)
    drop[: n // 3] = 3 * p_drop
    ret[: n // 3] = p_return / 2
    trace = np.ones((rounds, n), bool)
    for t in range(1, rounds):
        up = trace[t - 1]
        stay_up = rng.random(n) >= drop
        come_up = rng.random(n) < ret
        trace[t] = np.where(up, stay_up, come_up)
    return trace


def seconds_to_target(hist, target: float) -> float | None:
    for sim_t, loss, _ in hist.eval_curve():
        if loss <= target:
            return sim_t
    return None


def run_policy(name, policy, algo, participation, *, problem, rounds,
               epoch_s, seed=0):
    model, batcher, eval_fn = problem
    runner = RoundRunner(model=model, algo=algo, batcher=batcher,
                         schedule=inv_t(1.0), weight_decay=1e-3, seed=seed)
    latency = tiered_shifted_exponential(batcher.n_clients, seed=seed + 7)
    engine = FedSimEngine(runner, policy, participation, latency,
                          config=SimConfig(epoch_s=epoch_s), seed=seed + 13)
    t0 = time.time()
    _, hist = engine.run(rounds, eval_fn=eval_fn, eval_every=5)
    return {
        "policy": name,
        "sim_seconds_total": engine.now,
        "seconds_to_target": seconds_to_target(hist, TARGET_LOSS),
        "eval_curve": hist.eval_curve(),
        "final_eval_loss": hist.eval_loss[-1][1],
        "final_eval_acc": hist.eval_acc[-1][1],
        "tau_bar": hist.tau_bar,
        "tau_max": hist.tau_max,
        "mean_round_s": float(np.mean([r["duration_s"]
                                       for r in engine.round_log])),
        "host_seconds": time.time() - t0,
    }


def main(fast: bool = False) -> None:
    n_clients = 18 if fast else 24
    rounds = 60 if fast else 120
    epoch_s = 4.0
    s = max(2, n_clients // 3)

    model, batcher, probs, _, eval_fn = paper_problem(
        "paper_logistic", n_clients=n_clients, p_min=0.3)
    problem = (model, batcher, eval_fn)

    def policies():
        return [
            ("wait_for_all", WaitForAll(), BiasedFedAvg()),
            ("wait_for_s", WaitForS(s=s), BiasedFedAvg()),
            ("deadline", Deadline(deadline_s=3.0), BiasedFedAvg()),
            ("impatient_mifa", Impatient(), MIFA(memory="array")),
            ("impatient_biased", Impatient(), BiasedFedAvg()),
        ]

    def availability(kind, seed=0):
        if kind == "bernoulli":
            return BernoulliParticipation(probs, seed=42 + seed)
        if kind == "adversarial":
            return make_adversarial(n_clients, seed=seed)[0]
        if kind == "trace":
            # trace indexed by availability *epoch*; size for the worst case
            return TraceParticipation(
                markov_trace(n_clients, 50 * rounds, seed=seed))
        raise ValueError(kind)

    results: dict = {}
    for kind in ("bernoulli", "adversarial", "trace"):
        results[kind] = {}
        for name, policy, algo in policies():
            rec = run_policy(name, policy, algo, availability(kind),
                             problem=problem, rounds=rounds, epoch_s=epoch_s)
            results[kind][name] = rec
            tt = rec["seconds_to_target"]
            emit(f"time_to_accuracy/{kind}/{name}",
                 rec["host_seconds"] / rounds * 1e6,
                 f"sim_s={rec['sim_seconds_total']:.0f};"
                 f"to_target={'%.0f' % tt if tt is not None else 'never'};"
                 f"loss={rec['final_eval_loss']:.4f}")

    save_artifact("time_to_accuracy", {
        "n_clients": n_clients, "rounds": rounds, "epoch_s": epoch_s,
        "target_loss": TARGET_LOSS, "s": s, "results": results})

    # headline: under adversarial blackouts the impatient (MIFA) server must
    # reach the target loss in strictly less simulated wall-clock than the
    # wait-for-S straggler-bound protocol.
    adv = results["adversarial"]
    tt_mifa = adv["impatient_mifa"]["seconds_to_target"]
    tt_wfs = adv["wait_for_s"]["seconds_to_target"]
    assert tt_mifa is not None, "MIFA never reached the target loss"
    assert tt_wfs is None or tt_mifa < tt_wfs, (tt_mifa, tt_wfs)


if __name__ == "__main__":
    main()
