"""The paper's headline claim: MIFA under NON-STATIONARY / adversarial
availability (§1, §5: "allows patterns of the device unavailability to be
non-stationary and even adversarial").

Pattern: deterministic periodic blackouts with device-specific period/duty
(satisfies Assumption 4, is neither i.i.d. nor stationary). Under this
pattern FedAvg-IS is *mis-specified* — there is no participation probability
to invert, so we feed it the empirical average rate, which biases it —
while MIFA needs no availability model at all.
"""
from __future__ import annotations

import time

import numpy as np
from common import emit, paper_problem, save_artifact

from repro.core import (MIFA, AdversarialParticipation, BiasedFedAvg,
                        FedAvgIS, run_fl)
from repro.optim import inv_t


def make_adversarial(n_clients: int, seed: int = 0):
    """Stragglers (first third) are dark 3 of every 4 rounds, mid third 1 of
    3, the rest 1 of 8 — deterministic, phase-shifted."""
    rng = np.random.default_rng(seed)
    periods = np.empty(n_clients, np.int64)
    offs = np.empty(n_clients, np.int64)
    third = n_clients // 3
    periods[:third], offs[:third] = 4, 3
    periods[third:2 * third], offs[third:2 * third] = 3, 1
    periods[2 * third:], offs[2 * third:] = 8, 1
    phases = rng.integers(0, 8, n_clients)
    part = AdversarialParticipation(n_clients, periods, offs, phases)
    empirical_rate = 1.0 - offs / periods
    return part, empirical_rate


def main(fast: bool = False) -> None:
    n_clients = 24 if fast else 36
    rounds = 100 if fast else 180
    model, batcher, _, _, eval_fn = paper_problem(
        "paper_logistic", n_clients=n_clients, p_min=0.5)  # probs unused
    part, rate = make_adversarial(n_clients)

    results = {}
    for name, algo in [
        ("mifa", MIFA(memory="array")),
        ("biased_fedavg", BiasedFedAvg()),
        ("fedavg_is_misspecified", FedAvgIS(tuple(rate.tolist()))),
    ]:
        t0 = time.time()
        _, hist = run_fl(model=model, algo=algo,
                         participation=make_adversarial(n_clients)[0],
                         batcher=batcher, schedule=inv_t(1.0),
                         n_rounds=rounds, weight_decay=1e-3, seed=0,
                         eval_fn=eval_fn, eval_every=rounds)
        results[name] = {"final_eval_loss": hist.eval_loss[-1][1],
                         "final_eval_acc": hist.eval_acc[-1][1],
                         "tau_bar": hist.tau_bar, "tau_max": hist.tau_max}
        emit(f"adversarial/{name}", (time.time() - t0) / rounds * 1e6,
             f"loss={results[name]['final_eval_loss']:.4f};"
             f"acc={results[name]['final_eval_acc']:.4f};"
             f"tau_max={hist.tau_max}")
    save_artifact("adversarial", {"rounds": rounds, "n_clients": n_clients,
                                  "results": results})
    # MIFA must beat (or match) both baselines without any availability model
    assert results["mifa"]["final_eval_loss"] <= \
        results["biased_fedavg"]["final_eval_loss"] + 0.05


if __name__ == "__main__":
    main()
