"""Bank scale sweep: cohort rounds must be flat in N, linear in |A(t)|.

Fixed cohort size C, N ∈ {10², 10⁴, 10⁵, 10⁶}: per-round wall time and bank
memory for the MemoryBank backends, against the dense O(N·d) MIFA round at
small N (the thing that stops scaling). Then a cohort sweep at fixed N to
show the cost that *should* grow (linear in C) does.

Backends on this CPU container:
  * host / int8_paged — O(C·d) per round (numpy row writes); int8_paged's
    resident bytes additionally track clients-ever-seen, not N.
  * dense — O(C·d) when the jitted scatter updates rows in place (donated
    buffers / XLA's in-place scatter); swept to 10⁵ to bound device-memory
    use and benchmark runtime on CI hosts.

Usage:
    PYTHONPATH=src python benchmarks/run.py --only bank_scale [--fast]
Artifacts: benchmarks/artifacts/bank_scale.json (+ CSV rows on stdout).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from common import emit, save_artifact

from repro.bank import BankedMIFA, make_bank
from repro.core import MIFA
from repro.core.runner import RoundRunner
from repro.data import ProceduralBatcher
from repro.models.layers import softmax_cross_entropy

DIM, CLASSES = 16, 2


class TinyLogistic:
    """Minimal model shim (init/loss_fn) — d = DIM·CLASSES + CLASSES."""

    def init(self, rng):
        return {"w": jnp.zeros((DIM, CLASSES), jnp.float32),
                "b": jnp.zeros((CLASSES,), jnp.float32)}

    def loss_fn(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return softmax_cross_entropy(logits, batch["y"]), {}


def _draw_cohort(rng, n: int, c: int) -> np.ndarray:
    """<=c unique ids in O(c) — no O(N) permutation at N=10⁶."""
    return np.unique(rng.integers(0, n, size=2 * c))[:c]


def _runner(backend: str, n: int, cohort: int, seed: int = 0) -> RoundRunner:
    batcher = ProceduralBatcher(n_clients=n, dim=DIM, n_classes=CLASSES,
                                batch_size=8, k_steps=2, seed=seed)
    return RoundRunner(model=TinyLogistic(), algo=BankedMIFA(make_bank(backend)),
                       batcher=batcher, schedule=lambda t: 0.1, seed=seed,
                       cohort_capacity=cohort)


def time_bank_rounds(backend: str, n: int, cohort: int, *, rounds: int,
                     warmup: int = 3, seed: int = 0) -> dict:
    runner = _runner(backend, n, cohort, seed=seed)
    rng = np.random.default_rng(seed)
    for t in range(warmup):
        runner.step_cohort(t, _draw_cohort(rng, n, cohort))
    jax.block_until_ready(runner.params)
    t0 = time.perf_counter()
    for t in range(warmup, warmup + rounds):
        runner.step_cohort(t, _draw_cohort(rng, n, cohort))
    jax.block_until_ready(runner.params)
    us = (time.perf_counter() - t0) / rounds * 1e6
    mem = runner.algo.bank.memory_bytes(runner.state["bank"])
    return {"backend": backend, "n": n, "cohort": cohort, "us_per_round": us,
            "device_bytes": mem["device"], "host_bytes": mem["host"],
            "final_loss": runner.hist.train_loss[-1]}


def time_dense_mifa_rounds(n: int, *, rounds: int, warmup: int = 2,
                           seed: int = 0) -> dict:
    """The O(N·d) baseline: every round vmaps client_updates over ALL N."""
    batcher = ProceduralBatcher(n_clients=n, dim=DIM, n_classes=CLASSES,
                                batch_size=8, k_steps=2, seed=seed)
    runner = RoundRunner(model=TinyLogistic(), algo=MIFA(memory="array"),
                         batcher=batcher, schedule=lambda t: 0.1, seed=seed)
    mask = np.zeros(n, bool)
    mask[:: max(n // 32, 1)] = True              # ~32 active, rest memorized
    for t in range(warmup):
        runner.step(t, mask)
    jax.block_until_ready(runner.params)
    t0 = time.perf_counter()
    for t in range(warmup, warmup + rounds):
        runner.step(t, mask)
    jax.block_until_ready(runner.params)
    us = (time.perf_counter() - t0) / rounds * 1e6
    g_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(runner.state["G"]))
    return {"backend": "dense_mifa_O(N)", "n": n, "cohort": int(mask.sum()),
            "us_per_round": us, "device_bytes": g_bytes, "host_bytes": 0,
            "final_loss": runner.hist.train_loss[-1]}


def main(fast: bool = False) -> None:
    cohort = 16 if fast else 32
    rounds = 3 if fast else 10
    ns = [100, 2_000] if fast else [100, 10_000, 100_000, 1_000_000]
    sweeps = {
        "host": ns,
        "int8_paged": ns,
        "dense": [n for n in ns if n <= 100_000],
    }
    baseline_ns = [100, 1_000] if fast else [100, 10_000]

    rows = []
    for n in baseline_ns:
        row = time_dense_mifa_rounds(n, rounds=rounds)
        rows.append(row)
        emit(f"bank_scale/dense_mifa_n{n}", row["us_per_round"],
             f"device_mb={row['device_bytes'] / 1e6:.1f}")
    for backend, sweep in sweeps.items():
        per_n = []
        for n in sweep:
            row = time_bank_rounds(backend, n, cohort, rounds=rounds)
            rows.append(row)
            per_n.append(row)
            emit(f"bank_scale/{backend}_n{n}", row["us_per_round"],
                 f"host_mb={row['host_bytes'] / 1e6:.1f},"
                 f"device_mb={row['device_bytes'] / 1e6:.1f}")
        # flat-in-N check: largest-N round vs smallest-N round
        ratio = per_n[-1]["us_per_round"] / per_n[0]["us_per_round"]
        n_ratio = per_n[-1]["n"] / per_n[0]["n"]
        emit(f"bank_scale/{backend}_flatness", 0.0,
             f"time_ratio={ratio:.2f}_over_{n_ratio:.0f}x_N")

    # the dimension that SHOULD grow: cohort size at fixed N
    n_fixed = 2_000 if fast else 100_000
    cohort_rows = []
    for c in ([8, 32] if fast else [8, 32, 128, 512]):
        row = time_bank_rounds("host", n_fixed, c, rounds=rounds)
        cohort_rows.append(row)
        emit(f"bank_scale/host_n{n_fixed}_c{c}", row["us_per_round"],
             f"cohort={c}")

    save_artifact("bank_scale", {"rows": rows, "cohort_rows": cohort_rows,
                                 "cohort": cohort, "rounds": rounds})

    # sanity, not a timing assert: scaling 10-10000x in N must not blow up
    # host-bank round time anywhere near linearly (allow generous jitter)
    host = [r for r in rows if r["backend"] == "host"]
    ratio = host[-1]["us_per_round"] / host[0]["us_per_round"]
    n_ratio = host[-1]["n"] / host[0]["n"]
    assert ratio < max(8.0, 0.05 * n_ratio), (ratio, n_ratio)


if __name__ == "__main__":
    main()
