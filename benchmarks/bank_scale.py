"""Bank scale sweep: cohort rounds must be flat in N, linear in |A(t)|.

Fixed cohort size C, N ∈ {10², 10⁴, 10⁵, 10⁶}: per-round wall time and bank
memory for the MemoryBank backends, against the dense O(N·d) MIFA round at
small N (the thing that stops scaling). Then a cohort sweep at fixed N to
show the cost that *should* grow (linear in C) does.

Backends on this CPU container:
  * host / int8_paged — O(C·d) per round (numpy row writes); int8_paged's
    resident bytes additionally track clients-ever-seen, not N.
  * dense — O(C·d) when the jitted scatter updates rows in place (donated
    buffers / XLA's in-place scatter); swept to 10⁵ to bound device-memory
    use and benchmark runtime on CI hosts.
  * paged_device — jittable like dense, but device bytes are bounded by
    (n_slots+1)·page_size·d regardless of N: rows page in/out through a
    jit-native page table, so it also rides engine="scan" at N=10⁶
    (the `paged_scan` section below times exactly that).

Every row records a peak-device-bytes column: `device.memory_stats()`'s
`peak_bytes_in_use` where the backend reports it (GPU/TPU), else the bytes
live on device after the timed rounds (`jax.live_arrays()` — CPU fallback,
a floor on the true peak).

Usage:
    PYTHONPATH=src python benchmarks/run.py --only bank_scale [--fast]
Artifacts: benchmarks/artifacts/bank_scale.json (+ CSV rows on stdout).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from common import emit, save_artifact

from repro.bank import BankedMIFA, make_bank
from repro.core import MIFA
from repro.core.runner import RoundRunner, run_fl
from repro.data import ProceduralBatcher
from repro.models.layers import softmax_cross_entropy

DIM, CLASSES = 16, 2
PAGED_KW = {"page_size": 64, "n_slots": 128}


def _peak_device_bytes() -> int:
    """Peak device allocation if the platform reports it, else live bytes."""
    try:
        stats = jax.local_devices()[0].memory_stats()
        if stats and "peak_bytes_in_use" in stats:
            return int(stats["peak_bytes_in_use"])
    except Exception:
        pass
    return int(sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.live_arrays()))


class TinyLogistic:
    """Minimal model shim (init/loss_fn) — d = DIM·CLASSES + CLASSES."""

    def init(self, rng):
        return {"w": jnp.zeros((DIM, CLASSES), jnp.float32),
                "b": jnp.zeros((CLASSES,), jnp.float32)}

    def loss_fn(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return softmax_cross_entropy(logits, batch["y"]), {}


def _draw_cohort(rng, n: int, c: int) -> np.ndarray:
    """<=c unique ids in O(c) — no O(N) permutation at N=10⁶."""
    return np.unique(rng.integers(0, n, size=2 * c))[:c]


def _runner(backend: str, n: int, cohort: int, seed: int = 0,
            **bank_kwargs) -> RoundRunner:
    batcher = ProceduralBatcher(n_clients=n, dim=DIM, n_classes=CLASSES,
                                batch_size=8, k_steps=2, seed=seed)
    return RoundRunner(model=TinyLogistic(),
                       algo=BankedMIFA(make_bank(backend, **bank_kwargs)),
                       batcher=batcher, schedule=lambda t: 0.1, seed=seed,
                       cohort_capacity=cohort)


def time_bank_rounds(backend: str, n: int, cohort: int, *, rounds: int,
                     warmup: int = 3, seed: int = 0, **bank_kwargs) -> dict:
    runner = _runner(backend, n, cohort, seed=seed, **bank_kwargs)
    rng = np.random.default_rng(seed)
    for t in range(warmup):
        runner.step_cohort(t, _draw_cohort(rng, n, cohort))
    jax.block_until_ready(runner.params)
    t0 = time.perf_counter()
    for t in range(warmup, warmup + rounds):
        runner.step_cohort(t, _draw_cohort(rng, n, cohort))
    jax.block_until_ready(runner.params)
    us = (time.perf_counter() - t0) / rounds * 1e6
    mem = runner.algo.bank.memory_bytes(runner.state["bank"])
    return {"backend": backend, "n": n, "cohort": cohort, "us_per_round": us,
            "device_bytes": mem["device"], "host_bytes": mem["host"],
            "device_pages_bytes": mem.get("device_pages"),
            "peak_device_bytes": _peak_device_bytes(),
            "final_loss": runner.hist.train_loss[-1]}


def time_dense_mifa_rounds(n: int, *, rounds: int, warmup: int = 2,
                           seed: int = 0) -> dict:
    """The O(N·d) baseline: every round vmaps client_updates over ALL N."""
    batcher = ProceduralBatcher(n_clients=n, dim=DIM, n_classes=CLASSES,
                                batch_size=8, k_steps=2, seed=seed)
    runner = RoundRunner(model=TinyLogistic(), algo=MIFA(memory="array"),
                         batcher=batcher, schedule=lambda t: 0.1, seed=seed)
    mask = np.zeros(n, bool)
    mask[:: max(n // 32, 1)] = True              # ~32 active, rest memorized
    for t in range(warmup):
        runner.step(t, mask)
    jax.block_until_ready(runner.params)
    t0 = time.perf_counter()
    for t in range(warmup, warmup + rounds):
        runner.step(t, mask)
    jax.block_until_ready(runner.params)
    us = (time.perf_counter() - t0) / rounds * 1e6
    g_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(runner.state["G"]))
    return {"backend": "dense_mifa_O(N)", "n": n, "cohort": int(mask.sum()),
            "us_per_round": us, "device_bytes": g_bytes, "host_bytes": 0,
            "device_pages_bytes": None,
            "peak_device_bytes": _peak_device_bytes(),
            "final_loss": runner.hist.train_loss[-1]}


class _SparseTrace:
    """Fixed random C-cohort trace. Deliberately NOT TraceParticipation,
    whose forced all-active round 0 would fault every page at once."""

    def __init__(self, n: int, cohort: int, rounds: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.trace = np.zeros((rounds, n), bool)
        for t in range(rounds):
            self.trace[t, _draw_cohort(rng, n, cohort)] = True
        self.n = n

    def sample(self, t):
        return self.trace[t]


def time_paged_scan(n: int, *, rounds: int, cohort: int, scan_chunk: int,
                    seed: int = 0) -> dict:
    """run_fl over the paged bank: engine="scan" vs the dispatch loop.

    Each engine runs twice; the second run hits the in-process jit cache,
    so its wall time is steady-state (compile reported separately).
    """
    def _run(engine):
        batcher = ProceduralBatcher(n_clients=n, dim=DIM, n_classes=CLASSES,
                                    batch_size=8, k_steps=2, seed=seed)
        algo = BankedMIFA(make_bank("paged_device", **PAGED_KW))
        t0 = time.perf_counter()
        params, hist = run_fl(
            model=TinyLogistic(), algo=algo, batcher=batcher,
            participation=_SparseTrace(n, cohort, rounds, seed=seed),
            schedule=lambda t: 0.1, n_rounds=rounds, seed=seed,
            cohort_capacity=cohort, engine=engine, scan_chunk=scan_chunk)
        jax.block_until_ready(params)
        return time.perf_counter() - t0, hist

    loop_first, _ = _run("loop")
    loop_s, h_loop = _run("loop")
    scan_first, _ = _run("scan")
    scan_s, h_scan = _run("scan")
    assert h_loop.train_loss == h_scan.train_loss   # same trajectory
    return {"n": n, "rounds": rounds, "cohort": cohort,
            "scan_chunk": scan_chunk,
            "loop_first_s": loop_first, "scan_first_s": scan_first,
            "loop_s": loop_s, "scan_s": scan_s,
            "speedup": loop_s / scan_s,
            "peak_device_bytes": _peak_device_bytes(),
            "final_train_loss": h_scan.train_loss[-1]}


def main(fast: bool = False) -> None:
    cohort = 16 if fast else 32
    rounds = 3 if fast else 10
    ns = [100, 2_000] if fast else [100, 10_000, 100_000, 1_000_000]
    sweeps = {
        "host": (ns, {}),
        "int8_paged": (ns, {}),
        "dense": ([n for n in ns if n <= 100_000], {}),
        "paged_device": (ns, dict(PAGED_KW)),
    }
    baseline_ns = [100, 1_000] if fast else [100, 10_000]

    rows = []
    for n in baseline_ns:
        row = time_dense_mifa_rounds(n, rounds=rounds)
        rows.append(row)
        emit(f"bank_scale/dense_mifa_n{n}", row["us_per_round"],
             f"device_mb={row['device_bytes'] / 1e6:.1f}")
    for backend, (sweep, bkw) in sweeps.items():
        per_n = []
        for n in sweep:
            # paged faults compile one scatter per pow-2 batch bucket; give
            # the paging phase time to settle before the timed rounds
            wu = 8 if backend == "paged_device" else 3
            row = time_bank_rounds(backend, n, cohort, rounds=rounds,
                                   warmup=wu, **bkw)
            rows.append(row)
            per_n.append(row)
            emit(f"bank_scale/{backend}_n{n}", row["us_per_round"],
                 f"host_mb={row['host_bytes'] / 1e6:.1f},"
                 f"device_mb={row['device_bytes'] / 1e6:.1f},"
                 f"peak_device_mb={row['peak_device_bytes'] / 1e6:.1f}")
        # flat-in-N check: largest-N round vs smallest-N round
        ratio = per_n[-1]["us_per_round"] / per_n[0]["us_per_round"]
        n_ratio = per_n[-1]["n"] / per_n[0]["n"]
        emit(f"bank_scale/{backend}_flatness", 0.0,
             f"time_ratio={ratio:.2f}_over_{n_ratio:.0f}x_N")
        if backend == "paged_device":
            # the bounded-bytes claim: the page pool is (n_slots+1)·ps·d
            # regardless of N — identical across the whole sweep
            pool = {r["device_pages_bytes"] for r in per_n}
            assert len(pool) == 1, pool
            emit("bank_scale/paged_device_pool", 0.0,
                 f"device_pool_mb={pool.pop() / 1e6:.2f}_flat_in_N")

    # the dimension that SHOULD grow: cohort size at fixed N
    n_fixed = 2_000 if fast else 100_000
    cohort_rows = []
    for c in ([8, 32] if fast else [8, 32, 128, 512]):
        row = time_bank_rounds("host", n_fixed, c, rounds=rounds)
        cohort_rows.append(row)
        emit(f"bank_scale/host_n{n_fixed}_c{c}", row["us_per_round"],
             f"cohort={c}")

    # the tentpole end-to-end: run_fl(engine="scan") over the paged bank —
    # fast mode times a CI-pinned point, full mode goes to N=10⁶
    scan_n = 2_000 if fast else 1_000_000
    scan_rounds = 64 if fast else 32
    # chunk * cohort must stay within the slot budget: under scan the
    # residency unit is the chunk's cohort union
    scan_chunk = PAGED_KW["n_slots"] // cohort
    paged_scan = time_paged_scan(scan_n, rounds=scan_rounds, cohort=cohort,
                                 scan_chunk=scan_chunk)
    emit(f"bank_scale/paged_scan_n{scan_n}", paged_scan["scan_s"] * 1e6,
         f"speedup={paged_scan['speedup']:.2f}x,"
         f"loss={paged_scan['final_train_loss']:.4f},"
         f"peak_device_mb={paged_scan['peak_device_bytes'] / 1e6:.1f}")

    # paged rounds are flat in N, so comparing the largest swept points is
    # fair even though the O(N·d) baseline stops at a smaller N
    mifa_last = [r for r in rows if r["backend"] == "dense_mifa_O(N)"][-1]
    paged_last = [r for r in rows if r["backend"] == "paged_device"][-1]
    vs_mifa = mifa_last["us_per_round"] / paged_last["us_per_round"]
    emit("bank_scale/paged_vs_dense_mifa", 0.0, f"speedup={vs_mifa:.1f}x")

    save_artifact("bank_scale", {"rows": rows, "cohort_rows": cohort_rows,
                                 "paged_scan": paged_scan,
                                 "paged_vs_dense_mifa_speedup": vs_mifa,
                                 "cohort": cohort, "rounds": rounds})

    # sanity, not a timing assert: scaling 10-10000x in N must not blow up
    # host-bank round time anywhere near linearly (allow generous jitter)
    host = [r for r in rows if r["backend"] == "host"]
    ratio = host[-1]["us_per_round"] / host[0]["us_per_round"]
    n_ratio = host[-1]["n"] / host[0]["n"]
    assert ratio < max(8.0, 0.05 * n_ratio), (ratio, n_ratio)


if __name__ == "__main__":
    main()
