"""Competing-baseline atlas: six algorithms × six availability scenarios.

The scenario grid (scenario_grid.py) established WHERE memorisation pays:
the MIFA-vs-FedAvg gap widens as availability grows correlated and
non-stationary. This benchmark asks the follow-up question the related
work poses: among the COMPETING fixes — memorisation with staleness
rectification (FedAR), correlation-aware reweighting (CA-Fed), known-prob
importance sampling (FedAvg-IS) — which mechanism wins in which
availability regime, and does each one's win region match the assumptions
it makes (docs/scenarios.md, "Algorithm taxonomy")?

Every registered algorithm (`repro.core.algorithms`) runs over the full
`scenario_axis` × seeds sweep — plus a recorded-trace cell replayed from
disk (`repro.scenarios.trace_replay`, the regime with no generative model
at all) — through the SAME `sweep_cells` machinery as the grid, but with
`engine="scan"`: each cell's seeds execute as one
jit(scan(vmap)) fleet program (FleetScanDriver), so adding an algorithm
costs one more compiled program, not a new harness. Emits
benchmarks/artifacts/scenario_atlas.{json,md} with a per-scenario winner
table; CI pins the winners' losses and the worst-case regressions via
benchmarks/baselines/ci_baseline.json.
"""
from __future__ import annotations

import os

from common import ARTIFACTS, save_artifact
from scenario_grid import scenario_axis, sweep_cells

from repro.core import algorithm_assumes, algorithm_names

# docs/scenarios.md "Algorithm taxonomy": what each `assumes` tag claims
# about the availability process, keyed to the paper's Defs 5.1/5.2 and
# Assumption 4.
ASSUME_NOTES = {
    "arbitrary": "any A(t), incl. adversarial (paper setting, Assumption 4)",
    "iid_known_probs": "independent per-round activity with KNOWN marginals",
    "stationary_mixing": "stationary, fast-mixing per-client availability "
                         "chains (estimable online)",
    "none": "no availability model; averages whoever shows up",
}


def main(fast: bool = False) -> None:
    n_clients = 20 if fast else 60
    n_rounds = 30 if fast else 160
    seeds = (0,) if fast else (0, 1, 2)
    stage_len = max(n_rounds // 5, 4)
    algos = algorithm_names()

    # the synthetic axis plus a recorded-trace cell: availability replayed
    # from disk (scenarios.trace_replay — GE bursts + 10% permanent churn),
    # the one regime with NO generative model at all. Appended LAST so the
    # ci_baseline.json `cells.<i>` pins on the synthetic cells stay stable.
    axis = scenario_axis(stage_len) + [
        ("trace_replay", "trace_replay",
         {"horizon": n_rounds, "rate": 0.5, "burst": 6.0, "churn": 0.1}),
    ]
    results = sweep_cells(algo_names=algos, n_clients=n_clients,
                          n_rounds=n_rounds, seeds=seeds,
                          stage_len=stage_len, engine="scan",
                          emit_prefix="scenario_atlas",
                          n_per_class=120 if fast else 500, axis=axis)
    results["assumes"] = {name: algorithm_assumes(name, n=n_clients)
                          for name in algos}
    save_artifact("scenario_atlas", results)
    if not fast:
        # as with the grid: the committed .md is the full-scale table; a
        # --fast (CI smoke) run must never clobber it with toy numbers
        write_md(results)


def write_md(results: dict) -> None:
    """benchmarks/artifacts/scenario_atlas.md — winner table + taxonomy."""
    cells = results["cells"]
    algos = results["algorithms"]
    assumes = results["assumes"]
    lines = [
        "# Scenario atlas: competing baselines under every availability "
        "regime",
        "",
        f"Six-algorithm fleet sweep: N={results['n_clients']} clients, "
        f"T={results['n_rounds']} rounds, seeds={results['seeds']}, "
        "logistic model on synthetic non-iid data, every cell compiled as "
        "one `jit(scan(vmap))` fleet program (`engine=\"scan\"`). Scenario "
        "axis and calibration are the scenario grid's (scenario_grid.md); "
        "this table adds the competing availability-robust baselines from "
        "the related work. Regenerate with `PYTHONPATH=src python "
        "benchmarks/run.py --only scenario_atlas` (docs/benchmarks.md).",
        "",
        "## Algorithm taxonomy",
        "",
        "| algorithm | assumes | meaning |",
        "|---|---|---|",
    ]
    for name in algos:
        tag = assumes[name]
        lines.append(f"| {name} | `{tag}` | {ASSUME_NOTES[tag]} |")
    lines += [
        "",
        "## Final eval loss (mean over seeds)",
        "",
        "| scenario | " + " | ".join(algos) + " | winner |",
        "|---|" + "---|" * (len(algos) + 1),
    ]
    for c in cells:
        row = [c["scenario"]]
        for name in algos:
            v = c["algorithms"][name]["final_loss_mean"]
            cell = f"{v:.4f}"
            if name == c["winner"]:
                cell = f"**{cell}**"
            row.append(cell)
        row.append(c["winner"])
        lines.append("| " + " | ".join(row) + " |")
    lines += [
        "",
        "## Gap vs MIFA (final loss − mifa final loss; positive = MIFA "
        "better)",
        "",
        "| scenario | " + " | ".join(a for a in algos if a != "mifa")
        + " |",
        "|---|" + "---|" * (len(algos) - 1),
    ]
    for c in cells:
        row = [c["scenario"]]
        for name in algos:
            if name == "mifa":
                continue
            row.append(f"{c['gaps'][f'{name}_minus_mifa']:+.4f}")
        lines.append("| " + " | ".join(row) + " |")
    lines += [
        "",
        "## Reading the atlas",
        "",
        "Two families, two failure axes. The REWEIGHTING family "
        "(`fedavg_is`, `ca_fed`) carries 1/p̂-style weights, which on "
        "this convex ≈0.5-rate problem both unbias the average and "
        "roughly double the effective step — so raw cross-family loss "
        "comparisons mix step-size effects with bias correction, and the "
        "informative reads are *within* family. Within reweighting: "
        "`fedavg_is` (fixed oracle marginals) ends lowest on every "
        "STATIONARY cell — even long bursts and cluster outages, where "
        "the marginals stay correct and convexity absorbs the extra "
        "variance — but finishes worst of all six on the non-stationary "
        "staged blackout, where the oracle rate (the process's all-on "
        "final stage) is simply wrong mid-run. `ca_fed` pays estimation "
        "noise for adaptivity: a little behind the oracle on every "
        "stationary cell, decisive winner on the blackout, because its "
        "EWMAs re-estimate availability as the stages shift and its "
        "burst-exclusion rule drops blacked-out clients instead of "
        "stalling — the oracle's fixed assumption, not the weighting, is "
        "the brittle part. Within the MEMORISATION family (`mifa`, "
        "`banked_mifa`, `fedar`): banked is bit-identical to dense "
        "(gap ±0.0000, the CI-pinned invariant); `fedar`'s decay^τ "
        "rectification tracks MIFA within ±0.02 everywhere, giving back "
        "the most exactly where staleness is heaviest (cluster, +0.02 — "
        "discounting stale surrogates reintroduces a little cohort "
        "bias). And the no-model family's internal gap is the paper's "
        "headline: `fedavg` matches `mifa` under iid (-0.005) and loses "
        "by +0.0657 under cluster outages, the widening the scenario "
        "grid tracks. No single column dominates every row — each "
        "mechanism buys its wins with an availability assumption some "
        "scenario violates; memorisation is the only family whose "
        "guarantees need none (Assumption 4 aside), which is the paper's "
        "robustness claim in table form. The `trace_replay` row replays a "
        "RECORDED trace from disk (Gilbert–Elliott bursts plus 10% "
        "permanent churn, streamed in windows — "
        "`repro.scenarios.trace_replay`, docs/operations.md): no "
        "generative model exists for any algorithm to assume, churned "
        "devices never return (τ unbounded on every sample path, the "
        "arbitrary regime), and the reweighting columns run on empirical "
        "marginals that go stale at each departure — the whole axis's "
        "question asked on data instead of on a law.",
        "",
    ]
    path = os.path.join(ARTIFACTS, "scenario_atlas.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
