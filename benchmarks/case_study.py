"""§5.1 case study: rounds-to-ε vs p_min (paper Eq. 2 vs Eq. 3).

MIFA's round complexity scales with avg(1/p_i); sampling-based FedAvg pays
1/p_min through cohort waiting. We sweep p_min and measure the first round at
which the evaluation loss crosses a threshold ε.

The p_min sweep is a fleet: MIFA and device-sampling FedAvg run ALL p_min
points as one vmapped program each (one trial per availability point —
participation is host-side environment, so any availability parameter
batches freely). FedAvg-IS bakes the probabilities into its *static* config
(a hashable tuple), so it cannot batch across p_min and loops sequentially —
the one-spec-per-point case `repro.fleet.spec.expand_grid` documents.
"""
from __future__ import annotations

import time

import numpy as np
from common import emit, paper_problem, save_artifact

from repro.core import (MIFA, BernoulliParticipation, FedAvgIS,
                        FedAvgSampling, label_correlated_probs, run_fl)
from repro.fleet import Trial, make_fleet_eval, run_fleet
from repro.optim import inv_t


def _first_crossing(eval_rounds, losses, eps: float, max_rounds: int) -> int:
    for t, loss in zip(eval_rounds, losses):
        if loss <= eps:
            return int(t)
    return max_rounds  # censored


def main(fast: bool = False) -> None:
    eps = 1.2
    max_rounds = 150 if fast else 300
    n_clients = 30 if fast else 40
    p_mins = (0.05, 0.1, 0.2, 0.4) if not fast else (0.1, 0.3)

    model, batcher, _, _, eval_fn = paper_problem(
        "paper_logistic", n_clients=n_clients, p_min=p_mins[0])
    labels = eval_fn.client_labels
    fleet_eval = make_fleet_eval(model, eval_fn.eval_batch)
    probs_for = {pm: label_correlated_probs(labels, pm) for pm in p_mins}

    def trials_for():
        return [Trial(seed=0,
                      participation=BernoulliParticipation(probs_for[pm],
                                                           seed=7),
                      label=f"pmin{pm}") for pm in p_mins]

    kw = dict(model=model, batcher=batcher, schedule=inv_t(1.0),
              n_rounds=max_rounds, weight_decay=1e-3, eval_fn=fleet_eval,
              eval_every=5)
    t0 = time.time()
    _, h_mifa = run_fleet(algo=MIFA(memory="array"), trials=trials_for(),
                          **kw)
    _, h_samp = run_fleet(algo=FedAvgSampling(s=n_clients // 3),
                          trials=trials_for(), uses_update_clock=True, **kw)
    t2 = time.time()
    # FedAvg-IS: static per-point probs => sequential, one run per p_min
    h_is, wall_is = {}, {}
    for pm in p_mins:
        ti = time.time()
        _, h = run_fl(model=model, batcher=batcher, schedule=inv_t(1.0),
                      n_rounds=max_rounds, weight_decay=1e-3, seed=0,
                      algo=FedAvgIS(tuple(probs_for[pm].tolist())),
                      participation=BernoulliParticipation(probs_for[pm],
                                                           seed=7),
                      eval_fn=lambda p: eval_fn(p), eval_every=5)
        h_is[pm] = h
        wall_is[pm] = time.time() - ti
    # per-point attributable cost: the two fleet sweeps amortise over all
    # p_min points, the sequential IS run is that point's own wall clock
    wall_fleet_per_point = (t2 - t0) / len(p_mins)

    stacked = {"mifa": h_mifa.stacked(), "sampling": h_samp.stacked()}
    rows = []
    for j, pm in enumerate(p_mins):
        inv_avg = float(np.mean(1.0 / probs_for[pm]))
        inv_min = float(1.0 / probs_for[pm].min())
        r_mifa = _first_crossing(stacked["mifa"]["eval_rounds"],
                                 stacked["mifa"]["eval_loss"][j], eps,
                                 max_rounds)
        r_samp = _first_crossing(stacked["sampling"]["eval_rounds"],
                                 stacked["sampling"]["eval_loss"][j], eps,
                                 max_rounds)
        r_is = _first_crossing([t for t, _ in h_is[pm].eval_loss],
                               [v for _, v in h_is[pm].eval_loss], eps,
                               max_rounds)
        rows.append({"p_min": pm, "avg_inv_p": inv_avg,
                     "inv_p_min": inv_min, "mifa": r_mifa,
                     "sampling": r_samp, "is": r_is})
        emit(f"case_study/pmin{pm}",
             (wall_fleet_per_point + wall_is[pm]) * 1e6 / 3,
             f"mifa={r_mifa};sampling={r_samp};is={r_is};"
             f"avg_inv_p={inv_avg:.2f};inv_pmin={inv_min:.1f}")
    save_artifact("case_study", {"eps": eps, "rows": rows})


if __name__ == "__main__":
    main()
