"""§5.1 case study: rounds-to-ε vs p_min (paper Eq. 2 vs Eq. 3).

MIFA's round complexity scales with avg(1/p_i); sampling-based FedAvg pays
1/p_min through cohort waiting. We sweep p_min and measure the first round at
which the evaluation loss crosses a threshold ε.
"""
from __future__ import annotations

import time

import numpy as np
from common import emit, paper_problem, save_artifact

from repro.core import MIFA, FedAvgIS, FedAvgSampling, run_fl
from repro.optim import inv_t


def rounds_to_eps(model, batcher, algo, part, eval_fn, *, eps: float,
                  max_rounds: int, clock: bool) -> int:
    _, hist = run_fl(model=model, algo=algo, participation=part,
                     batcher=batcher, schedule=inv_t(1.0),
                     n_rounds=max_rounds, weight_decay=1e-3, seed=0,
                     eval_fn=eval_fn, eval_every=5, uses_update_clock=clock)
    for t, loss in hist.eval_loss:
        if loss <= eps:
            return t
    return max_rounds  # censored


def main(fast: bool = False) -> None:
    eps = 1.2
    max_rounds = 150 if fast else 300
    n_clients = 30 if fast else 40
    p_mins = (0.05, 0.1, 0.2, 0.4) if not fast else (0.1, 0.3)
    rows = []
    for p_min in p_mins:
        model, batcher, probs, make_part, eval_fn = paper_problem(
            "paper_logistic", n_clients=n_clients, p_min=p_min)
        inv_avg = float(np.mean(1.0 / probs))
        inv_min = float(1.0 / probs.min())
        t0 = time.time()
        r_mifa = rounds_to_eps(model, batcher, MIFA(memory="array"),
                               make_part(7), eval_fn, eps=eps,
                               max_rounds=max_rounds, clock=False)
        r_samp = rounds_to_eps(model, batcher, FedAvgSampling(s=n_clients // 3),
                               make_part(7), eval_fn, eps=eps,
                               max_rounds=max_rounds, clock=True)
        r_is = rounds_to_eps(model, batcher, FedAvgIS(tuple(probs.tolist())),
                             make_part(7), eval_fn, eps=eps,
                             max_rounds=max_rounds, clock=False)
        wall = time.time() - t0
        rows.append({"p_min": p_min, "avg_inv_p": inv_avg,
                     "inv_p_min": inv_min, "mifa": r_mifa,
                     "sampling": r_samp, "is": r_is})
        emit(f"case_study/pmin{p_min}", wall * 1e6 / 3,
             f"mifa={r_mifa};sampling={r_samp};is={r_is};"
             f"avg_inv_p={inv_avg:.2f};inv_pmin={inv_min:.1f}")
    save_artifact("case_study", {"eps": eps, "rows": rows})


if __name__ == "__main__":
    main()
