"""Shared benchmark utilities: the paper's experimental setup on synthetic
non-iid data (docs/architecture.md §6), timed-call helper, artifact IO."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import (BernoulliParticipation,  # noqa: E402
                        label_correlated_probs)
from repro.data import (ClientBatcher, label_skew_partition,  # noqa: E402
                        make_classification)
from repro.models import build_model  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def paper_problem(model_name: str = "paper_logistic", *, n_clients: int = 100,
                  p_min: float = 0.1, n_per_class: int = 500,
                  batch_size: int = 100, k_steps: int = 5, seed: int = 0):
    """The paper §7 setup: N=100 clients, 2 classes each, label-correlated
    Bernoulli availability, batch 100 (synthetic stand-in for MNIST/CIFAR)."""
    cfg = get_config(model_name).replace(fl_clients=n_clients)
    model = build_model(cfg)
    X, y = make_classification(10, cfg.d_model, n_per_class, noise=1.0,
                               seed=seed)
    Xte, yte = make_classification(10, cfg.d_model, 100, noise=1.0,
                                   seed=seed + 1000)
    idx, labels = label_skew_partition(y, n_clients, seed=seed)
    probs = label_correlated_probs(labels, p_min=p_min)
    batcher = ClientBatcher(X, y, idx, batch_size=batch_size, k_steps=k_steps,
                            seed=seed)

    def eval_fn(params):
        batch = {"x": jnp.asarray(Xte), "y": jnp.asarray(yte)}
        loss, _ = model.loss_fn(params, batch)
        return float(loss), float(model.accuracy(params, batch))

    # the raw test set and client labels, so fleet benchmarks can build a
    # vmapped eval (repro.fleet.make_fleet_eval) and sweep availability
    # parameters (label_correlated_probs) over the same problem instance
    eval_fn.eval_batch = {"x": Xte, "y": yte}
    eval_fn.client_labels = labels

    participation = lambda s: BernoulliParticipation(probs, seed=s)
    return model, batcher, probs, participation, eval_fn


def timeit_us(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def save_artifact(name: str, payload: dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
