"""Scan engine scaling: whole-run lax.scan vs the per-round dispatch loop.

MIFA's value claim is wall-clock speed under arbitrary availability, but on
the tiny models where availability studies actually run (the paper's Fig. 2
logistic problem, correlated-availability grids) the per-round loop is
dominated by dispatch: one jitted call, one host→device batch upload, and
one Python iteration per round. The scan engine
(`core.scan_engine`, docs/architecture.md §9) compiles `scan_chunk`-round
blocks into single XLA programs, so a T-round run is ~T/scan_chunk
launches instead of T.

This benchmark runs identical trials (same seed, same jit-native Bernoulli
scenario — availability sampled inside the program on both paths) through
both engines at T ∈ {64, 256, 1024}, asserts the trajectories are
bit-exact, and records rounds/sec and the speedup in
benchmarks/artifacts/scan_scale.{json,md}. The headline metric is
*steady-state* rounds/sec — the first round (loop) / first chunk (scan)
carries jit compilation and is timed separately (`loop_compile_s` /
`scan_compile_s` in the artifact) — because dispatch overhead per round,
not one-time tracing, is what the engine removes and what a T≫chunk run
converges to. End-to-end totals including compile are recorded alongside.

The fast (CI) variant feeds the perf-regression gate: its artifact is
compared against benchmarks/baselines/ci_baseline.json by
benchmarks/check_regression.py (see docs/benchmarks.md for the refresh
workflow).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np
from common import ARTIFACTS, emit, paper_problem, save_artifact

from repro.core import MIFA, RoundRunner, ScanDriver
from repro.optim import inv_t
from repro.scenarios import Bernoulli

SCAN_CHUNK = 64


def one_point(*, model, batcher, probs, n_rounds: int) -> dict:
    # keep a steady-state region even at small T (chunk == T would leave
    # nothing to measure after the compile chunk)
    chunk = min(SCAN_CHUNK, max(n_rounds // 4, 1))
    make_runner = lambda: RoundRunner(
        model=model, algo=MIFA(memory="array"), batcher=batcher,
        schedule=inv_t(1.0), weight_decay=1e-3, seed=0,
        scenario=Bernoulli(probs, seed=123))

    # per-round dispatch loop: round 0 carries the jit trace; steady-state
    # cost is the MEDIAN per-round wall time (robust to scheduler noise
    # over the seconds-long window a 1024-round loop spans)
    rl = make_runner()
    t0 = time.perf_counter()
    rl.step_scenario(0)
    jax.block_until_ready(rl.params)
    loop_compile_s = time.perf_counter() - t0
    round_times = []
    for t in range(1, n_rounds):
        t0 = time.perf_counter()
        rl.step_scenario(t)
        round_times.append(time.perf_counter() - t0)
    jax.block_until_ready(rl.params)
    loop_steady_s = float(np.sum(round_times))
    p_loop, h_loop = rl.finalize()

    # scan engine: the first chunk carries the scan program's compile; the
    # rest runs through the driver's pipelined chunk path, one timing
    # sample per chunk iteration (build + deferred flush + dispatch)
    rs = make_runner()
    drv = ScanDriver(rs, scan_chunk=chunk)
    carry = drv._init_carry()
    t0 = time.perf_counter()
    xs = drv._build_xs(0, chunk, None)
    carry, ys = drv._chunk_fn(carry, xs)
    drv._writeback(carry)
    drv._flush(0, chunk, ys, carry)
    scan_compile_s = time.perf_counter() - t0
    chunk_times, chunk_lens = [], []
    pending = None
    for c0 in range(chunk, n_rounds, chunk):
        c1 = min(c0 + chunk, n_rounds)
        t0 = time.perf_counter()
        xs = drv._build_xs(c0, c1, None)
        if pending is not None:
            drv._flush(*pending)
        carry, ys = drv._chunk_fn(carry, xs)
        drv._writeback(carry)
        pending = (c0, c1, ys, carry)
        chunk_times.append(time.perf_counter() - t0)
        chunk_lens.append(c1 - c0)
    t0 = time.perf_counter()
    if pending is not None:
        drv._flush(*pending)
    jax.block_until_ready(rs.params)
    drain_s = time.perf_counter() - t0
    scan_steady_s = float(np.sum(chunk_times)) + drain_s
    p_scan, h_scan = rs.finalize()

    # same trajectory, not just similar timings: the speedup must come from
    # fewer dispatches, not from computing something else
    for a, b in zip(jax.tree.leaves(p_loop), jax.tree.leaves(p_scan)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h_loop.train_loss == h_scan.train_loss

    loop_rps = 1.0 / float(np.median(round_times))
    full = [dt for dt, ln in zip(chunk_times, chunk_lens) if ln == chunk]
    scan_rps = (chunk / float(np.median(full)) if full
                else chunk / scan_compile_s)
    return {"T": n_rounds, "scan_chunk": chunk,
            "loop_compile_s": loop_compile_s,
            "scan_compile_s": scan_compile_s,
            "loop_total_s": loop_compile_s + loop_steady_s,
            "scan_total_s": scan_compile_s + scan_steady_s,
            "loop_rounds_per_s": loop_rps,
            "scan_rounds_per_s": scan_rps,
            "speedup": scan_rps / loop_rps,
            "total_speedup": (loop_compile_s + loop_steady_s)
            / (scan_compile_s + scan_steady_s),
            "final_train_loss": h_scan.train_loss[-1]}


def main(fast: bool = False) -> None:
    Ts = (16, 64) if fast else (64, 256, 1024)
    # the paper's tiny logistic problem at sweep scale: dispatch overhead,
    # not compute, is the cost the scan engine removes
    model, batcher, probs, _, _ = paper_problem(
        "paper_logistic", n_clients=10, n_per_class=50, batch_size=8,
        k_steps=2)
    results = {}
    for T in Ts:
        r = one_point(model=model, batcher=batcher, probs=probs, n_rounds=T)
        results[f"T{T}"] = r
        emit(f"scan_scale/T{T}", r["scan_total_s"] * 1e6,
             f"loop_rps={r['loop_rounds_per_s']:.0f};"
             f"scan_rps={r['scan_rounds_per_s']:.0f};"
             f"speedup={r['speedup']:.1f}x;"
             f"total_speedup={r['total_speedup']:.1f}x")
    payload = {"Ts": list(Ts), "n_clients": 10, "scan_chunk": SCAN_CHUNK,
               "results": results}
    save_artifact("scan_scale", payload)
    if not fast:
        write_md(payload)


def write_md(payload: dict) -> None:
    lines = [
        "# Scan engine scaling: whole-run lax.scan vs per-round dispatch",
        "",
        f"MIFA(array) on the tiny paper-logistic problem "
        f"(N = {payload['n_clients']} clients, CPU), availability sampled "
        "in-program from a jit-native Bernoulli scenario on BOTH paths; "
        f"scan_chunk ≤ {payload['scan_chunk']}. Rounds/sec are steady-state "
        "— median per-round (loop) / per-chunk (scan) wall time; the first "
        "round / first chunk carries jit compilation and is reported in "
        "the compile columns — and `total` columns are end-to-end "
        "including compile. Trajectories are asserted bit-exact between "
        "the engines. `benchmarks/scan_scale.py` regenerates this file.",
        "",
        "| T rounds | loop rounds/s | scan rounds/s | steady speedup | "
        "loop total (s) | scan total (s) | total speedup | "
        "loop compile (s) | scan compile (s) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key, r in payload["results"].items():
        lines.append(
            f"| {r['T']} | {r['loop_rounds_per_s']:.0f} | "
            f"{r['scan_rounds_per_s']:.0f} | {r['speedup']:.1f}x | "
            f"{r['loop_total_s']:.2f} | {r['scan_total_s']:.2f} | "
            f"{r['total_speedup']:.1f}x | {r['loop_compile_s']:.2f} | "
            f"{r['scan_compile_s']:.2f} |")
    lines += [
        "",
        "The loop pays one jitted dispatch + one host→device batch upload "
        "per round; the scan amortises both over `scan_chunk`-round "
        "compiled blocks with donated carries, and overlaps host batch "
        "assembly with device compute (the driver flushes each chunk one "
        "iteration late). The trajectories are fp32 bit-exact "
        "(tests/test_scan_engine.py), so the speedup is free: same rounds, "
        "same numbers, ~T/scan_chunk launches.",
        "",
    ]
    path = os.path.join(ARTIFACTS, "scan_scale.md")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
